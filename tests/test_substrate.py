"""Substrate tests: optimizer, data pipeline, checkpointing, engine.

Property tests guard `hypothesis` with pytest.importorskip so minimal
environments still run the unit tests.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.training import optimizer as opt


class TestAdamW:
    def _params(self, key=0):
        k = jax.random.key(key)
        return {"w": jax.random.normal(k, (8, 8)),
                "b": jnp.zeros((8,)),
                "nested": {"m": jax.random.normal(k, (4, 8))}}

    def test_descends_quadratic(self):
        cfg = opt.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                              total_steps=1000)
        params = self._params()
        state = opt.init_state(params)
        target = jax.tree.map(jnp.zeros_like, params)

        def loss_fn(p):
            return sum(jnp.sum((a - b) ** 2) for a, b in
                       zip(jax.tree.leaves(p), jax.tree.leaves(target)))

        l0 = float(loss_fn(params))
        for _ in range(50):
            grads = jax.grad(loss_fn)(params)
            params, state, _ = opt.apply_updates(params, grads, state, cfg)
        assert float(loss_fn(params)) < 0.1 * l0

    def test_grad_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = opt.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        total = jnp.sqrt(sum(jnp.sum(x ** 2)
                             for x in jax.tree.leaves(clipped)))
        assert float(total) == pytest.approx(1.0, rel=1e-5)

    def test_weight_decay_only_on_matrices(self):
        cfg = opt.AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0)
        params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        state = opt.init_state(params)
        grads = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = opt.apply_updates(params, grads, state, cfg)
        assert float(new["w"][0, 0]) < 1.0   # decayed
        assert float(new["b"][0]) == 1.0     # not decayed

    def test_lr_schedule_shape(self):
        cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_frac=0.1)
        lrs = [float(opt.lr_schedule(cfg, jnp.int32(s)))
               for s in (0, 5, 10, 55, 100, 200)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert 0.1 < lrs[3] < 1.0
        assert lrs[4] == pytest.approx(0.1, rel=1e-5)
        assert lrs[5] == pytest.approx(0.1, rel=1e-5)

    def test_update_is_finite(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(lr=st.floats(1e-5, 1e-2), seed=st.integers(0, 100))
        @settings(max_examples=20, deadline=None)
        def run(lr, seed):
            cfg = opt.AdamWConfig(lr=lr, warmup_steps=0)
            params = self._params(seed)
            state = opt.init_state(params)
            grads = jax.tree.map(
                lambda p: jax.random.normal(jax.random.key(seed), p.shape),
                params)
            new, _state, _m = opt.apply_updates(params, grads, state, cfg)
            for leaf in jax.tree.leaves(new):
                assert bool(jnp.isfinite(leaf).all())

        run()


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        a = next(SyntheticTokens(cfg))
        b = next(SyntheticTokens(cfg))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_stream_advances(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        it = SyntheticTokens(cfg)
        a, b = next(it), next(it)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_tokens_in_range(self):
        cfg = DataConfig(vocab_size=50, seq_len=32, global_batch=8)
        batch = next(SyntheticTokens(cfg))
        assert batch["tokens"].min() >= 0
        assert batch["tokens"].max() < 50

    def test_markov_structure_learnable(self):
        """Each token has at most `branching` successors."""
        cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=8,
                         branching=4)
        toks = next(SyntheticTokens(cfg))["tokens"]
        succ = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                succ.setdefault(int(a), set()).add(int(b))
        assert max(len(s) for s in succ.values()) <= 4


class TestCheckpoint:
    def test_roundtrip(self):
        params = {"w": jnp.arange(6.0).reshape(2, 3),
                  "n": {"b": jnp.ones((4,), jnp.bfloat16)}}
        with tempfile.TemporaryDirectory() as d:
            store.save(d, 10, params)
            out = store.restore(d, params)
            np.testing.assert_array_equal(np.asarray(out["w"]),
                                          np.asarray(params["w"]))
            assert out["n"]["b"].dtype == jnp.bfloat16
            assert store.latest_step(d) == 10
            assert store.meta(d)["step"] == 10

    def test_retention(self):
        params = {"w": jnp.zeros(2)}
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3, 4, 5):
                store.save(d, s, params, keep=2)
            steps = sorted(os.listdir(d))
            assert len(steps) == 2
            assert store.latest_step(d) == 5

    def test_opt_state_roundtrip(self):
        params = {"w": jnp.ones((3, 3))}
        state = opt.init_state(params)
        with tempfile.TemporaryDirectory() as d:
            store.save(d, 1, params, state)
            out = store.restore(d, state, name="opt_state.npz")
            assert int(out.step) == 0


class TestEngine:
    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import get_smoke_config
        from repro.models import Model
        cfg = get_smoke_config("llama3-8b")
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        return cfg, model, params

    def test_serves_batched_requests(self, setup):
        from repro.serving.engine import InferenceEngine
        cfg, model, params = setup
        fake = [0.0]
        eng = InferenceEngine(model, params, max_batch=4, max_len=48,
                              policy="proposed", num_host_cores=8,
                              clock=lambda: fake[0])
        rng = np.random.default_rng(0)
        ids = [eng.submit(rng.integers(0, 999, 8).tolist(), 5)
               for _ in range(6)]
        for _ in range(100):
            if not eng.pending and not eng.active_mask.any():
                break
            eng.step()
            fake[0] += 0.1
        reqs = {r.req_id: r for r in
                [x for x in eng.slots if x] + eng.pending}
        assert not reqs  # drained
        assert eng.host_cpu_report()["assigns"] >= 6 * 3

    def test_engine_matches_sequential_decode(self, setup):
        """Continuous batching must produce the same tokens as dedicated
        single-request decoding (greedy)."""
        from repro.serving.engine import InferenceEngine
        cfg, model, params = setup
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, 999, 8).tolist() for _ in range(3)]

        # sequential reference
        want = []
        for p in prompts:
            toks = jnp.asarray(p, jnp.int32)[None, :]
            logits, cache = jax.jit(
                lambda pr, t: model.prefill(pr, t, None, max_len=32)
            )(params, toks)
            out = [int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))]
            for _ in range(3):
                tok = jnp.asarray([[out[-1]]], jnp.int32)
                logits, cache = jax.jit(model.decode_step)(params, cache,
                                                           tok)
                out.append(int(jnp.argmax(logits[0, 0, :cfg.vocab_size])))
            want.append(out)

        eng = InferenceEngine(model, params, max_batch=4, max_len=32,
                              policy="linux", num_host_cores=4)
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        eng.run_until_drained()
        # engine retains outputs on the request objects it created; gather
        # them via the slots history -> track through returned ids instead
        # (requests complete in submission order here)
        # We reconstruct by re-submitting and recording step outputs:
        eng2 = InferenceEngine(model, params, max_batch=4, max_len=32,
                               policy="linux", num_host_cores=4)
        reqs = [eng2.submit(p, max_new_tokens=4) for p in prompts]
        outputs = {r: [] for r in reqs}
        for _ in range(50):
            if not eng2.pending and not eng2.active_mask.any():
                break
            for rid, tok in eng2.step():
                outputs[rid].append(tok)
        for rid, p, w in zip(reqs, prompts, want):
            # first token comes from prefill (recorded at admit), so the
            # stepped tokens are w[1:]
            assert outputs[rid] == w[1:], (rid, outputs[rid], w)
