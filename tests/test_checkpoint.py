"""`repro.checkpoint.store`: flat-key .npz checkpoints with atomic
rename, step retention, bf16 round-trip, and template-driven restore.

The store backs both the training driver and the fleet engine's
checkpoint/resume (`repro.sim.fleetsim`), whose bit-exact resume
contract needs numpy template leaves restored as numpy with their
dtype preserved — pinned here.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


@pytest.fixture
def params():
    return {"w": np.arange(6, dtype=np.float64).reshape(2, 3),
            "b": np.array([1.5, -2.5], dtype=np.float32)}


class TestSaveLayout:
    def test_atomic_rename_layout(self, tmp_path, params):
        """A finished checkpoint is a fully-renamed `step_XXXXXXXX`
        directory — no stray temp dirs survive, so a reader never sees
        a half-written checkpoint."""
        path = store.save(str(tmp_path), 3, params)
        assert os.path.basename(path) == "step_00000003"
        assert sorted(os.listdir(tmp_path)) == ["step_00000003"]
        assert "params.npz" in os.listdir(path)
        assert "meta.json" in os.listdir(path)

    def test_meta_round_trip(self, tmp_path, params):
        store.save(str(tmp_path), 5, params,
                   extra={"config": "abc123", "engine": "fleet"})
        meta = store.meta(str(tmp_path))
        assert meta["step"] == 5
        assert meta["config"] == "abc123"
        assert meta["engine"] == "fleet"

    def test_keep_retention_gc(self, tmp_path, params):
        """`keep=` bounds the directory to the newest N checkpoints."""
        for step in range(6):
            store.save(str(tmp_path), step, params, keep=2)
        kept = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert kept == ["step_00000004", "step_00000005"]

    def test_opt_state_is_separate_file(self, tmp_path, params):
        opt = {"m": np.zeros(3), "v": np.ones(3)}
        path = store.save(str(tmp_path), 0, params, opt_state=opt)
        assert "opt_state.npz" in os.listdir(path)
        back = store.restore(str(tmp_path), opt, name="opt_state.npz")
        np.testing.assert_array_equal(back["v"], opt["v"])


class TestRestore:
    def test_latest_step(self, tmp_path, params):
        assert store.latest_step(str(tmp_path)) is None
        store.save(str(tmp_path), 2, params)
        store.save(str(tmp_path), 9, params)
        assert store.latest_step(str(tmp_path)) == 9

    def test_restore_from_latest_and_by_step(self, tmp_path, params):
        store.save(str(tmp_path), 1, params)
        newer = {k: v + 1 for k, v in params.items()}
        store.save(str(tmp_path), 2, newer)
        by_latest = store.restore(str(tmp_path), params)
        np.testing.assert_array_equal(by_latest["w"], newer["w"])
        by_step = store.restore(str(tmp_path), params, step=1)
        np.testing.assert_array_equal(by_step["w"], params["w"])

    def test_missing_checkpoint_raises(self, tmp_path, params):
        with pytest.raises(FileNotFoundError):
            store.restore(str(tmp_path), params)

    def test_numpy_template_preserves_dtype(self, tmp_path, params):
        """float64 numpy leaves come back as float64 numpy — the store
        must not route them through jax (x64 off would silently
        truncate to float32, breaking the fleet engine's bit-exact
        resume)."""
        f64 = {"dvth": np.array([1e-3 + 1e-12, 2e-3], dtype=np.float64)}
        store.save(str(tmp_path), 0, f64)
        back = store.restore(str(tmp_path), f64)
        assert isinstance(back["dvth"], np.ndarray)
        assert back["dvth"].dtype == np.float64
        np.testing.assert_array_equal(back["dvth"], f64["dvth"])

    def test_jax_template_restores_jax(self, tmp_path):
        tree = {"w": jnp.ones((2, 2), dtype=jnp.float32)}
        store.save(str(tmp_path), 0, tree)
        back = store.restore(str(tmp_path), tree)
        assert isinstance(back["w"], jnp.ndarray)
        assert back["w"].dtype == jnp.float32

    def test_bf16_round_trip(self, tmp_path):
        """npz has no bf16: save() stores the raw uint16 bits and
        restore() re-views them through the template dtype — exact."""
        tree = {"w": jnp.array([0.5, -1.25, 3.0, 1e-2],
                               dtype=jnp.bfloat16)}
        store.save(str(tmp_path), 0, tree)
        raw = np.load(os.path.join(str(tmp_path), "step_00000000",
                                   "params.npz"))
        assert raw["w"].dtype == np.uint16
        back = store.restore(str(tmp_path), tree)
        assert back["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(back["w"], np.float32),
                                      np.asarray(tree["w"], np.float32))

    def test_nested_tree_structure(self, tmp_path):
        tree = {"layer": {"w": np.ones((2,)), "b": np.zeros((2,))},
                "scale": np.array(2.0)}
        store.save(str(tmp_path), 0, tree)
        back = store.restore(str(tmp_path), tree)
        assert set(back) == {"layer", "scale"}
        np.testing.assert_array_equal(back["layer"]["w"],
                                      tree["layer"]["w"])


class TestCorruptionFallback:
    """save() records each npz's sha256 + byte length in meta.json;
    restore() verifies before loading. A corrupt *newest* checkpoint
    falls back to the latest earlier step that verifies (warning); an
    explicitly requested step stays strict."""

    def _corrupt(self, tmp_path, step, mode="truncate"):
        path = os.path.join(str(tmp_path), f"step_{step:08d}",
                            "params.npz")
        if mode == "truncate":
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(size // 2)
        else:       # bit flip, same length
            with open(path, "r+b") as f:
                f.seek(os.path.getsize(path) // 2)
                b = f.read(1)
                f.seek(-1, os.SEEK_CUR)
                f.write(bytes([b[0] ^ 0xFF]))

    def test_digests_recorded(self, tmp_path, params):
        store.save(str(tmp_path), 0, params,
                   opt_state={"m": np.zeros(3)})
        meta = store.meta(str(tmp_path))
        assert set(meta["digests"]) == {"params.npz", "opt_state.npz"}
        rec = meta["digests"]["params.npz"]
        assert len(rec["sha256"]) == 64 and rec["bytes"] > 0

    @pytest.mark.parametrize("mode", ["truncate", "bitflip"])
    def test_corrupt_newest_falls_back(self, tmp_path, params, mode):
        store.save(str(tmp_path), 1, params)
        newer = {k: v + 1 for k, v in params.items()}
        store.save(str(tmp_path), 2, newer)
        self._corrupt(tmp_path, 2, mode)
        with pytest.warns(RuntimeWarning, match="falling back to step 1"):
            back = store.restore(str(tmp_path), params)
        np.testing.assert_array_equal(back["w"], params["w"])

    def test_explicit_step_stays_strict(self, tmp_path, params):
        store.save(str(tmp_path), 1, params)
        store.save(str(tmp_path), 2, params)
        self._corrupt(tmp_path, 2)
        with pytest.raises(ValueError, match="failed verification"):
            store.restore(str(tmp_path), params, step=2)

    def test_all_corrupt_raises(self, tmp_path, params):
        store.save(str(tmp_path), 1, params)
        self._corrupt(tmp_path, 1)
        with pytest.raises(ValueError, match="no earlier step verifies"):
            store.restore(str(tmp_path), params)

    def test_pre_digest_checkpoint_still_loads(self, tmp_path, params):
        """Checkpoints written before digests existed (no record in
        meta.json) load without verification, as before."""
        import json
        store.save(str(tmp_path), 0, params)
        meta_path = os.path.join(str(tmp_path), "step_00000000",
                                 "meta.json")
        with open(meta_path) as f:
            meta = json.load(f)
        del meta["digests"]
        with open(meta_path, "w") as f:
            json.dump(meta, f)
        back = store.restore(str(tmp_path), params)
        np.testing.assert_array_equal(back["w"], params["w"])
