"""Telemetry subsystem tests: probe-layer invariants (windowed ring
aggregates == full-history recompute), the zero-cost-off purity pin
(telemetry-on runs are bit-identical to hub-less runs), and export
round-trips (JSONL event stream, Chrome trace, series npz/csv,
Prometheus text, live metrics endpoint)."""
from __future__ import annotations

import json
import math
import os
import urllib.request

import numpy as np
import pytest

from repro.sim import ExperimentConfig, run_experiment
from repro.telemetry import (
    NULL_HUB,
    EVENT_SCHEMA_VERSION,
    TelemetryHub,
    WindowedSeries,
    export_run,
    hist_bin_index,
    hist_bin_upper,
    prometheus_text,
    read_jsonl,
    series_to_csv,
    series_to_npz,
    start_metrics_server,
    write_chrome_trace,
    write_jsonl,
)

# Forces `temporal_adjustment` to actually defer wake-ups on a short
# run: permanently dirty hour (phase=pi/2 puts the diurnal intensity
# peak at t=0), full deferral. Used by every test that needs
# carbon-aware cause-attribution events in the stream.
CARBON_DEFER_OPTS = {
    "carbon_aware": True,
    "intensity_opts": (("phase", math.pi / 2),),
    "dirty_frac": 1.0,
    "defer_frac": 1.0,
}


def _telemetry_cfg(**kw) -> ExperimentConfig:
    base = dict(duration_s=12.0, rate_rps=60.0, seed=0,
                policy_opts=CARBON_DEFER_OPTS)
    base.update(kw)
    return ExperimentConfig(**base).with_telemetry()


# ---------------------------------------------------------------------- #
# probe layer
# ---------------------------------------------------------------------- #
class TestProbes:
    def test_counter_gauge(self):
        hub = TelemetryHub()
        hub.inc("a")
        hub.inc("a", 4)
        assert hub.counter("a").value == 5
        hub.set_gauge("g", 2.5)
        assert hub.gauge("g").value == 2.5

    def test_histogram_bin_edges_partition(self):
        """Every positive float lands in exactly one bin, and bins are
        ordered half-open intervals: upper(i-1) <= value <= upper(i)
        (the previous bin's upper edge is this bin's lower edge)."""
        for v in [1e-7, 1e-6, 1.0, 3.14, 999.0, 1e6, 5e8]:
            i = hist_bin_index(v)
            assert v <= hist_bin_upper(i) or math.isinf(hist_bin_upper(i))
            if 0 < i:
                assert v >= hist_bin_upper(i - 1)

    def _recompute(self, obs, window_s):
        """Full-history per-window aggregates, the slow obvious way."""
        wins: dict[int, list[float]] = {}
        for t, v in obs:
            wins.setdefault(int(t / window_s), []).append(v)
        return wins

    def _check_against_recompute(self, obs, window_s, max_windows=4096):
        s = WindowedSeries("x", window_s=window_s,
                           max_windows=max_windows)
        for t, v in obs:
            s.observe(t, v)
        full = self._recompute(obs, window_s)
        retained = {int(round(w["t_start"] / window_s)): w
                    for w in s.windows()}
        # ring keeps the most recent max_windows windows
        keep = sorted(full)[-max_windows:]
        assert sorted(retained) == keep
        for idx in keep:
            vals = full[idx]
            w = retained[idx]
            assert w["count"] == len(vals)
            assert w["total"] == pytest.approx(math.fsum(vals), rel=1e-9)
            assert w["min"] == min(vals)
            assert w["max"] == max(vals)
        # merged histogram equals recompute over retained values only
        kept_vals = [v for idx in keep for v in full[idx]]
        bins = [0] * len(s.merged_bins())
        for v in kept_vals:
            bins[hist_bin_index(v)] += 1
        assert s.merged_bins() == bins
        # quantiles: the returned bucket edge bounds at least the
        # q-th-ranked observation from above
        n = len(kept_vals)
        for q in (0.5, 0.9, 0.99):
            edge = s.quantile(q)
            below = sum(1 for v in kept_vals if v <= edge)
            assert below > q * (n - 1) - 1e-9

    def test_windowed_ring_equals_recompute_property(self):
        """Hypothesis when available; otherwise the same property over
        a seeded generative sweep (the container has no hypothesis
        wheel and deps cannot be installed)."""
        try:
            from hypothesis import given, settings
            from hypothesis import strategies as st

            @settings(max_examples=50, deadline=None)
            @given(st.lists(st.tuples(
                st.floats(min_value=0.0, max_value=500.0,
                          allow_nan=False, allow_infinity=False),
                st.floats(min_value=1e-6, max_value=1e5,
                          allow_nan=False, allow_infinity=False)),
                min_size=1, max_size=300),
                st.sampled_from([0.5, 1.0, 7.3]),
                st.sampled_from([4, 64, 4096]))
            def check(obs, window_s, max_windows):
                obs.sort()          # hub observations arrive in order
                self._check_against_recompute(obs, window_s,
                                              max_windows)

            check()
        except ImportError:
            rng = np.random.default_rng(7)
            for trial in range(40):
                n = int(rng.integers(1, 300))
                ts = np.sort(rng.uniform(0.0, 500.0, n))
                vs = 10.0 ** rng.uniform(-6, 5, n)
                window_s = float(rng.choice([0.5, 1.0, 7.3]))
                max_windows = int(rng.choice([4, 64, 4096]))
                self._check_against_recompute(
                    list(zip(ts.tolist(), vs.tolist())),
                    window_s, max_windows)

    def test_out_of_order_observation_policy(self):
        """Late samples fold into a still-retained window; samples
        older than the ring are counted as dropped, never mis-binned."""
        s = WindowedSeries("x", window_s=1.0, max_windows=2)
        for t in (0.5, 1.5, 2.5):
            s.observe(t, 1.0)
        s.observe(1.7, 5.0)          # window 1 still retained
        assert {int(w["t_start"]) for w in s.windows()} == {1, 2}
        w1 = next(w for w in s.windows() if int(w["t_start"]) == 1)
        assert w1["count"] == 2 and w1["max"] == 5.0
        before = s.dropped_observations
        s.observe(0.1, 9.0)          # window 0 evicted -> dropped
        assert s.dropped_observations == before + 1

    def test_timeline_ring_and_stride(self):
        hub = TelemetryHub(timeline_maxlen=3)
        tl = hub.timeline("t")
        for i in range(5):
            tl.record(float(i), (float(i),))
        assert len(tl) == 3
        assert [t for t, _ in tl.samples()] == [2.0, 3.0, 4.0]
        assert tl.dropped == 2

    def test_event_ring_bounded(self):
        hub = TelemetryHub(max_events=10)
        for i in range(25):
            hub.event("k", float(i), n=i)
        assert len(hub.events) == 10
        assert hub.events_dropped == 15
        assert hub.summary()["events_dropped"] == 15

    def test_null_hub_is_disabled(self):
        assert NULL_HUB.enabled is False
        NULL_HUB.inc("x")
        NULL_HUB.event("k", 0.0)
        NULL_HUB.timeline("t").record(0.0, (1.0,))
        assert NULL_HUB.summary() == {}

    def test_from_opts_filters_unknown(self):
        hub = TelemetryHub.from_opts(
            {"window_s": 2.0, "max_events": 9,
             "export_dir": "/tmp/x", "unknown_key": 1})
        assert hub.window_s == 2.0
        assert hub.events.maxlen == 9


# ---------------------------------------------------------------------- #
# zero-cost-off purity
# ---------------------------------------------------------------------- #
class TestPurity:
    def test_telemetry_on_is_bit_identical(self):
        """Recording is pure observation: the same config with and
        without telemetry must produce bit-identical scalars and
        per-machine detail (no extra RNG draws, no aging mutation)."""
        base = ExperimentConfig(duration_s=10.0, rate_rps=60.0, seed=3,
                                policy_opts=CARBON_DEFER_OPTS)
        off = run_experiment(base)
        on = run_experiment(base.with_telemetry())
        assert on.telemetry_summary is not None
        assert off.telemetry_summary is None
        d_off = off.to_dict()
        d_on = on.to_dict()
        for d in (d_off, d_on):
            d.pop("provenance", None)
            d.pop("telemetry_summary", None)
            # config hash legitimately differs (telemetry field is in
            # the fingerprint); everything numeric must not
            d.pop("config_hash", None)
        assert d_on == d_off

    def test_scalars_exclude_telemetry(self):
        """telemetry_summary holds wall-time gauges — it must never
        leak into scalars() or every drift check would be flaky."""
        res = run_experiment(_telemetry_cfg(duration_s=4.0))
        assert "telemetry_summary" not in res.scalars()


# ---------------------------------------------------------------------- #
# exports
# ---------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def telemetry_run():
    """One shared default-dims run with telemetry + carbon-aware
    deferral forced on: expensive, so module-scoped."""
    cfg = _telemetry_cfg()
    hub = TelemetryHub.from_opts(cfg.telemetry_options)
    result = run_experiment(cfg, telemetry=hub)
    return cfg, hub, result


class TestExports:
    def test_jsonl_roundtrip_and_required_events(self, telemetry_run,
                                                 tmp_path):
        cfg, hub, _ = telemetry_run
        path = tmp_path / "events.jsonl"
        write_jsonl(hub, str(path))
        meta, events = read_jsonl(str(path))
        assert meta["schema"] == EVENT_SCHEMA_VERSION
        assert meta["events"] == len(events)
        kinds = {e["kind"] for e in events}
        # per-core gate/wake spans with machine+core attribution
        assert {"gate", "wake"} <= kinds
        gate = next(e for e in events if e["kind"] == "gate")
        assert {"machine", "core", "cause"} <= gate.keys()
        # >=1 carbon-aware deferral cause record (acceptance criterion)
        defers = [e for e in events if e["kind"] == "carbon_deferral"]
        assert defers and all(e["cause"] == "carbon-aware-deferral"
                              and e["deferred"] >= 1 for e in defers)
        # routing decisions carry the justifying fleet snapshot
        route = next(e for e in events if e["kind"] == "route")
        assert isinstance(route["depths"], list)
        assert route["chosen"] < len(route["depths"])

    def test_jsonl_schema_guard(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text(json.dumps(
            {"kind": "telemetry_meta", "schema": 999}) + "\n")
        with pytest.raises(ValueError, match="schema"):
            read_jsonl(str(path))

    def test_chrome_trace_structure(self, telemetry_run, tmp_path):
        cfg, hub, _ = telemetry_run
        path = tmp_path / "trace.json"
        write_chrome_trace(hub, str(path), t_end=cfg.duration_s)
        with open(path) as f:
            doc = json.load(f)
        evs = doc["traceEvents"]
        assert evs, "trace must not be empty"
        complete = [e for e in evs if e["ph"] == "X"]
        instants = [e for e in evs if e["ph"] == "i"]
        assert complete and instants
        horizon_us = cfg.duration_s * 1e6
        for e in complete:
            assert e["dur"] >= 0
            assert 0 <= e["ts"] <= horizon_us
            assert e["ts"] + e["dur"] <= horizon_us * (1 + 1e-9)
            assert {"pid", "tid", "name"} <= e.keys()
        assert any(e["name"] == "gated" for e in complete)
        assert any(e["name"] == "carbon_deferral" for e in instants)

    def test_series_csv_and_npz(self, telemetry_run, tmp_path):
        _, hub, _ = telemetry_run
        csv_path = tmp_path / "series.csv"
        npz_path = tmp_path / "series.npz"
        series_to_csv(hub, str(csv_path))
        header = csv_path.read_text().splitlines()[0]
        assert header.split(",")[:3] == ["series", "t_start", "window_s"]
        series_to_npz(hub, str(npz_path))
        with np.load(str(npz_path)) as npz:
            freq_keys = [k for k in npz.files
                         if k.startswith("timeline/m")
                         and k.endswith("/freq/values")]
            assert freq_keys
            k = freq_keys[0]
            t = npz[k.replace("/values", "/t")]
            assert len(t) == len(npz[k])
            assert (np.diff(t) > 0).all()

    def test_export_run_writes_all_surfaces(self, telemetry_run,
                                            tmp_path):
        cfg, hub, _ = telemetry_run
        paths = export_run(hub, str(tmp_path / "out"),
                           t_end=cfg.duration_s)
        assert set(paths) == {"events_jsonl", "chrome_trace",
                              "series_csv", "series_npz", "prometheus"}
        for p in paths.values():
            assert os.path.getsize(p) > 0

    def test_prometheus_text_format(self, telemetry_run):
        _, hub, _ = telemetry_run
        text = prometheus_text(hub)
        lines = text.splitlines()
        assert any(l.startswith("# TYPE repro_") for l in lines)
        assert any("_total" in l for l in lines
                   if not l.startswith("#"))
        # every histogram ends with the mandatory +Inf bucket
        buckets = [l for l in lines if "_bucket{" in l]
        assert buckets
        hist_names = {l.split("_bucket{")[0] for l in buckets}
        for hn in hist_names:
            assert any(l.startswith(hn + '_bucket{le="+Inf"}')
                       for l in buckets)
        # exposition format: every sample line is `name{labels} value`
        for l in lines:
            if l and not l.startswith("#"):
                name, _, value = l.rpartition(" ")
                assert name
                float(value)

    def test_metrics_server_serves_snapshot(self, telemetry_run):
        _, hub, _ = telemetry_run
        server = start_metrics_server(lambda: prometheus_text(hub),
                                      port=0)
        try:
            url = f"http://127.0.0.1:{server.server_port}/metrics"
            with urllib.request.urlopen(url, timeout=5) as resp:
                body = resp.read().decode()
            assert "repro_" in body
        finally:
            server.shutdown()


# ---------------------------------------------------------------------- #
# result/runner integration
# ---------------------------------------------------------------------- #
class TestIntegration:
    def test_summary_in_result_and_roundtrip(self, telemetry_run):
        _, _, result = telemetry_run
        s = result.telemetry_summary
        assert s["events"] > 0
        assert "carbon_deferral" in s["event_kinds"]
        assert any(k.startswith("phase/") for k in s["gauges"])
        back = type(result).from_dict(result.to_dict())
        assert back.telemetry_summary == s

    def test_export_dir_opt(self, tmp_path):
        cfg = _telemetry_cfg(duration_s=4.0).with_telemetry(
            export_dir=str(tmp_path))
        res = run_experiment(cfg)
        export = res.telemetry_summary["export"]
        for p in export.values():
            assert os.path.exists(p)
        assert str(tmp_path) in next(iter(export.values()))

    def test_config_fingerprint_tracks_telemetry(self):
        a = ExperimentConfig()
        b = a.with_telemetry()
        assert a.fingerprint() != b.fingerprint()
        assert b.telemetry and not a.telemetry
