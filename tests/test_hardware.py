"""Heterogeneous hardware subsystem (`repro.hardware` + the fleet
threading through config / cluster / fleetsim / routing).

Pins the PR's contracts: the SKU catalog behind the shared seventh
registry axis, fleet-spec resolution (`uniform` -> None sentinel, spec
strings, explicit rows), the bit-exactness guarantee — a whole-fleet
reference-SKU run matches the uniform default scalar-for-scalar on both
engines, and fingerprints ignore the default fleet — the ragged
padded-mask fleet engine (numpy vs jax backend parity, event-engine
closeness, the mixed-Vdd refusal wording), the FleetView hardware
columns, and the acceptance scenario: `generation-aware` routing beats
`jsq` on fleet yearly carbon over a mixed 2-SKU fleet with p99 within
10%.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.carbon.base import (BASELINE_LIFESPAN_YEARS,
                               CPU_EMBODIED_KGCO2EQ)
from repro.carbon.intensity import ConstantIntensity, ShiftedIntensity
from repro.core import aging
from repro.hardware import (
    CPU_IMPACT_KGCO2EQ,
    HardwareSKU,
    REFERENCE_CPU_TDP_W,
    available_skus,
    canonical_fleet_name,
    canonical_sku_name,
    embodied_carbon,
    get_cpu_impact,
    get_sku,
    register_sku,
    resolve_fleet,
    sku_carbon_model,
)
from repro.hardware.registry import _REGISTRY
from repro.sim import Cluster, ExperimentConfig, FleetView
from repro.sim.routing import GenerationAwareRouter, get_router
from repro.sim.runner import run_experiment


def _has_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except ImportError:
        return False


# ---------------------------------------------------------------------- #
# SKU catalog + registry axis
# ---------------------------------------------------------------------- #
class TestSKURegistry:
    def test_builtins_registered(self):
        assert {"xeon-40c", "legacy-18c", "xeon-28c", "epyc-64c",
                "epyc-128c"} <= set(available_skus())

    def test_canonical_name(self):
        assert canonical_sku_name("Epyc_64c") == "epyc-64c"
        assert get_sku("XEON_40C").name == "xeon-40c"

    def test_fresh_instance_with_opts(self):
        a = get_sku("epyc-64c")
        b = get_sku("epyc-64c", num_cores=32)
        assert a is not b
        assert a.num_cores == 64 and b.num_cores == 32

    def test_unknown_sku_raises(self):
        with pytest.raises(KeyError, match="unknown hardware SKU"):
            get_sku("threadripper-9000")

    def test_decorator_rejects_non_sku(self):
        with pytest.raises(TypeError) as err:
            register_sku("bogus")(object)
        assert err.value.args[0] == (
            "@register_sku('bogus') expects a HardwareSKU subclass, "
            f"got {object!r}")

    def test_custom_sku_registers(self):
        @register_sku("test-4c")
        @dataclasses.dataclass(frozen=True)
        class Tiny(HardwareSKU):
            num_cores: int = 4
        try:
            assert get_sku("test-4c").num_cores == 4
        finally:
            _REGISTRY.pop("test-4c", None)

    def test_field_validation(self):
        with pytest.raises(ValueError, match="num_cores"):
            HardwareSKU(num_cores=0)
        with pytest.raises(ValueError, match="vdd must exceed vth"):
            HardwareSKU(vdd=0.4, vth=0.45)


class TestEmbodiedImpactTable:
    def test_reference_entry_matches_legacy_constant(self):
        assert get_cpu_impact("reference-xeon-40c") == CPU_EMBODIED_KGCO2EQ

    def test_unknown_model_lists_known(self):
        with pytest.raises(KeyError, match="epyc-9554-64c"):
            get_cpu_impact("pentium-ii")

    def test_amortization(self):
        total = CPU_IMPACT_KGCO2EQ["epyc-9554-64c"]
        full_life_h = BASELINE_LIFESPAN_YEARS * 24.0 * 365.0
        assert embodied_carbon("epyc-9554-64c", full_life_h) == \
            pytest.approx(total)
        assert embodied_carbon("epyc-9554-64c", full_life_h,
                               cpu_usage=0.5) == pytest.approx(total / 2)
        with pytest.raises(ValueError, match="duration_used_h"):
            embodied_carbon("epyc-9554-64c", -1.0)

    def test_reference_sku_is_legacy_fleet_machine(self):
        """The catalog reference reproduces every pre-heterogeneity
        fleet-wide constant — the anchor of the bit-exactness story."""
        sku = get_sku("xeon-40c")
        assert sku.num_cores == 40
        assert sku.embodied_kg == CPU_EMBODIED_KGCO2EQ
        assert sku.cpu_tdp_w == REFERENCE_CPU_TDP_W
        assert sku.power_scale == 1.0
        assert sku.base_life_years == BASELINE_LIFESPAN_YEARS
        # identity, not equality: the settler groups machines by params
        assert sku.aging_params() is aging.DEFAULT_PARAMS

    def test_non_reference_aging_params_resolve_k(self):
        p = get_sku("legacy-18c").aging_params()
        assert p.vth == 0.48 and p is not aging.DEFAULT_PARAMS
        assert p.K > 0.0


# ---------------------------------------------------------------------- #
# fleet-spec resolution + inventory
# ---------------------------------------------------------------------- #
class TestFleetResolution:
    def test_uniform_resolves_to_none_sentinel(self):
        assert resolve_fleet("uniform", None, 22) is None
        assert resolve_fleet("Uniform", {}, 3) is None

    def test_bare_sku_name_fills_fleet(self):
        inv = resolve_fleet("epyc-64c", None, 3)
        assert inv.sku_names == ("epyc-64c",) * 3
        assert inv.num_cores == (64, 64, 64)
        assert not inv.ragged

    def test_spec_string_with_rest(self):
        inv = resolve_fleet("xeon-40c:1+epyc-64c:rest", None, 4)
        assert inv.sku_names == ("xeon-40c",) + ("epyc-64c",) * 3
        assert inv.ragged
        assert inv.max_cores == 64
        assert inv.total_cores == 40 + 3 * 64

    def test_canonical_fleet_name_canonicalizes_spec_parts(self):
        assert canonical_fleet_name("Xeon_40c:1+EPYC_64C:rest") == \
            "xeon-40c:1+epyc-64c:rest"

    def test_mixed_rows_with_nested_opts(self):
        inv = resolve_fleet(
            "mixed", {"rows": (("xeon-40c", 1),
                               ("epyc-64c", 2, {"t0_s": 3600.0}))}, 3)
        assert inv.t0_s == (0.0, 3600.0, 3600.0)
        assert inv.generations == (3, 4, 4)

    def test_row_count_must_match_n_machines(self):
        with pytest.raises(ValueError, match="use count='rest' to fill"):
            resolve_fleet("xeon-40c:2", None, 22)
        with pytest.raises(ValueError, match="n_machines=1"):
            resolve_fleet("xeon-40c:2", None, 1)

    def test_single_rest_row_only(self):
        with pytest.raises(ValueError, match="only one fleet row"):
            resolve_fleet("xeon-40c:rest+epyc-64c:rest", None, 4)

    def test_bad_spec_segment(self):
        with pytest.raises(ValueError, match="bad fleet spec segment"):
            resolve_fleet("xeon-40c:", None, 3)

    def test_shared_dynamics_params_identity_on_reference(self):
        inv = resolve_fleet("xeon-40c", None, 3)
        assert inv.shared_dynamics_params() is aging.DEFAULT_PARAMS

    def test_shared_dynamics_allows_f_nominal_spread(self):
        inv = resolve_fleet("xeon-28c:1+epyc-64c:rest", None, 3)
        assert inv.shared_dynamics_params() is inv.aging_params[0]

    def test_shared_dynamics_rejects_mixed_vdd_vth(self):
        inv = resolve_fleet("legacy-18c:1+xeon-40c:rest", None, 3)
        with pytest.raises(ValueError) as err:
            inv.shared_dynamics_params()
        assert err.value.args[0] == (
            "fleet engine cannot vectorize fleets mixing NBTI operating "
            "points (Vdd/Vth); run it under engine='event'")

    def test_per_sku_carbon_models(self):
        inv = resolve_fleet("xeon-40c:1+epyc-64c:rest", None, 3)
        models = inv.carbon_models("linear-extension", None)
        assert len(models) == 3
        # same SKU shares one instance; different SKUs price differently
        assert models[1] is models[2] and models[0] is not models[1]
        ref = models[0].lifetime(0.02, 0.01)
        big = models[1].lifetime(0.02, 0.01)
        assert big.yearly_kgco2eq > ref.yearly_kgco2eq

    def test_intensity_for_phase_shift(self):
        inv = resolve_fleet(
            "mixed", {"rows": (("xeon-40c", 1),
                               ("xeon-40c", "rest", {"t0_s": 7200.0}))}, 3)
        base = ConstantIntensity()
        assert inv.intensity_for(0, base) is base
        shifted = inv.intensity_for(1, base)
        assert isinstance(shifted, ShiftedIntensity)

    def test_sku_carbon_model_embodied_override(self):
        sku = get_sku("epyc-64c")
        m = sku_carbon_model(sku, "linear-extension", {})
        ref = sku_carbon_model(get_sku("xeon-40c"), "linear-extension", {})
        est, est_ref = m.lifetime(0.02, 0.01), ref.lifetime(0.02, 0.01)
        assert est.yearly_kgco2eq / est_ref.yearly_kgco2eq == \
            pytest.approx(sku.embodied_kg / CPU_EMBODIED_KGCO2EQ)


# ---------------------------------------------------------------------- #
# config axis: fingerprint backward-compat
# ---------------------------------------------------------------------- #
class TestConfigFleetAxis:
    def test_with_fleet_and_canonicalization(self):
        cfg = ExperimentConfig(fleet="EPYC_64C")
        assert cfg.fleet == "epyc-64c"
        cfg2 = ExperimentConfig().with_fleet(
            "mixed", rows=(("xeon-40c", 1), ("epyc-64c", "rest")))
        assert cfg2.fleet == "mixed"
        assert dict(cfg2.fleet_opts)["rows"]

    def test_uniform_fleet_fingerprint_invariant(self):
        """Pre-hardware configs hash identically after the fleet axis
        landed — pinned so goldens survive the subsystem."""
        assert ExperimentConfig().fingerprint() == \
            ExperimentConfig(fleet="Uniform").fingerprint() == \
            "8335264983f5"

    def test_non_uniform_fleet_changes_fingerprint(self):
        cfg = ExperimentConfig()
        assert cfg.with_fleet("epyc-64c").fingerprint() != \
            cfg.fingerprint()
        assert cfg.with_fleet("xeon-40c:1+epyc-64c:rest").fingerprint() \
            != cfg.with_fleet("epyc-64c").fingerprint()


# ---------------------------------------------------------------------- #
# bit-exactness: whole-fleet reference SKU == uniform default
# ---------------------------------------------------------------------- #
class TestUniformBitExactness:
    CFG = ExperimentConfig(duration_s=6.0, rate_rps=30.0, seed=0,
                           n_prompt=1, n_token=2)

    @staticmethod
    def _assert_scalars_match(uni, ref_fleet):
        s0, s1 = uni.scalars(), ref_fleet.scalars()
        assert set(s0) - {"fleet"} <= set(s1)
        for k in set(s0) | set(s1):
            if k in ("fleet", "config_hash"):
                continue
            assert s0.get(k) == s1.get(k), k

    def test_event_engine(self):
        uni = run_experiment(self.CFG)
        ref = run_experiment(self.CFG.with_fleet("xeon-40c"))
        self._assert_scalars_match(uni, ref)
        assert uni.per_machine_degradation == ref.per_machine_degradation
        assert uni.per_machine_sku is None
        assert ref.per_machine_sku == ("xeon-40c",) * 3

    def test_fleet_engine(self):
        uni = run_experiment(
            self.CFG.with_engine("fleet", backend="numpy"))
        ref = run_experiment(
            self.CFG.with_fleet("xeon-40c").with_engine(
                "fleet", backend="numpy"))
        self._assert_scalars_match(uni, ref)


# ---------------------------------------------------------------------- #
# ragged fleet engine
# ---------------------------------------------------------------------- #
class TestRaggedFleetEngine:
    CFG = ExperimentConfig(duration_s=120.0, rate_rps=30.0, seed=2,
                           n_prompt=1, n_token=2,
                           fleet="xeon-28c:2+epyc-64c:1")

    def test_numpy_run_is_sane(self):
        r = run_experiment(self.CFG.with_engine("fleet", backend="numpy"))
        assert r.fleet == "xeon-28c:2+epyc-64c:1"
        assert r.per_machine_sku == ("xeon-28c", "xeon-28c", "epyc-64c")
        assert len(r.per_machine_degradation) == 3
        assert np.isfinite(r.fleet_yearly_total_kgco2eq)
        assert r.fleet_yearly_total_kgco2eq > 0.0
        assert 0.0 <= r.availability <= 1.0

    def test_deterministic(self):
        cfg = self.CFG.with_engine("fleet", backend="numpy")
        a, b = run_experiment(cfg), run_experiment(cfg)
        assert a.scalars() == b.scalars()

    def test_close_to_event_engine(self):
        """The vectorized surrogate tracks the per-task reference on a
        mixed fleet (same contract the uniform goldens pin)."""
        ev = run_experiment(self.CFG)
        fl = run_experiment(self.CFG.with_engine("fleet",
                                                 backend="numpy"))
        assert fl.fleet_yearly_total_kgco2eq == pytest.approx(
            ev.fleet_yearly_total_kgco2eq, rel=5e-3)

    @pytest.mark.skipif(not _has_jax(), reason="jax not installed")
    def test_numpy_vs_jax_backend_parity(self):
        r_np = run_experiment(self.CFG.with_engine("fleet",
                                                   backend="numpy"))
        r_jx = run_experiment(self.CFG.with_engine("fleet",
                                                   backend="jax"))
        assert r_jx.fleet_yearly_total_kgco2eq == pytest.approx(
            r_np.fleet_yearly_total_kgco2eq, rel=1e-3)
        assert r_jx.availability == pytest.approx(r_np.availability,
                                                  abs=1e-5)
        for a, b in zip(r_np.per_machine_degradation,
                        r_jx.per_machine_degradation):
            assert b == pytest.approx(a, rel=1e-2, abs=1e-6)

    def test_mixed_vdd_fleet_refused(self):
        cfg = self.CFG.with_fleet("legacy-18c:1+xeon-28c:rest")
        with pytest.raises(ValueError, match="mixing NBTI operating "
                           r"points \(Vdd/Vth\); run it under "
                           "engine='event'"):
            run_experiment(cfg.with_engine("fleet", backend="numpy"))

    def test_mixed_vdd_fleet_runs_under_event_engine(self):
        cfg = dataclasses.replace(self.CFG, duration_s=6.0,
                                  fleet="legacy-18c:1+xeon-28c:rest")
        r = run_experiment(cfg)
        assert r.per_machine_sku[0] == "legacy-18c"
        assert np.isfinite(r.fleet_yearly_total_kgco2eq)

    def test_faults_on_ragged_fleet(self):
        cfg = self.CFG.with_engine("fleet", backend="numpy")
        cfg = dataclasses.replace(
            cfg, duration_s=60.0).with_fault_model("machine-crash",
                                                   mttf_s=20.0,
                                                   reboot_s=10.0)
        r = run_experiment(cfg)
        assert r.machine_crashes > 0
        assert 0.0 < r.availability < 1.0


# ---------------------------------------------------------------------- #
# FleetView hardware columns
# ---------------------------------------------------------------------- #
class TestFleetViewHardwareColumns:
    def test_uniform_defaults(self):
        fleet = Cluster(ExperimentConfig(n_prompt=1, n_token=2)).fleet
        assert isinstance(fleet, FleetView)
        assert fleet.generations().tolist() == [0, 0, 0]
        assert fleet.core_counts().tolist() == [40, 40, 40]
        assert fleet.sku_names() == (None, None, None)
        assert fleet.pending_prompt_tokens == 0.0
        assert fleet.pending_decode_tokens == 0.0

    def test_mixed_fleet_columns(self):
        cfg = ExperimentConfig(n_prompt=1, n_token=2,
                               fleet="xeon-28c:1+epyc-64c:1+epyc-128c:1")
        fleet = Cluster(cfg).fleet
        assert fleet.generations().tolist() == [2, 4, 5]
        assert fleet.core_counts().tolist() == [28, 64, 128]
        assert fleet.sku_names() == ("xeon-28c", "epyc-64c", "epyc-128c")
        assert fleet.prompt_generations().tolist() == [2]
        assert fleet.token_generations().tolist() == [4, 5]


# ---------------------------------------------------------------------- #
# generation-aware router
# ---------------------------------------------------------------------- #
class _StubAging:
    def __init__(self, deg):
        self.mean_degradation = deg


class _StubFleet:
    """Minimal FleetView stand-in for unit-testing selection logic."""

    def __init__(self, prompt_loads=(), token_loads=(), prompt_gens=(),
                 token_gens=(), token_deg=(), pending_prompt=0.0,
                 pending_decode=0.0):
        self._pl = np.asarray(prompt_loads, dtype=float)
        self._tl = np.asarray(token_loads, dtype=float)
        self._pg = np.asarray(prompt_gens, dtype=np.int64)
        self._tg = np.asarray(token_gens, dtype=np.int64)
        self._deg = tuple(token_deg)
        self.pending_prompt_tokens = pending_prompt
        self.pending_decode_tokens = pending_decode

    def prompt_depths(self):
        return self._pl

    def token_loads(self):
        return self._tl

    def prompt_generations(self):
        return self._pg

    def token_generations(self):
        return self._tg

    def token_aging(self, indices=None):
        idx = range(len(self._deg)) if indices is None else indices
        return tuple(_StubAging(self._deg[int(i)]) for i in idx)


class TestGenerationAwareRouter:
    def test_registered(self):
        assert isinstance(get_router("Generation_Aware"),
                          GenerationAwareRouter)

    def test_opts_validated(self):
        with pytest.raises(ValueError, match="token_slack must be >= 0"):
            GenerationAwareRouter(token_slack=-1)
        with pytest.raises(ValueError, match="long_prompt_tokens"):
            GenerationAwareRouter(long_prompt_tokens=0.0)

    def test_prompt_prefers_newest_generation(self):
        fleet = _StubFleet(prompt_loads=[1, 1, 1], prompt_gens=[2, 4, 3])
        assert GenerationAwareRouter().select_prompt(fleet) == 1

    def test_token_prefers_oldest_then_most_aged(self):
        fleet = _StubFleet(token_loads=[3, 2, 3], token_gens=[1, 4, 1],
                           token_deg=[0.01, 0.0, 0.03])
        # slack 2 admits all; oldest gen = {0, 2}; most aged wins
        assert GenerationAwareRouter().select_token(fleet) == 2

    def test_long_prompt_widens_feasibility(self):
        fleet = _StubFleet(prompt_loads=[0, 2], prompt_gens=[2, 4],
                           pending_prompt=512.0)
        r = GenerationAwareRouter()
        # short prompt: only the idle old machine is feasible
        short = _StubFleet(prompt_loads=[0, 2], prompt_gens=[2, 4])
        assert r.select_prompt(short) == 0
        # long prompt: extra slack reaches the loaded new-gen machine
        assert r.select_prompt(fleet) == 1

    def test_long_decode_widens_feasibility(self):
        r = GenerationAwareRouter()
        short = _StubFleet(token_loads=[0, 3], token_gens=[4, 1],
                           token_deg=[0.0, 0.02])
        assert r.select_token(short) == 0
        long = _StubFleet(token_loads=[0, 3], token_gens=[4, 1],
                          token_deg=[0.0, 0.02], pending_decode=128.0)
        assert r.select_token(long) == 1

    def test_uniform_fleet_end_to_end(self):
        cfg = ExperimentConfig(duration_s=6.0, rate_rps=30.0, seed=0,
                               n_prompt=1, n_token=2,
                               router="generation-aware")
        r = run_experiment(cfg)
        assert r.completed > 0
        assert r.scalars() == run_experiment(cfg).scalars()

    def test_beats_jsq_on_mixed_fleet_carbon(self):
        """Acceptance pin: decode pinned to old silicon + prefill to the
        new SKU lowers fleet yearly embodied carbon vs jsq, within 10%
        of its p99 latency."""
        base = ExperimentConfig(duration_s=30.0, rate_rps=20.0, seed=1,
                                n_prompt=1, n_token=2,
                                fleet="xeon-28c:2+epyc-64c:1")
        jsq = run_experiment(base.with_router("jsq"))
        gen = run_experiment(base.with_router("generation-aware"))
        assert gen.fleet_yearly_total_kgco2eq < \
            jsq.fleet_yearly_total_kgco2eq
        assert gen.p99_latency_s <= 1.10 * jsq.p99_latency_s
