"""Unit + property tests for the NBTI aging model (paper §3.2).

Property tests guard `hypothesis` with pytest.importorskip so minimal
environments still run the unit tests.
"""
import math

import numpy as np
import pytest

from repro.core import aging
from repro.core.aging import DEFAULT_PARAMS, TEN_YEARS_S


class TestCalibration:
    def test_k_positive(self):
        assert DEFAULT_PARAMS.K > 0

    def test_ten_year_worst_case_is_30pct(self):
        """K is solved so 10y @ 54C, Y=1 costs exactly 30% frequency."""
        dvth = aging.dvth_after(DEFAULT_PARAMS, 54.0, 1.0, TEN_YEARS_S)
        f = aging.frequency_scalar(DEFAULT_PARAMS, 1.0, dvth)
        assert f == pytest.approx(0.70, abs=1e-9)

    def test_cooler_core_ages_slower(self):
        hot = aging.dvth_after(DEFAULT_PARAMS, 54.0, 1.0, 1e6)
        cool = aging.dvth_after(DEFAULT_PARAMS, 48.0, 1.0, 1e6)
        assert cool < hot

    def test_deep_idle_halts_aging(self):
        dvth0 = 0.01
        out = aging.dvth_after(DEFAULT_PARAMS, 48.0, 0.0, 1e7, dvth0)
        assert out == dvth0


class TestRecursion:
    def test_composition_equals_single_interval(self):
        """Splitting a constant-regime interval must not change the result
        (the recursion is exactly the closed form dVth = ADF * t^n)."""
        a = float(aging.adf(DEFAULT_PARAMS, 54.0, 1.0))
        one = aging.advance_dvth_scalar(DEFAULT_PARAMS, 0.0, a, 1000.0)
        split = aging.advance_dvth_scalar(DEFAULT_PARAMS, 0.0, a, 400.0)
        split = aging.advance_dvth_scalar(DEFAULT_PARAMS, split, a, 600.0)
        assert split == pytest.approx(one, rel=1e-12)

    def test_closed_form(self):
        a = float(aging.adf(DEFAULT_PARAMS, 51.08, 1.0))
        t = 12345.0
        got = aging.advance_dvth_scalar(DEFAULT_PARAMS, 0.0, a, t)
        assert got == pytest.approx(a * t ** DEFAULT_PARAMS.n, rel=1e-12)

    def test_vector_matches_scalar(self):
        rng = np.random.default_rng(0)
        dvth = rng.uniform(0, 0.05, 64)
        temps = rng.choice([48.0, 51.08, 54.0], 64)
        stress = rng.choice([0.0, 1.0], 64)
        tau = rng.uniform(0, 1e5, 64)
        a = aging.adf(DEFAULT_PARAMS, temps, stress)
        vec = aging.advance_dvth(DEFAULT_PARAMS, dvth, a, tau)
        for i in range(64):
            sc = aging.advance_dvth_scalar(DEFAULT_PARAMS, float(dvth[i]),
                                           float(a[i]), float(tau[i]))
            assert vec[i] == pytest.approx(sc, rel=1e-12)


class TestProperties:
    def test_monotone_nondecreasing(self):
        """Aging never reverses (no recovery modeled, like the paper)."""
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(
            dvth=st.floats(0.0, 0.1),
            tau=st.floats(0.0, 1e8),
            temp=st.sampled_from([48.0, 51.08, 54.0]),
        )
        @settings(max_examples=200, deadline=None)
        def run(dvth, tau, temp):
            a = float(aging.adf(DEFAULT_PARAMS, temp, 1.0))
            out = aging.advance_dvth_scalar(DEFAULT_PARAMS, dvth, a, tau)
            assert out >= dvth - 1e-15

        run()

    def test_interval_additivity(self):
        """advance(t1) ∘ advance(t2) == advance(t1 + t2) at constant ADF —
        the core invariant that makes lazy settlement correct."""
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(
            dvth=st.floats(0.0, 0.05),
            t1=st.floats(1.0, 1e6),
            t2=st.floats(1.0, 1e6),
        )
        @settings(max_examples=200, deadline=None)
        def run(dvth, t1, t2):
            a = float(aging.adf(DEFAULT_PARAMS, 54.0, 1.0))
            seq = aging.advance_dvth_scalar(DEFAULT_PARAMS, dvth, a, t1)
            seq = aging.advance_dvth_scalar(DEFAULT_PARAMS, seq, a, t2)
            direct = aging.advance_dvth_scalar(DEFAULT_PARAMS, dvth, a,
                                               t1 + t2)
            assert seq == pytest.approx(direct, rel=1e-9)

        run()

    def test_frequency_bounded(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(tau=st.floats(1.0, 1e8))
        @settings(max_examples=100, deadline=None)
        def run(tau):
            dvth = aging.dvth_after(DEFAULT_PARAMS, 54.0, 1.0, tau)
            f = aging.frequency_scalar(DEFAULT_PARAMS, 1.0, dvth)
            assert 0.0 < f <= 1.0

        run()

    def test_adf_increases_with_temperature(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(temp=st.floats(40.0, 80.0))
        @settings(max_examples=100, deadline=None)
        def run(temp):
            a1 = float(aging.adf(DEFAULT_PARAMS, temp, 1.0))
            a2 = float(aging.adf(DEFAULT_PARAMS, temp + 5.0, 1.0))
            assert a2 > a1

        run()


class TestSublinearity:
    def test_front_loaded_aging(self):
        """t^(1/6): the first year costs more than any later year."""
        y1 = aging.dvth_after(DEFAULT_PARAMS, 54.0, 1.0, aging.SECONDS_PER_YEAR)
        y2 = aging.dvth_after(DEFAULT_PARAMS, 54.0, 1.0, 2 * aging.SECONDS_PER_YEAR)
        assert y1 > (y2 - y1)
