"""Power subsystem tests: residency accounting, built-in models, the
fifth config axis, energy/operational wiring, and temporal consumers."""
import dataclasses
import math

import numpy as np
import pytest

from repro.carbon.intensity import ConstantIntensity, DiurnalIntensity
from repro.core import CoreManager, idling
from repro.core.temperature import CState
from repro.power import (NODE_COEFFS, FittedLinearModel, FlatTdpModel,
                         MinMaxLinearModel, PowerModel, ResidencyAccumulator,
                         StateResidency, TdpPerCoreModel,
                         available_power_models, canonical_power_model_name,
                         get_power_model)
from repro.sim import ExperimentConfig, run_experiment, run_policy_sweep

#: the historical implicit assumption: (2800 + 800) W at 0.6 utilization
FLAT_WATTS = 2160.0


def residency(num_cores=4, duration_s=10.0, busy=10.0, idle=20.0,
              gated=10.0, freq=None, window_s=10.0, windows=None):
    """Hand-rolled StateResidency; one full window by default."""
    if windows is None:
        windows = ((busy,), (idle,), (gated,))
    return StateResidency(
        num_cores=num_cores, duration_s=duration_s, busy_core_s=busy,
        idle_core_s=idle, gated_core_s=gated,
        freq_busy_core_s=busy if freq is None else freq,
        window_s=window_s, window_busy_s=windows[0],
        window_idle_s=windows[1], window_gated_s=windows[2])


class TestResidencyAccumulator:
    def test_conservation(self):
        acc = ResidencyAccumulator(8, window_s=1.0)
        acc.advance(0.7, 3, 2)
        acc.advance(2.4, 5, 0)
        acc.advance(7.13, 0, 8)
        r = acc.snapshot()
        total = r.busy_core_s + r.idle_core_s + r.gated_core_s
        assert total == pytest.approx(8 * 7.13, rel=1e-12)
        assert r.duration_s == 7.13
        # windows bank the same core-seconds as the scalar integrals
        assert sum(r.window_busy_s) == pytest.approx(r.busy_core_s, rel=1e-12)
        assert sum(r.window_idle_s) == pytest.approx(r.idle_core_s, rel=1e-12)
        assert sum(r.window_gated_s) == pytest.approx(r.gated_core_s,
                                                     rel=1e-12)

    def test_window_split_across_boundaries(self):
        acc = ResidencyAccumulator(2, window_s=1.0)
        acc.advance(2.5, 1, 0)          # spans windows 0, 1 and half of 2
        r = acc.snapshot()
        assert r.window_busy_s == (1.0, 1.0, 0.5)
        assert r.window_idle_s == (1.0, 1.0, 0.5)
        assert r.window_gated_s == (0.0, 0.0, 0.0)

    def test_same_window_fast_path(self):
        acc = ResidencyAccumulator(4, window_s=100.0)
        acc.advance(3.0, 1, 0)
        acc.advance(9.0, 2, 1)
        r = acc.snapshot()
        assert len(r.window_busy_s) == 1
        assert r.window_busy_s[0] == pytest.approx(1 * 3.0 + 2 * 6.0)
        assert r.window_gated_s[0] == pytest.approx(1 * 6.0)

    def test_non_advancing_time_is_noop(self):
        acc = ResidencyAccumulator(4)
        acc.advance(5.0, 2, 0)
        acc.advance(5.0, 4, 0)          # dt == 0
        acc.advance(4.0, 4, 0)          # dt < 0
        r = acc.snapshot()
        assert r.busy_core_s == 10.0 and r.duration_s == 5.0

    def test_bad_window_rejected(self):
        with pytest.raises(ValueError, match="window_s must be > 0"):
            ResidencyAccumulator(4, window_s=0.0)

    def test_frequency_weighting(self):
        acc = ResidencyAccumulator(2)
        acc.advance(10.0, 1, 0)
        acc.add_busy_frequency(0.9, 6.0)
        acc.add_busy_frequency(0.8, 4.0)
        r = acc.snapshot()
        assert r.mean_busy_frequency == pytest.approx(
            (0.9 * 6.0 + 0.8 * 4.0) / 10.0)

    def test_mean_frequency_defaults_to_nominal(self):
        assert residency(busy=0.0).mean_busy_frequency == 1.0

    def test_snapshot_dict_roundtrip(self):
        acc = ResidencyAccumulator(4, window_s=2.0)
        acc.advance(3.3, 2, 1)
        acc.add_busy_frequency(0.95, 3.3)
        r = acc.snapshot()
        assert StateResidency.from_dict(r.to_dict()) == r

    def test_iter_windows_fracs(self):
        acc = ResidencyAccumulator(4, window_s=1.0)
        acc.advance(2.0, 3, 1)
        rows = list(acc.snapshot().iter_windows())
        assert [t for t, *_ in rows] == [0.0, 1.0]
        for _, elapsed, bf, if_, gf in rows:
            assert elapsed == pytest.approx(1.0)
            assert bf + if_ + gf == pytest.approx(1.0)
            assert (bf, gf) == (pytest.approx(0.75), pytest.approx(0.25))


class TestManagerResidency:
    def make(self, n=8, policy="proposed", seed=0):
        return CoreManager(n, policy=policy,
                           rng=np.random.default_rng(seed))

    def test_lifecycle_residency(self):
        m = self.make(n=4)
        m.assign(0, 0.0)
        m.release(0, 3.0)
        r = m.residency(10.0)
        assert r.busy_core_s == pytest.approx(3.0)
        assert r.idle_core_s == pytest.approx(4 * 10.0 - 3.0)
        assert r.gated_core_s == 0.0
        # the released task banked its settled speed over its 3 s run
        assert 0.0 < r.mean_busy_frequency <= 1.6

    def test_gated_cores_counted(self):
        m = self.make(n=8)
        m.periodic(1.0)                  # no tasks -> idles most cores
        gated = int((m.c_state == CState.DEEP_IDLE).sum())
        assert gated > 0
        r = m.residency(11.0)
        assert r.gated_core_s == pytest.approx(gated * 10.0)

    def test_conservation_under_load(self):
        for policy in ("proposed", "linux", "least-aged"):
            m = self.make(n=8, policy=policy)
            rng = np.random.default_rng(42)
            t = 0.0
            for task in range(50):
                t += float(rng.exponential(0.3))
                m.assign(task, t)
                m.periodic(t)
                m.release(task, t + float(rng.exponential(0.5)))
            r = m.residency()
            total = r.busy_core_s + r.idle_core_s + r.gated_core_s
            assert total == pytest.approx(8 * r.duration_s, rel=1e-9)
            assert min(r.busy_core_s, r.idle_core_s) >= 0.0


class TestBuiltinModels:
    def test_registry_contents(self):
        assert available_power_models() == (
            "fitted-linear", "flat-tdp", "minmax-linear", "tdp-per-core")
        assert canonical_power_model_name("Flat_TDP") == "flat-tdp"
        with pytest.raises(KeyError, match="unknown power model 'nope'"):
            get_power_model("nope")

    def test_flat_tdp_golden(self):
        m = get_power_model("flat-tdp")
        assert isinstance(m, FlatTdpModel)
        # residency-blind: 2160 W whatever the core states say
        for fracs in ((1, 0, 0), (0, 1, 0), (0, 0, 1), (0.2, 0.3, 0.5)):
            assert m.machine_power_w(*fracs, 0.7, 40) == FLAT_WATTS
        r = residency(duration_s=100.0)
        assert m.energy_kwh(r) == FLAT_WATTS * 100.0 / 3.6e6
        assert m.marginal_task_w(1.0, 40) == 0.0

    def test_tdp_per_core_state_ordering(self):
        m = TdpPerCoreModel()
        busy = m.machine_power_w(1.0, 0.0, 0.0, 1.0, 40)
        idle = m.machine_power_w(0.0, 1.0, 0.0, 1.0, 40)
        gated = m.machine_power_w(0.0, 0.0, 1.0, 1.0, 40)
        assert busy > idle > gated
        assert gated == pytest.approx(250.0 + 1680.0)   # floors only
        assert m.marginal_task_w(1.0, 40) > 0.0

    def test_minmax_governors(self):
        perf = MinMaxLinearModel(governor="performance")
        save = MinMaxLinearModel(governor="powersave")
        onde = MinMaxLinearModel(governor="ondemand")
        args = (1.0, 0.0, 0.0, 1.0, 40)
        assert perf.machine_power_w(*args) == onde.machine_power_w(*args)
        assert save.machine_power_w(*args) < perf.machine_power_w(*args)
        # ondemand: aged-slow cores draw less; factor clamps to [0, 1]
        slow = onde.machine_power_w(1.0, 0.0, 0.0, 0.9, 40)
        assert slow < onde.machine_power_w(*args)
        assert (onde.machine_power_w(1.0, 0.0, 0.0, 1.7, 40)
                == onde.machine_power_w(*args))

    def test_minmax_validation(self):
        with pytest.raises(ValueError, match="unknown governor"):
            MinMaxLinearModel(governor="turbo")
        with pytest.raises(ValueError, match="must be >= min_core_w"):
            MinMaxLinearModel(min_core_w=10.0, max_core_w=5.0)
        with pytest.raises(ValueError, match="min_core_w must be >= 0"):
            MinMaxLinearModel(min_core_w=float("nan"))

    def test_fitted_linear(self):
        for node in NODE_COEFFS:
            m = FittedLinearModel(node=node)
            busy = m.machine_power_w(1.0, 0.0, 0.0, 1.0, 40)
            gated = m.machine_power_w(0.0, 0.0, 1.0, 1.0, 40)
            assert busy > gated > 0.0
        # the frequency term: aged-slow busy cores draw less
        m = FittedLinearModel()
        assert (m.machine_power_w(1.0, 0.0, 0.0, 0.9, 40)
                < m.machine_power_w(1.0, 0.0, 0.0, 1.0, 40))
        with pytest.raises(ValueError, match="unknown node"):
            FittedLinearModel(node="mystery-cpu")
        with pytest.raises(ValueError, match="coeffs missing keys"):
            FittedLinearModel(coeffs={"c0": 100.0})

    def test_energy_integrates_windows(self):
        m = TdpPerCoreModel()
        # two 10 s windows: all-busy then all-gated
        r = residency(num_cores=4, duration_s=20.0, busy=40.0, idle=0.0,
                      gated=40.0, window_s=10.0,
                      windows=((40.0, 0.0), (0.0, 0.0), (0.0, 40.0)))
        expected = (m.machine_power_w(1, 0, 0, 1.0, 4) * 10.0
                    + m.machine_power_w(0, 0, 1, 1.0, 4) * 10.0) / 3.6e6
        assert m.energy_kwh(r) == pytest.approx(expected, rel=1e-12)

    def test_operational_constant_matches_energy(self):
        m = MinMaxLinearModel()
        r = residency(num_cores=4, duration_s=20.0, busy=30.0, idle=40.0,
                      gated=10.0, window_s=10.0,
                      windows=((20.0, 10.0), (15.0, 25.0), (5.0, 5.0)))
        g = m.operational_g(r, ConstantIntensity(400.0))
        assert g == pytest.approx(m.energy_kwh(r) * 400.0, rel=1e-12)

    def test_operational_prices_when_not_just_how_much(self):
        """Identical energy costs more carbon when the busy window lands
        on the dirty half of the cycle — the temporal coupling."""
        m = TdpPerCoreModel()
        sig = DiurnalIntensity(mean=400.0, amplitude=0.8, period_s=80.0)
        busy_early = residency(
            num_cores=4, duration_s=20.0, busy=40.0, idle=40.0, gated=0.0,
            window_s=10.0, windows=((40.0, 0.0), (0.0, 40.0), (0.0, 0.0)))
        busy_late = residency(
            num_cores=4, duration_s=20.0, busy=40.0, idle=40.0, gated=0.0,
            window_s=10.0, windows=((0.0, 40.0), (40.0, 0.0), (0.0, 0.0)))
        # rising quarter-cycle: window midpoint 15 s is dirtier than 5 s
        assert (m.operational_g(busy_late, sig)
                > m.operational_g(busy_early, sig))
        assert m.energy_kwh(busy_early) == pytest.approx(
            m.energy_kwh(busy_late), rel=1e-12)


class TestFifthConfigAxis:
    def test_with_power_model(self):
        cfg = ExperimentConfig()
        assert cfg.power_model == "flat-tdp" and cfg.power_opts == ()
        cfg2 = cfg.with_power_model("MinMax_Linear", governor="performance",
                                    c6_core_w=0.2)
        assert cfg2.power_model == "minmax-linear"
        assert cfg2.power_opts == (("c6_core_w", 0.2),
                                   ("governor", "performance"))
        assert cfg2.power_options == {"c6_core_w": 0.2,
                                      "governor": "performance"}
        assert cfg.power_model == "flat-tdp"       # original untouched

    def test_unknown_model_fails_fast_at_run(self):
        # names canonicalize without validation (like every axis); the
        # runner resolves the model before simulating, so a typo costs
        # nothing
        cfg = ExperimentConfig(power_model="voltage-psychic", **SHORT)
        with pytest.raises(KeyError, match="unknown power model"):
            run_experiment(cfg)

    def test_power_window_resolution(self):
        cfg = ExperimentConfig(duration_s=120.0, idling_period_s=1.0)
        assert cfg.resolved_power_window_s == 1.0
        cfg = ExperimentConfig(duration_s=4096.0, idling_period_s=1.0)
        assert cfg.resolved_power_window_s == 4.0
        assert ExperimentConfig(
            power_window_s=7.5).resolved_power_window_s == 7.5
        with pytest.raises(ValueError, match="power_window_s"):
            ExperimentConfig(power_window_s=-1.0)

    def test_dict_opts_frozen_sorted(self):
        cfg = ExperimentConfig(power_opts={"utilization": 0.5,
                                           "gpu_tdp_w": 2000.0})
        assert cfg.power_opts == (("gpu_tdp_w", 2000.0),
                                  ("utilization", 0.5))


SHORT = dict(rate_rps=40.0, duration_s=15.0, seed=3)


@pytest.fixture(scope="module")
def flat_result():
    return run_experiment(ExperimentConfig(**SHORT))


class TestExperimentWiring:
    def test_flat_tdp_energy_golden(self, flat_result):
        r = flat_result
        n = len(r.per_machine_energy_kwh)
        expected = sum(FLAT_WATTS * res.duration_s / 3.6e6
                       for res in r.per_machine_residency)
        assert r.fleet_energy_kwh == pytest.approx(expected, rel=1e-12)
        assert r.mean_machine_power_w == pytest.approx(FLAT_WATTS,
                                                       rel=1e-12)
        assert n == ExperimentConfig(**SHORT).n_machines
        assert all(e > 0.0 for e in r.per_machine_energy_kwh)

    def test_residency_invariants_per_machine(self, flat_result):
        for res in flat_result.per_machine_residency:
            total = res.busy_core_s + res.idle_core_s + res.gated_core_s
            assert total == pytest.approx(res.num_cores * res.duration_s,
                                          rel=1e-9)
            assert 0.0 < res.mean_busy_frequency <= 1.6

    def test_operational_fields(self, flat_result):
        r = flat_result
        assert r.fleet_operational_kgco2eq > 0.0
        assert r.fleet_yearly_operational_kgco2eq > 0.0
        assert r.fleet_yearly_total_kgco2eq == pytest.approx(
            r.fleet_yearly_kgco2eq + r.fleet_yearly_operational_kgco2eq,
            rel=1e-12)

    def test_repricing(self, flat_result):
        r = flat_result
        assert r.fleet_energy_under() == r.fleet_energy_kwh
        assert r.fleet_energy_under("flat-tdp") == r.fleet_energy_kwh
        repriced = r.fleet_energy_under("minmax-linear")
        assert repriced > 0.0 and repriced != r.fleet_energy_kwh
        assert r.fleet_energy_under(MinMaxLinearModel()) == pytest.approx(
            repriced, rel=1e-12)

    def test_repricing_needs_residency(self, flat_result):
        stripped = dataclasses.replace(flat_result,
                                       per_machine_residency=None)
        with pytest.raises(ValueError, match="per_machine_residency"):
            stripped.fleet_energy_under("minmax-linear")

    def test_json_roundtrip_and_scalars(self, flat_result):
        r = flat_result
        r2 = type(r).from_json(r.to_json())
        assert r2 == r
        assert r2.fleet_energy_under() == r.fleet_energy_kwh
        s = r.scalars()
        for key in ("power_model", "fleet_energy_kwh",
                    "mean_machine_power_w",
                    "fleet_yearly_operational_kgco2eq",
                    "fleet_yearly_total_kgco2eq"):
            assert key in s

    def test_power_opts_flow_through(self):
        r = run_experiment(ExperimentConfig(
            power_opts={"utilization": 0.5}, **SHORT))
        assert r.mean_machine_power_w == pytest.approx(3600.0 * 0.5,
                                                       rel=1e-12)
        assert r.power_opts == (("utilization", 0.5),)

    def test_sweep_power_axis(self):
        grid = run_policy_sweep(
            ExperimentConfig(**SHORT), policies=("proposed",),
            power_models=("flat-tdp", "minmax-linear"))
        assert set(grid.keys()) == {("proposed", "flat-tdp"),
                                    ("proposed", "minmax-linear")}
        flat = grid[("proposed", "flat-tdp")]
        mm = grid[("proposed", "minmax-linear")]
        assert flat.power_model == "flat-tdp"
        assert mm.power_model == "minmax-linear"
        # same simulation, different pricing
        assert flat.per_machine_degradation == mm.per_machine_degradation
        assert flat.fleet_energy_kwh != mm.fleet_energy_kwh


class TestTemporalAdjustment:
    def test_zero_and_clean_passthrough(self):
        assert idling.temporal_adjustment(0, 900.0, 400.0, 0) == 0
        assert idling.temporal_adjustment(5, 400.0, 400.0, 0) == 5
        assert idling.temporal_adjustment(-5, 410.0, 400.0, 0) == -5

    def test_dirty_gating_amplified(self):
        assert idling.temporal_adjustment(3, 900.0, 400.0, 0,
                                          gate_gain=2.0) == 6

    def test_dirty_wake_deferred(self):
        assert idling.temporal_adjustment(-4, 900.0, 400.0, 0,
                                          defer_frac=0.5) == -2
        assert idling.temporal_adjustment(-4, 900.0, 400.0, 0,
                                          defer_frac=1.0) == 0

    def test_latency_guard_overrides_deferral(self):
        assert idling.temporal_adjustment(-4, 900.0, 400.0, 3,
                                          guard_tasks=2) == -4


class TestCarbonAwarePolicy:
    def test_option_validation(self):
        from repro.core.policies.proposed import ProposedPolicy
        with pytest.raises(ValueError, match="defer_frac"):
            ProposedPolicy(defer_frac=1.5)
        with pytest.raises(ValueError, match="gate_gain"):
            ProposedPolicy(gate_gain=0.5)
        with pytest.raises(ValueError, match="guard_tasks"):
            ProposedPolicy(guard_tasks=-1)
        with pytest.raises(ValueError, match="dirty_frac"):
            ProposedPolicy(dirty_frac=0.0)

    def test_never_dirty_is_bitexact(self):
        """carbon_aware under a constant signal (never above dirty_frac
        x mean) must reproduce the plain proposed run bitwise."""
        base = run_experiment(ExperimentConfig(**SHORT))
        aware = run_experiment(ExperimentConfig(
            policy_opts={"carbon_aware": True, "intensity": "constant"},
            **SHORT))
        assert aware.per_machine_degradation == base.per_machine_degradation
        assert aware.completed == base.completed
        assert aware.p99_latency_s == base.p99_latency_s


class TestFootprintGreedyRouter:
    def test_flat_tdp_zero_grid_degenerates_to_carbon_greedy(self):
        """With a residency-blind power model and a zero-carbon grid the
        operational term vanishes, so footprint-greedy must make exactly
        carbon-greedy's placements."""
        cg = run_experiment(ExperimentConfig(router="carbon-greedy",
                                             **SHORT))
        fg = run_experiment(ExperimentConfig(
            router="footprint-greedy",
            router_opts={"power_model": "flat-tdp",
                         "intensity": ConstantIntensity(0.0)},
            **SHORT))
        assert fg.per_machine_degradation == cg.per_machine_degradation
        assert fg.completed == cg.completed

    def test_option_validation(self):
        from repro.sim.routing import FootprintGreedyRouter
        with pytest.raises(ValueError, match="embodied_horizon_years"):
            FootprintGreedyRouter(embodied_horizon_years=0.0)
        with pytest.raises(ValueError, match="tau_s"):
            FootprintGreedyRouter(tau_s=-1.0)


#: diurnal grid with a short period so a 60 s run sees dirty and clean
#: phases; shared by the policy, the carbon model, and the router.
IOPTS = (("amplitude", 0.8), ("period_s", 40.0), ("phase", 0.0))


class TestAcceptanceScenario:
    """ISSUE 6 acceptance: under a diurnal intensity, carbon-aware
    idling + footprint-greedy routing reduce total (operational +
    embodied) gCO2eq vs the embodied-only baseline, with <10% p99
    latency impact."""

    @pytest.fixture(scope="class")
    def pair(self):
        common = dict(
            policy="proposed",
            carbon_model="operational-embodied",
            carbon_opts={"intensity": "diurnal", "intensity_opts": IOPTS},
            power_model="minmax-linear",
            rate_rps=50.0, duration_s=60.0, seed=7)
        baseline = run_experiment(ExperimentConfig(
            router="carbon-greedy", **common))
        treatment = run_experiment(ExperimentConfig(
            policy_opts={"carbon_aware": True, "intensity": "diurnal",
                         "intensity_opts": IOPTS},
            router="footprint-greedy",
            router_opts={"carbon_model": "operational-embodied",
                         "carbon_opts": (("intensity", "diurnal"),
                                         ("intensity_opts", IOPTS))},
            **common))
        return baseline, treatment

    def test_total_carbon_reduced(self, pair):
        baseline, treatment = pair
        assert (treatment.fleet_yearly_total_kgco2eq
                < baseline.fleet_yearly_total_kgco2eq)

    def test_p99_latency_within_ten_percent(self, pair):
        baseline, treatment = pair
        assert treatment.p99_latency_s <= 1.10 * baseline.p99_latency_s

    def test_service_preserved(self, pair):
        baseline, treatment = pair
        assert treatment.completed >= 0.99 * baseline.completed
