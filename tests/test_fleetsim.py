"""Fleet engine (`repro.sim.fleetsim`): parity vs the event-loop
reference, backend agreement, checkpoint/resume exactness, config
plumbing, and a scale smoke.

The fleet engine is a mean-field surrogate, so event-engine parity is
pinned with tolerances (calibrated against the measured deltas on the
default 22-machine config), NOT bit-exactness — that property belongs
to the event engine alone (tests/test_perf_bitexact.py).
"""
from __future__ import annotations

import os
import shutil

import numpy as np
import pytest

from repro.sim import ExperimentConfig
from repro.sim.fleetsim import FleetEngine, _resolve_backend
from repro.sim.runner import run_experiment


def _has_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except ImportError:
        return False


@pytest.fixture(scope="module")
def event_result():
    return run_experiment(ExperimentConfig())


@pytest.fixture(scope="module")
def fleet_result():
    return run_experiment(
        ExperimentConfig().with_engine("fleet", backend="numpy"))


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-12)


class TestEventParity:
    """Fleet surrogate vs event reference on the default config
    (22 machines x 120 s @ 60 rps). Tolerances bracket the measured
    deltas with headroom, tight enough that a physics regression in
    either engine trips them."""

    def test_engine_labels(self, event_result, fleet_result):
        assert event_result.engine == "event"
        assert fleet_result.engine == "fleet"
        # the label must NOT leak into the diffable scalar row
        assert "engine" not in event_result.scalars()
        assert "engine" not in fleet_result.scalars()

    def test_throughput(self, event_result, fleet_result):
        assert _rel(fleet_result.completed, event_result.completed) < 0.10

    def test_latency(self, event_result, fleet_result):
        assert _rel(fleet_result.mean_latency_s,
                    event_result.mean_latency_s) < 0.10
        assert _rel(fleet_result.p99_latency_s,
                    event_result.p99_latency_s) < 0.10

    def test_aging(self, event_result, fleet_result):
        assert _rel(fleet_result.mean_degradation_percentiles[50],
                    event_result.mean_degradation_percentiles[50]) < 0.15
        assert _rel(fleet_result.freq_cv_percentiles[50],
                    event_result.freq_cv_percentiles[50]) < 0.10

    def test_carbon_and_energy(self, event_result, fleet_result):
        assert _rel(fleet_result.fleet_yearly_kgco2eq,
                    event_result.fleet_yearly_kgco2eq) < 0.15
        assert _rel(fleet_result.fleet_energy_kwh,
                    event_result.fleet_energy_kwh) < 0.05

    def test_shapes_match_fleet(self, event_result, fleet_result):
        n = ExperimentConfig().n_machines
        for res in (event_result, fleet_result):
            assert len(res.per_machine_degradation) == n
            assert len(res.per_machine_residency) == n
            assert np.isfinite(res.per_machine_degradation).all()


class TestBurstyScenarioParity:
    """Cross-validation beyond the Poisson default (ROADMAP 1e): the
    fluid surrogate must track the event reference through bursty
    arrival processes too — MMPP's on/off rate switching and the
    flash-crowd spike both stress the queue-drain approximation in ways
    a constant-rate trace never does. Tolerances bracket the measured
    deltas (mmpp: completed 2.9%, deg50 5.4%; flashcrowd: completed
    3.6%, deg50 7.7%) with headroom."""

    @pytest.fixture(scope="class", params=["conversation-mmpp",
                                           "conversation-flashcrowd"])
    def pair(self, request):
        cfg = ExperimentConfig(scenario=request.param)
        ev = run_experiment(cfg)
        fl = run_experiment(cfg.with_engine("fleet", backend="numpy"))
        return ev, fl

    def test_throughput(self, pair):
        ev, fl = pair
        assert _rel(fl.completed, ev.completed) < 0.08

    def test_latency(self, pair):
        ev, fl = pair
        assert _rel(fl.mean_latency_s, ev.mean_latency_s) < 0.06
        assert _rel(fl.p99_latency_s, ev.p99_latency_s) < 0.02

    def test_aging(self, pair):
        ev, fl = pair
        assert _rel(fl.mean_degradation_percentiles[50],
                    ev.mean_degradation_percentiles[50]) < 0.15
        assert _rel(fl.freq_cv_percentiles[50],
                    ev.freq_cv_percentiles[50]) < 0.05

    def test_carbon_and_energy(self, pair):
        ev, fl = pair
        assert _rel(fl.fleet_yearly_kgco2eq,
                    ev.fleet_yearly_kgco2eq) < 0.10
        assert _rel(fl.fleet_energy_kwh, ev.fleet_energy_kwh) < 0.02


@pytest.mark.skipif(not _has_jax(), reason="jax not installed")
class TestBackendAgreement:
    """numpy (f64 reference) vs jax (f32 lax.scan) run the same
    functional step; agreement is close but not bit-exact."""

    @pytest.fixture(scope="class")
    def pair(self):
        cfg = ExperimentConfig(duration_s=60.0)
        res_np = run_experiment(cfg.with_engine("fleet", backend="numpy"))
        res_jx = run_experiment(cfg.with_engine("fleet", backend="jax"))
        return res_np, res_jx

    def test_throughput_and_latency(self, pair):
        res_np, res_jx = pair
        assert _rel(res_jx.completed, res_np.completed) < 0.01
        assert _rel(res_jx.mean_latency_s, res_np.mean_latency_s) < 0.01

    def test_aging(self, pair):
        res_np, res_jx = pair
        assert _rel(res_jx.mean_degradation_percentiles[50],
                    res_np.mean_degradation_percentiles[50]) < 0.02
        assert _rel(res_jx.fleet_yearly_kgco2eq,
                    res_np.fleet_yearly_kgco2eq) < 0.05


class TestCheckpointResume:
    def _cfg(self, ckpt_dir: str) -> ExperimentConfig:
        return ExperimentConfig(duration_s=60.0).with_engine(
            "fleet", backend="numpy", checkpoint_dir=ckpt_dir,
            checkpoint_every_s=20.0)

    def test_resume_is_exact(self, tmp_path):
        """Kill-and-resume reproduces the uninterrupted run's scalar
        row bit-for-bit (numpy backend contract)."""
        ckpt = str(tmp_path / "ckpt")
        cfg = self._cfg(ckpt)
        uninterrupted = run_experiment(cfg)
        # Simulate the interruption: drop the checkpoints past t=20 s,
        # so the rerun resumes from the earliest retained one.
        steps = sorted(d for d in os.listdir(ckpt)
                       if d.startswith("step_"))
        assert len(steps) >= 2, "expected several periodic checkpoints"
        for d in steps[1:]:
            shutil.rmtree(os.path.join(ckpt, d))
        resumed = run_experiment(cfg)
        a, b = uninterrupted.scalars(), resumed.scalars()
        assert a.keys() == b.keys()
        for k in a:
            assert a[k] == b[k] or (a[k] != a[k] and b[k] != b[k]), k

    def test_resume_refuses_config_mismatch(self, tmp_path):
        ckpt = str(tmp_path / "ckpt")
        run_experiment(self._cfg(ckpt))
        other = ExperimentConfig(duration_s=60.0, seed=7).with_engine(
            "fleet", backend="numpy", checkpoint_dir=ckpt,
            checkpoint_every_s=20.0)
        with pytest.raises(ValueError, match="refusing to resume"):
            run_experiment(other)


class TestConfigPlumbing:
    def test_default_fingerprint_unchanged(self):
        """Adding the engine axis must not re-hash existing configs:
        the default (event, no opts) is omitted from the payload, so
        every pre-engine fingerprint — including the pinned drift-gate
        golden — survives."""
        assert ExperimentConfig().fingerprint() == \
            ExperimentConfig(engine="event").fingerprint()

    def test_fleet_fingerprint_differs(self):
        base = ExperimentConfig()
        assert base.with_engine("fleet").fingerprint() != base.fingerprint()
        assert base.with_engine(
            "fleet", backend="numpy").fingerprint() != \
            base.with_engine("fleet").fingerprint()

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError, match="unknown engine"):
            ExperimentConfig(engine="warp")

    def test_unknown_engine_opts_rejected(self):
        cfg = ExperimentConfig().with_engine("fleet", warp_factor=9)
        with pytest.raises(ValueError, match="unknown engine_opts"):
            FleetEngine(cfg)

    def test_backend_resolution(self):
        assert _resolve_backend("numpy") == "numpy"
        expect = "jax" if _has_jax() else "numpy"
        assert _resolve_backend("auto") == expect
        with pytest.raises(ValueError, match="unknown fleet backend"):
            _resolve_backend("fortran")


class TestScaleSmoke:
    def test_200_machines(self):
        """A 200-machine fleet through the vectorized engine at test
        scale (the >= 1 h headline lives in BENCH_sim.json)."""
        cfg = ExperimentConfig(
            n_prompt=45, n_token=155, rate_rps=545.0,
            duration_s=60.0).with_engine("fleet", backend="numpy")
        res = run_experiment(cfg)
        assert res.engine == "fleet"
        assert res.completed > 0
        assert len(res.per_machine_degradation) == 200
        assert np.isfinite(res.fleet_yearly_total_kgco2eq)
