"""Tests for Algorithm 1 (Task-to-Core Mapping), Algorithm 2 (Selective
Core Idling), the reaction function, process variation, and carbon model.

Property tests guard `hypothesis` with pytest.importorskip so minimal
environments still run the unit tests.
"""
import math

import numpy as np
import pytest

from repro.core import carbon, idling, mapping, variation
from repro.core.idling import reaction_function


class TestReactionFunction:
    def test_zero(self):
        assert reaction_function(0.0) == 0.0

    def test_asymmetry_fast_oversub_slow_underutil(self):
        """Paper: react slower to underutilization, faster to oversub."""
        for e in (0.1, 0.3, 0.5):
            assert abs(reaction_function(-e)) > abs(reaction_function(e))

    def test_bounded(self):
        assert reaction_function(1.0) == pytest.approx(math.tan(0.785), rel=1e-9)
        assert reaction_function(-1.0) == pytest.approx(math.atan(-1.55), rel=1e-9)
        assert abs(reaction_function(1.0)) <= 1.0 + 1e-6
        assert abs(reaction_function(-1.0)) <= 1.0

    def test_sign_preserving_monotone(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(e=st.floats(-1.0, 1.0))
        @settings(max_examples=200, deadline=None)
        def run(e):
            f = reaction_function(e)
            assert math.copysign(1, f) == math.copysign(1, e) or f == 0.0
            assert reaction_function(min(e + 0.01, 1.0)) >= f - 1e-12

        run()


class TestCoreCorrection:
    def test_all_idle_cores_spare(self):
        # 32 cores, all active, 0 tasks -> strong positive correction.
        c = idling.core_correction(32, 32, 0, 0)
        assert c == int(32 * math.tan(0.785))

    def test_balanced(self):
        assert idling.core_correction(32, 16, 16, 0) == 0

    def test_oversubscription_wakes_cores(self):
        # 8 active of 32, 16 tasks running/waiting -> negative correction.
        c = idling.core_correction(32, 8, 8, 8)
        assert c < 0

    def test_task_cap_at_total(self):
        c = idling.core_correction(16, 16, 16, 1000)
        assert c == 0  # tasks capped at N, e = 0

    def test_correction_bounds(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(n=st.integers(2, 128), active=st.integers(0, 128),
               tasks=st.integers(0, 256), oversub=st.integers(0, 64))
        @settings(max_examples=300, deadline=None)
        def run(n, active, tasks, oversub):
            active = min(active, n)
            tasks = min(tasks, active)
            c = idling.core_correction(n, active, tasks, oversub)
            assert -n <= c <= n

        run()


class TestApplyCorrection:
    def _state(self, n=16, n_active=12, n_tasks=4, seed=0):
        rng = np.random.default_rng(seed)
        active = np.zeros(n, bool)
        active[:n_active] = True
        tasks = np.zeros(n, bool)
        tasks[rng.choice(n_active, n_tasks, replace=False)] = True
        age = rng.uniform(0, 1, n)
        return active, tasks, age

    def test_never_idles_busy_core(self):
        active, tasks, age = self._state()
        to_idle, _ = idling.apply_correction(8, active, tasks, age)
        assert not tasks[to_idle].any()
        assert active[to_idle].all()

    def test_idles_most_aged_first(self):
        active, tasks, age = self._state()
        to_idle, _ = idling.apply_correction(3, active, tasks, age)
        cand = np.flatnonzero(active & ~tasks)
        expect = cand[np.argsort(-age[cand])][:3]
        np.testing.assert_array_equal(to_idle, expect)

    def test_wakes_least_aged_first(self):
        active, tasks, age = self._state()
        _, to_wake = idling.apply_correction(-2, active, tasks, age)
        cand = np.flatnonzero(~active)
        expect = cand[np.argsort(age[cand])][:2]
        np.testing.assert_array_equal(to_wake, expect)

    def test_correction_capped_by_candidates(self):
        active, tasks, age = self._state(n=8, n_active=8, n_tasks=6)
        to_idle, _ = idling.apply_correction(5, active, tasks, age)
        assert len(to_idle) == 2  # only 2 unassigned active cores exist


class TestMapping:
    def test_selects_max_idle_score(self):
        hist = np.zeros((4, mapping.IDLE_HISTORY_LEN))
        hist[2, :] = 5.0
        hist[1, :] = 1.0
        active = np.ones(4, bool)
        tasks = np.zeros(4, bool)
        assert mapping.select_core(active, tasks, hist) == 2

    def test_skips_assigned_and_idle(self):
        hist = np.zeros((4, mapping.IDLE_HISTORY_LEN))
        hist[2, :] = 5.0
        hist[3, :] = 4.0
        active = np.array([True, True, True, False])
        tasks = np.array([False, False, True, False])
        # core 2 busy, core 3 deep-idle -> best remaining is 0 or 1 (ties -> 0)
        assert mapping.select_core(active, tasks, hist) in (0, 1)

    def test_returns_minus_one_when_full(self):
        hist = np.zeros((2, mapping.IDLE_HISTORY_LEN))
        assert mapping.select_core(np.ones(2, bool), np.ones(2, bool), hist) == -1

    def test_ring_buffer(self):
        hist = np.zeros((1, mapping.IDLE_HISTORY_LEN))
        pos = np.zeros(1, np.int64)
        for k in range(12):
            mapping.record_idle_end(hist, pos, 0, float(k))
        # last 8 entries survive: 4..11
        assert set(hist[0]) == set(float(k) for k in range(4, 12))

    def test_selected_core_is_valid(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(n=st.integers(1, 64), seed=st.integers(0, 1000))
        @settings(max_examples=100, deadline=None)
        def run(n, seed):
            rng = np.random.default_rng(seed)
            active = rng.random(n) < 0.7
            tasks = (rng.random(n) < 0.4) & active
            hist = rng.uniform(0, 10, (n, mapping.IDLE_HISTORY_LEN))
            core = mapping.select_core(active, tasks, hist)
            if core == -1:
                assert not (active & ~tasks).any()
            else:
                assert active[core] and not tasks[core]
                cand = active & ~tasks
                assert hist[core].sum() == pytest.approx(
                    hist[cand].sum(axis=1).max())

        run()


class TestVariation:
    def test_no_variation_gives_nominal(self):
        p = variation.VariationParams(sigma_frac=0.0)
        f0 = variation.sample_initial_frequencies(
            p, 16, np.random.default_rng(0))
        np.testing.assert_allclose(f0, p.f_nominal, rtol=1e-9)

    def test_deterministic_given_seed(self):
        p = variation.VariationParams()
        a = variation.sample_initial_frequencies(p, 40, np.random.default_rng(7))
        b = variation.sample_initial_frequencies(p, 40, np.random.default_rng(7))
        np.testing.assert_array_equal(a, b)

    def test_spread_reasonable(self):
        p = variation.VariationParams()
        rng = np.random.default_rng(3)
        f0 = np.concatenate([
            variation.sample_initial_frequencies(p, 80, rng) for _ in range(20)
        ])
        assert 0.6 < f0.min() and f0.max() < 1.6
        assert 0.005 < f0.std() < 0.2

    def test_partition_covers_all_cores(self):
        parts = variation.core_cell_partition(10, 40)
        assert len(parts) == 40
        assert all(len(c) >= 1 for c in parts)
        assert sorted(np.concatenate(parts)) == list(range(100))

    def test_partition_more_cores_than_cells(self):
        parts = variation.core_cell_partition(4, 40)
        assert len(parts) == 40

    def test_correlation_decay(self):
        """Nearby cells correlate more than distant cells."""
        p = variation.VariationParams()
        rng = np.random.default_rng(11)
        grids = np.stack([variation.sample_grid(p, rng) for _ in range(4000)])
        near = np.corrcoef(grids[:, 0, 0], grids[:, 0, 1])[0, 1]
        far = np.corrcoef(grids[:, 0, 0], grids[:, 9, 9])[0, 1]
        assert near > far
        assert near == pytest.approx(math.exp(-p.alpha), abs=0.1)


class TestCarbon:
    def test_no_improvement_no_saving(self):
        e = carbon.estimate(0.01, 0.01)
        assert e.reduction_frac == pytest.approx(0.0)
        assert e.extended_life_years == pytest.approx(3.0)

    def test_paper_headline_mapping(self):
        """37.67% yearly reduction corresponds to extension 1/(1-0.3767)."""
        ext = 1.0 / (1.0 - 0.3767)
        e = carbon.estimate(ext * 0.01, 0.01)
        assert e.reduction_frac == pytest.approx(0.3767, abs=1e-6)

    def test_halted_aging_capped(self):
        e = carbon.estimate(0.01, 0.0)
        assert e.extension_factor == 100.0

    def test_reduction_bounded(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(dl=st.floats(1e-6, 1.0), dt=st.floats(1e-6, 1.0))
        @settings(max_examples=200, deadline=None)
        def run(dl, dt):
            e = carbon.estimate(dl, dt)
            assert e.reduction_frac < 1.0
            assert e.yearly_kgco2eq > 0

        run()
