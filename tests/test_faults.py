"""Fault-injection subsystem (`repro.faults` + the fault layers in
`repro.core.manager`, `repro.sim.cluster`, `repro.sim.fleetsim`).

Pins the PR's acceptance scenario — under guardband faults at a fixed
seed/horizon, the proposed policy demonstrably fails fewer cores and
keeps higher availability than the linux baseline — plus the request
conservation invariant (completed + failed + rejected + pending ==
submitted), bounded retries, the faultless bit-exactness contract
(`fault_model="none"` builds no machinery and leaves fingerprints and
result scalars unchanged), per-model smokes on both engines, and the
manager/routing health surfaces.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.manager import CoreManager
from repro.faults import (
    FaultDecision,
    available_fault_models,
    canonical_fault_model_name,
    get_fault_model,
)
from repro.sim import ExperimentConfig
from repro.sim.cluster import (
    BACKOFF_BASE_S,
    HEDGE_TIMEOUT_S,
    MAX_RETRIES,
    Cluster,
)
from repro.sim.runner import run_experiment, run_policy_sweep

# ---------------------------------------------------------------------- #
# registry axis
# ---------------------------------------------------------------------- #
class TestRegistry:
    def test_builtins_registered(self):
        names = available_fault_models()
        for n in ("none", "guardband", "machine-crash", "transient-stall"):
            assert n in names

    def test_canonical_name(self):
        assert canonical_fault_model_name("Machine_Crash") == \
            "machine-crash"
        assert get_fault_model("GUARDBAND").name == "guardband"

    def test_opts_reach_model(self):
        m = get_fault_model("guardband", margin=0.05)
        assert m.margin == 0.05

    def test_unknown_name_raises(self):
        with pytest.raises(KeyError, match="unknown fault model"):
            get_fault_model("cosmic-rays")

    def test_fresh_instance_per_get(self):
        assert get_fault_model("machine-crash") is not \
            get_fault_model("machine-crash")

    def test_none_decides_nothing(self):
        assert get_fault_model("none").periodic(None) is None

    def test_decision_truthiness(self):
        assert not FaultDecision()
        assert FaultDecision(fail_cores=(3,))
        assert FaultDecision(crash=True)


# ---------------------------------------------------------------------- #
# faultless contract: "none" is free and invisible
# ---------------------------------------------------------------------- #
class TestFaultlessContract:
    def test_fingerprint_unchanged_by_default_axis(self):
        """Pre-fault configs keep their historical hashes: the default
        fault fields are omitted from the fingerprint payload (same
        treatment as the engine axis), so the pinned drift-gate golden
        survives without re-pinning."""
        assert ExperimentConfig().fingerprint() == \
            ExperimentConfig(fault_model="none").fingerprint()

    def test_fault_fingerprint_differs(self):
        base = ExperimentConfig()
        assert base.with_fault_model("guardband").fingerprint() != \
            base.fingerprint()
        assert base.with_fault_model(
            "guardband", margin=0.02).fingerprint() != \
            base.with_fault_model("guardband").fingerprint()

    def test_no_fault_machinery_when_off(self):
        cluster = Cluster(ExperimentConfig(duration_s=1.0))
        assert cluster.faults is None

    def test_robustness_scalars_only_when_on(self):
        cfg = ExperimentConfig(duration_s=20.0, n_prompt=1, n_token=2,
                               rate_rps=8.0)
        off = run_experiment(cfg).scalars()
        on = run_experiment(
            cfg.with_fault_model("transient-stall")).scalars()
        assert "availability" not in off
        assert "core_failures" not in off
        assert on["availability"] <= 1.0
        assert set(off) < set(on)

    def test_unknown_fault_model_fails_fast(self):
        with pytest.raises(KeyError, match="unknown fault model"):
            run_experiment(
                ExperimentConfig(fault_model="solar-flare"))


# ---------------------------------------------------------------------- #
# manager-level fault handling
# ---------------------------------------------------------------------- #
class TestManagerFaults:
    def _mgr(self, **kw):
        return CoreManager(8, policy="linux",
                           rng=np.random.default_rng(0), **kw)

    def test_fail_core_offlines_and_demotes(self):
        demoted = []
        mgr = self._mgr(on_demote=lambda tid, now, speed:
                        demoted.append(tid))
        mgr.assign(1, 0.0)
        victim = mgr.core_of_task[1]
        mgr.fail_core(victim, 1.0)
        assert mgr.failed[victim]
        assert demoted == [1]
        # the failed core never gets another task
        for tid in range(2, 12):
            mgr.assign(tid, 1.0 + tid)
            assert mgr.core_of_task.get(tid) != victim

    def test_fail_core_idempotent(self):
        mgr = self._mgr()
        mgr.fail_core(2, 1.0)
        mgr.fail_core(2, 2.0)
        assert int(mgr.failed.sum()) == 1

    def test_crash_reboot_preserves_failed_cores(self):
        mgr = self._mgr()
        mgr.assign(1, 0.0)
        mgr.fail_core(5, 1.0)
        mgr.crash(2.0)
        assert not mgr.core_of_task
        mgr.reboot(3.0)
        assert mgr.failed[5]
        # failed core stays fenced after reboot
        for tid in range(10, 20):
            mgr.assign(tid, 3.0 + tid)
            assert mgr.core_of_task.get(tid) != 5

    def test_stall_slows_then_clears(self):
        mgr = self._mgr()
        mgr.assign(1, 0.0)
        core = mgr.core_of_task[1]
        mgr.set_core_slowdown(core, 1.0, 0.25)
        assert mgr._stalls[core] == 0.25
        mgr.clear_core_slowdown(core, 2.0)
        assert core not in mgr._stalls


# ---------------------------------------------------------------------- #
# routing health surface
# ---------------------------------------------------------------------- #
class TestFleetViewHealth:
    def test_health_fields(self):
        cfg = ExperimentConfig(duration_s=1.0)
        cluster = Cluster(cfg)
        view = cluster.fleet
        assert view.prompt_up().all()
        assert view.token_up().all()
        assert view.machine_up().all()
        assert (view.offline_cores() == 0).all()
        cluster.machines[0].manager.fail_core(3, 0.5)
        assert view.offline_cores()[0] == 1


# ---------------------------------------------------------------------- #
# event-engine fault experiments
# ---------------------------------------------------------------------- #
def _conserved(r) -> bool:
    return (r.completed + r.failed_requests + r.rejected_requests
            + r.pending_requests) == r.submitted


_SMALL = dict(duration_s=30.0, n_prompt=1, n_token=2, rate_rps=8.0,
              seed=3)


class TestEventEngineFaults:
    def test_machine_crash_smoke(self):
        r = run_experiment(ExperimentConfig(
            **_SMALL, fault_model="machine-crash",
            fault_opts=(("mttf_s", 20.0), ("reboot_s", 5.0))))
        assert r.machine_crashes > 0
        assert r.availability < 1.0
        assert r.retries > 0
        assert _conserved(r)
        assert r.p99_degraded_window_s > 0.0

    def test_transient_stall_smoke(self):
        r = run_experiment(ExperimentConfig(
            **_SMALL, fault_model="transient-stall",
            fault_opts=(("rate_per_s", 0.2),)))
        assert r.stalls > 0
        # stalls degrade service but never take capacity offline
        assert r.availability == 1.0
        assert r.core_failures == 0 and r.machine_crashes == 0
        assert _conserved(r)

    def test_guardband_smoke(self):
        r = run_experiment(ExperimentConfig(
            **_SMALL, fault_model="guardband",
            fault_opts=(("margin", 0.010),)))
        assert r.core_failures > 0
        assert r.availability < 1.0
        assert _conserved(r)

    def test_retries_bounded(self):
        r = run_experiment(ExperimentConfig(
            **_SMALL, fault_model="machine-crash",
            fault_opts=(("mttf_s", 15.0), ("reboot_s", 5.0))))
        assert r.retries <= MAX_RETRIES * r.submitted
        assert MAX_RETRIES >= 1 and BACKOFF_BASE_S > 0
        assert HEDGE_TIMEOUT_S > 0

    def test_determinism(self):
        cfg = ExperimentConfig(**_SMALL, fault_model="machine-crash",
                               fault_opts=(("mttf_s", 20.0),))
        a, b = run_experiment(cfg), run_experiment(cfg)
        assert a.scalars() == b.scalars()


class TestGuardbandAcceptance:
    """The PR's pinned acceptance scenario: identical silicon, identical
    fault thresholds (the fault RNG stream is policy-independent), fixed
    seed and horizon — the aging-aware policy must keep more cores under
    the guardband margin than the aging-oblivious baseline."""

    @pytest.fixture(scope="class")
    def pair(self):
        base = ExperimentConfig(seed=3, duration_s=60.0,
                                fault_model="guardband",
                                fault_opts=(("margin", 0.012),))
        return (run_experiment(base.with_policy("linux")),
                run_experiment(base.with_policy("proposed")))

    def test_proposed_fails_fewer_cores(self, pair):
        linux, proposed = pair
        assert proposed.core_failures < linux.core_failures

    def test_proposed_keeps_higher_availability(self, pair):
        linux, proposed = pair
        assert proposed.availability > linux.availability

    def test_both_conserve_requests(self, pair):
        for r in pair:
            assert _conserved(r)

    def test_retries_bounded(self, pair):
        for r in pair:
            assert r.retries <= MAX_RETRIES * r.submitted


# ---------------------------------------------------------------------- #
# fleet engine fault experiments
# ---------------------------------------------------------------------- #
class TestFleetEngineFaults:
    def _run(self, fault_model, fault_opts=(), backend="numpy"):
        cfg = ExperimentConfig(
            policy="proposed", duration_s=60.0, seed=7,
            fault_model=fault_model, fault_opts=fault_opts,
            engine_opts=(("backend", backend),), engine="fleet")
        return run_experiment(cfg)

    def test_guardband(self):
        r = self._run("guardband", (("margin", 0.012),))
        assert r.core_failures > 0
        assert r.availability < 1.0
        assert _conserved(r)

    def test_machine_crash(self):
        r = self._run("machine-crash", (("mttf_s", 120.0),))
        assert r.machine_crashes > 0
        assert r.availability < 1.0
        assert r.retries > 0
        assert _conserved(r)

    def test_transient_stall(self):
        r = self._run("transient-stall", (("rate_per_s", 0.1),))
        assert r.stalls > 0
        assert r.availability == 1.0
        assert _conserved(r)

    def test_backends_agree_on_counts(self):
        a = self._run("machine-crash", (("mttf_s", 120.0),), "numpy")
        b = self._run("machine-crash", (("mttf_s", 120.0),), "jax")
        # fault timelines are precomputed from the same RNG streams, so
        # the crash count matches exactly; retried queue mass is fluid
        # (f32 vs f64 rounding can differ by a unit)
        assert a.machine_crashes == b.machine_crashes
        assert abs(a.retries - b.retries) <= 1
        assert a.availability == pytest.approx(b.availability, rel=1e-3)

    def test_custom_model_rejected_by_fleet_engine(self):
        from repro.faults import FaultModel, register_fault_model
        from repro.faults.registry import _REGISTRY
        from repro.sim.fleetsim import FleetEngine

        @register_fault_model("test-meteor")
        class MeteorFaults(FaultModel):
            name = "test-meteor"

        try:
            cfg = ExperimentConfig(fault_model="test-meteor",
                                   engine="fleet")
            with pytest.raises(ValueError, match="cannot vectorize"):
                FleetEngine(cfg)
        finally:
            _REGISTRY.pop("test-meteor", None)


# ---------------------------------------------------------------------- #
# sweep axis
# ---------------------------------------------------------------------- #
class TestSweepAxis:
    def test_fault_axis_keys(self):
        cfg = ExperimentConfig(duration_s=10.0, n_prompt=1, n_token=1,
                               rate_rps=4.0)
        sweep = run_policy_sweep(cfg, policies=("linux",),
                                 fault_models=("none",
                                               "transient-stall"))
        assert set(sweep) == {("linux", "none"),
                              ("linux", "transient-stall")}
        assert sweep[("linux", "none")].fault_model == "none"
        assert sweep[("linux", "transient-stall")].stalls >= 0
