"""Carbon-accounting subsystem + structured-results tests.

Golden-pins the `linear-extension` model bit-exactly against the
pre-subsystem `repro.core.carbon.estimate` outputs, covers the
reliability-threshold and operational+embodied models with their
`CarbonIntensity` signals, pins the carbon registry's error wordings in
parity with the policy / scenario / router axes, and round-trips
`ExperimentResult` / `SweepResult` through JSON (the acceptance 2x2x2
grid, provenance included).
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.carbon import (BASELINE_LIFESPAN_YEARS, CPU_EMBODIED_KGCO2EQ,
                          CarbonModel, ConstantIntensity, DiurnalIntensity,
                          LifetimeEstimate, MAX_EXTENSION_FACTOR,
                          NBTI_TIME_EXPONENT, TraceIntensity,
                          available_carbon_models, estimate,
                          get_carbon_model, get_intensity,
                          register_carbon_model)
from repro.sim import (ExperimentConfig, ExperimentResult, Provenance,
                       SweepResult, carbon_comparison, run_experiment,
                       run_policy_sweep)


def canon(obj) -> str:
    """Canonical JSON string — the NaN-safe lossless-equality witness
    (NaN != NaN under ==, but serializes to the identical token)."""
    return json.dumps(obj, sort_keys=True)


# --------------------------------------------------------------------- #
# linear-extension: bit-exact re-homing of repro.core.carbon.estimate
# --------------------------------------------------------------------- #
class TestLinearExtensionGoldenPin:
    # Captured from the pre-subsystem repro.core.carbon.estimate at
    # commit e3b4222 (exact repr of every float; the second case is the
    # linux/proposed p99 pair of the seed Fig.-7 configuration).
    GOLD = {
        (0.02, 0.013): (1.5384615384615385, 4.615384615384616,
                        60.29833333333333, 92.76666666666667, 0.35),
        (0.017512094707309137, 0.011416982341791698): (
            1.533863693841968, 4.601591081525904, 60.47908105465876,
            92.76666666666667, 0.3480515876249505),
        (0.01, 0.01): (1.0, 3.0, 92.76666666666667, 92.76666666666667,
                       0.0),
        (0.01, 0.0): (100.0, 300.0, 0.9276666666666668, 92.76666666666667,
                      0.99),
        (0.0, 0.01): (1e-06, 3e-06, 92766666.66666667,
                      92.76666666666667, -999999.0000000001),
    }

    @pytest.mark.parametrize("args", sorted(GOLD))
    def test_pinned_values(self, args):
        est = get_carbon_model("linear-extension").lifetime(*args)
        gold = self.GOLD[args]
        assert est.extension_factor == gold[0]
        assert est.extended_life_years == gold[1]
        assert est.yearly_kgco2eq == gold[2]
        assert est.baseline_yearly_kgco2eq == gold[3]
        assert est.reduction_frac == gold[4]

    def test_matches_estimate_wrapper_everywhere(self):
        """`carbon.estimate` and the registered model must agree
        bit-exactly across a dense (deg_ref, deg_technique) grid."""
        model = get_carbon_model("linear-extension")
        for dl in (0.0, 1e-9, 1e-4, 0.01, 0.0173, 0.3, 1.0):
            for dt in (0.0, 1e-9, 1e-4, 0.01, 0.0173, 0.3, 1.0):
                a = estimate(dl, dt)
                b = model.lifetime(dl, dt)
                assert a == b, (dl, dt)

    def test_core_carbon_compat_module(self):
        """The historical `repro.core.carbon` spelling still works and
        resolves to the same implementation."""
        from repro.core import carbon as core_carbon
        assert core_carbon.estimate(0.02, 0.013) == \
            get_carbon_model("linear-extension").lifetime(0.02, 0.013)
        assert core_carbon.CarbonEstimate is LifetimeEstimate
        assert core_carbon.MAX_EXTENSION_FACTOR == MAX_EXTENSION_FACTOR

    def test_halted_aging_uses_named_cap(self):
        assert MAX_EXTENSION_FACTOR == 100.0
        est = get_carbon_model("linear-extension").lifetime(0.01, 0.0)
        assert est.extension_factor == MAX_EXTENSION_FACTOR

    def test_custom_embodied_and_lifespan(self):
        est = get_carbon_model("linear-extension", embodied_kg=100.0,
                               base_life_years=5.0).lifetime(0.02, 0.01)
        assert est.extended_life_years == 10.0
        assert est.yearly_kgco2eq == 10.0
        assert est.baseline_life_years == 5.0

    def test_invalid_opts_rejected(self):
        with pytest.raises(ValueError):
            get_carbon_model("linear-extension", embodied_kg=0.0)
        with pytest.raises(TypeError):
            get_carbon_model("linear-extension", bogus_opt=1)


class TestReliabilityThreshold:
    def test_exponent_matches_aging_params(self):
        """NBTI_TIME_EXPONENT is deliberately duplicated (the carbon
        layer must not import repro.core); this pin keeps it in sync
        with the aging model's default."""
        from repro.core import aging
        assert NBTI_TIME_EXPONENT == aging.AgingParams().n

    def test_guardband_inversion_exponent(self):
        """dVth = ADF * t^n inverts to extension = ratio^(1/n)."""
        model = get_carbon_model("reliability-threshold")
        est = model.lifetime(0.011, 0.01)
        assert est.extension_factor == pytest.approx(
            1.1 ** (1.0 / NBTI_TIME_EXPONENT), rel=1e-12)
        assert est.extended_life_years == pytest.approx(
            BASELINE_LIFESPAN_YEARS * est.extension_factor)

    def test_more_optimistic_than_linear_when_technique_wins(self):
        lin = get_carbon_model("linear-extension").lifetime(0.02, 0.015)
        rel = get_carbon_model("reliability-threshold").lifetime(0.02, 0.015)
        assert rel.extension_factor > lin.extension_factor
        assert rel.reduction_frac > lin.reduction_frac

    def test_cap_binds(self):
        model = get_carbon_model("reliability-threshold")
        assert model.lifetime(0.03, 0.01).extension_factor == \
            MAX_EXTENSION_FACTOR                       # 3^6 = 729 -> cap
        assert model.lifetime(0.01, 0.0).extension_factor == \
            MAX_EXTENSION_FACTOR
        small = get_carbon_model("reliability-threshold",
                                 max_extension=5.0)
        assert small.lifetime(0.03, 0.01).extension_factor == 5.0

    def test_no_improvement_no_saving(self):
        est = get_carbon_model("reliability-threshold").lifetime(0.01, 0.01)
        assert est.extension_factor == pytest.approx(1.0)
        assert est.reduction_frac == pytest.approx(0.0)

    def test_invalid_opts_rejected(self):
        with pytest.raises(ValueError):
            get_carbon_model("reliability-threshold", n=0.0)
        with pytest.raises(ValueError):
            get_carbon_model("reliability-threshold", max_extension=0.5)


# --------------------------------------------------------------------- #
# intensity signals + operational-embodied total footprint
# --------------------------------------------------------------------- #
class TestIntensitySignals:
    def test_constant(self):
        ci = ConstantIntensity(120.0)
        assert ci.g_per_kwh(0.0) == ci.g_per_kwh(1e7) == 120.0
        assert ci.mean_g_per_kwh() == 120.0

    def test_diurnal_mean_preserving(self):
        ci = DiurnalIntensity(mean=400.0, amplitude=0.6)
        values = [ci.g_per_kwh(t) for t in np.linspace(0, 86400, 86400,
                                                       endpoint=False)]
        assert ci.mean_g_per_kwh() == 400.0
        assert np.mean(values) == pytest.approx(400.0, rel=1e-3)
        assert max(values) == pytest.approx(640.0, rel=1e-3)
        assert min(values) == pytest.approx(160.0, rel=1e-3)

    def test_diurnal_validation(self):
        with pytest.raises(ValueError):
            DiurnalIntensity(mean=400.0, amplitude=1.0)
        with pytest.raises(ValueError):
            DiurnalIntensity(mean=400.0, period_s=0.0)

    def test_trace_step_hold_and_cyclic(self):
        tr = TraceIntensity(times_s=(0.0, 3600.0, 7200.0),
                            values_g_per_kwh=(100.0, 300.0, 200.0))
        assert tr.g_per_kwh(0.0) == 100.0
        assert tr.g_per_kwh(3599.9) == 100.0
        assert tr.g_per_kwh(3600.0) == 300.0
        # span = 7200 + mean gap 3600 = 10800; wraps cyclically
        assert tr.g_per_kwh(10800.0 + 5.0) == 100.0
        assert tr.mean_g_per_kwh() == pytest.approx(200.0)

    def test_trace_from_csv_and_validation(self):
        tr = TraceIntensity.from_csv(
            "time_s,g_per_kwh\n0,50\n1800,150\n")
        assert tr.mean_g_per_kwh() == pytest.approx(100.0)
        with pytest.raises(ValueError, match="time_s"):
            TraceIntensity.from_csv("a,b\n1,2\n")
        with pytest.raises(ValueError):
            TraceIntensity(times_s=(10.0,), values_g_per_kwh=(1.0,))

    def test_get_intensity_resolution(self):
        assert isinstance(get_intensity("constant"), ConstantIntensity)
        ci = ConstantIntensity(10.0)
        assert get_intensity(ci) is ci
        with pytest.raises(KeyError, match="diurnal"):
            get_intensity("definitely-not-a-signal")
        with pytest.raises(TypeError):
            get_intensity(ci, value_g_per_kwh=5.0)


class TestTraceIntensityHardening:
    """Ingest validation: power x intensity integration multiplies trace
    values straight into headline results, so bad samples must fail
    loudly — pointing at the offending index — never propagate."""

    def test_nonmonotonic_times_name_the_sample(self):
        with pytest.raises(ValueError, match=r"times_s\[2\]=100\.0"):
            TraceIntensity(times_s=(0.0, 200.0, 100.0),
                           values_g_per_kwh=(1.0, 2.0, 3.0))
        with pytest.raises(ValueError, match=r"times_s\[1\]"):
            TraceIntensity(times_s=(0.0, 0.0), values_g_per_kwh=(1.0, 2.0))

    def test_nonzero_start_named(self):
        with pytest.raises(ValueError, match=r"times_s\[0\]=10\.0"):
            TraceIntensity(times_s=(10.0, 20.0),
                           values_g_per_kwh=(1.0, 2.0))

    def test_negative_and_nonfinite_values_named(self):
        with pytest.raises(ValueError, match=r"g_per_kwh\[1\]=-5\.0"):
            TraceIntensity(times_s=(0.0, 60.0),
                           values_g_per_kwh=(1.0, -5.0))
        with pytest.raises(ValueError, match=r"g_per_kwh\[0\]=nan"):
            TraceIntensity(times_s=(0.0, 60.0),
                           values_g_per_kwh=(float("nan"), 1.0))
        with pytest.raises(ValueError, match=r"g_per_kwh\[1\]=inf"):
            TraceIntensity(times_s=(0.0, 60.0),
                           values_g_per_kwh=(1.0, float("inf")))
        with pytest.raises(ValueError, match=r"times_s\[1\]=inf"):
            TraceIntensity(times_s=(0.0, float("inf")),
                           values_g_per_kwh=(1.0, 2.0))

    @staticmethod
    def _roundtrip_property(times, values):
        """Valid trace -> CSV text -> from_csv reproduces the signal."""
        tr = TraceIntensity(times_s=times, values_g_per_kwh=values)
        csv_text = "time_s,g_per_kwh\n" + "".join(
            f"{t!r},{v!r}\n" for t, v in zip(times, values))
        back = TraceIntensity.from_csv(csv_text)
        assert back == tr
        assert back.mean_g_per_kwh() == pytest.approx(tr.mean_g_per_kwh())
        for t in list(times) + [tr._span_s * 2.5]:
            assert back.g_per_kwh(t) == tr.g_per_kwh(t)

    def test_csv_roundtrip_property(self):
        """Hypothesis round-trip when available; otherwise the same
        property over a seeded generative sweep (the container has no
        hypothesis wheel and deps cannot be installed)."""
        try:
            from hypothesis import given, settings
            from hypothesis import strategies as st

            finite = st.floats(min_value=0.0, max_value=1e4,
                               allow_nan=False, allow_infinity=False)

            @settings(max_examples=50, deadline=None)
            @given(st.lists(st.tuples(
                st.floats(min_value=1e-3, max_value=3600.0,
                          allow_nan=False, allow_infinity=False),
                finite), min_size=1, max_size=20))
            def prop(gap_value_pairs):
                t = 0.0
                times, values = [], []
                for gap, v in gap_value_pairs:
                    times.append(t)
                    values.append(v)
                    t += gap
                self._roundtrip_property(tuple(times), tuple(values))

            prop()
        except ImportError:
            rng = np.random.default_rng(20260807)
            for _ in range(50):
                n = int(rng.integers(1, 20))
                gaps = rng.uniform(1e-3, 3600.0, size=n)
                times = tuple(np.concatenate(
                    ([0.0], np.cumsum(gaps)[:-1])).tolist())
                values = tuple(rng.uniform(0.0, 1e4, size=n).tolist())
                self._roundtrip_property(times, values)


class TestOperationalEmbodied:
    def test_components_sum(self):
        fp = get_carbon_model("operational-embodied").footprint(0.02, 0.01)
        assert fp.total_kg == pytest.approx(
            fp.operational_kg + fp.cpu_embodied_kg + fp.gpu_embodied_kg)
        assert 0.0 < fp.embodied_frac < 1.0

    def test_embodied_dominates_on_clean_grid(self):
        """Paper Fig. 1: as grid intensity falls, embodied carbon
        becomes the dominant share."""
        def frac(ci):
            return get_carbon_model(
                "operational-embodied", intensity="constant",
                intensity_opts={"value_g_per_kwh": ci},
            ).footprint(0.01, 0.01).embodied_frac
        assert frac(12.0) > frac(436.0) > frac(820.0)

    def test_lifetime_delegates_to_wrapped_model(self):
        oe = get_carbon_model("operational-embodied",
                              lifetime_model="reliability-threshold")
        direct = get_carbon_model("reliability-threshold")
        assert oe.lifetime(0.02, 0.015) == direct.lifetime(0.02, 0.015)

    def test_aging_management_cuts_embodied_component_only(self):
        model = get_carbon_model("operational-embodied")
        base = model.footprint(0.01, 0.01)
        managed = model.footprint(0.02, 0.01)   # technique halves aging
        assert managed.cpu_embodied_kg == pytest.approx(
            base.cpu_embodied_kg / 2.0)
        assert managed.operational_kg == base.operational_kg
        assert managed.gpu_embodied_kg == base.gpu_embodied_kg

    def test_diurnal_signal_prices_its_mean(self):
        flat = get_carbon_model(
            "operational-embodied", intensity="constant",
            intensity_opts={"value_g_per_kwh": 250.0}).footprint(0.01, 0.01)
        swung = get_carbon_model(
            "operational-embodied", intensity="diurnal",
            intensity_opts={"mean": 250.0, "amplitude": 0.8},
        ).footprint(0.01, 0.01)
        assert swung.operational_kg == pytest.approx(flat.operational_kg)

    def test_utilization_override(self):
        model = get_carbon_model("operational-embodied", utilization=0.6)
        assert model.footprint(0.01, 0.01, utilization=0.3).operational_kg \
            == pytest.approx(model.footprint(0.01, 0.01).operational_kg / 2)


# --------------------------------------------------------------------- #
# registry parity with the policy / scenario / router axes
# --------------------------------------------------------------------- #
def _axis_params():
    from repro.carbon import registry as carbon_reg
    from repro.core.policies import CorePolicy
    from repro.core.policies import registry as policy_reg
    from repro.faults import registry as fault_reg
    from repro.faults.base import FaultModel
    from repro.hardware import registry as hardware_reg
    from repro.hardware.base import HardwareSKU
    from repro.power import registry as power_reg
    from repro.power.base import PowerModel
    from repro.sim import routing as router_reg
    from repro.workloads import registry as scenario_reg

    def subclass_of(base):
        return lambda: type("Imposter", (base,), {})

    return [
        pytest.param(policy_reg._POLICIES, "core policy",
                     subclass_of(CorePolicy), id="policy"),
        pytest.param(scenario_reg._SCENARIOS, "workload scenario",
                     lambda: (lambda: None), id="scenario"),
        pytest.param(router_reg._ROUTERS, "cluster router",
                     subclass_of(router_reg.ClusterRouter), id="router"),
        pytest.param(carbon_reg._MODELS, "carbon model",
                     subclass_of(CarbonModel), id="carbon"),
        pytest.param(power_reg._MODELS, "power model",
                     subclass_of(PowerModel), id="power"),
        pytest.param(fault_reg._MODELS, "fault model",
                     subclass_of(FaultModel), id="fault"),
        pytest.param(hardware_reg._SKUS, "hardware SKU",
                     subclass_of(HardwareSKU), id="hardware"),
    ]


class TestRegistryParity:
    """The seven axes share `repro.registry.Registry`; their pinned
    error wordings must keep the same shape, byte for byte."""

    @pytest.mark.parametrize("reg,kind,imposter", _axis_params())
    def test_unknown_name_wording(self, reg, kind, imposter):
        with pytest.raises(KeyError) as err:
            reg.get("definitely-not-registered")
        assert err.value.args[0] == (
            f"unknown {kind} 'definitely-not-registered'; available: "
            f"{', '.join(reg.available())}")

    @pytest.mark.parametrize("reg,kind,imposter", _axis_params())
    def test_duplicate_name_wording(self, reg, kind, imposter):
        taken = reg.available()[0]
        prev = reg.store[taken]
        prev_desc = (repr(getattr(prev, "__name__", prev))
                     if reg.quote_prev else prev.__name__)
        with pytest.raises(ValueError) as err:
            reg.register(taken)(imposter())
        assert err.value.args[0] == (
            f"{reg.noun} name {taken!r} already registered to {prev_desc}")

    def test_unknown_carbon_model_lists_builtins(self):
        with pytest.raises(KeyError, match="linear-extension"):
            get_carbon_model("definitely-not-a-model")

    def test_decorator_rejects_non_model(self):
        with pytest.raises(TypeError) as err:
            register_carbon_model("bogus")(object)
        assert err.value.args[0] == (
            "@register_carbon_model('bogus') expects a CarbonModel "
            f"subclass, got {object!r}")

    def test_builtins_present(self):
        assert {"linear-extension", "reliability-threshold",
                "operational-embodied"} <= set(available_carbon_models())

    def test_fresh_instance_per_call(self):
        assert get_carbon_model("linear-extension") is not \
            get_carbon_model("linear-extension")

    def test_name_normalization(self):
        a = get_carbon_model("Linear_Extension")
        assert type(a) is type(get_carbon_model("linear-extension"))

    def test_custom_model_registers_and_prices(self):
        @register_carbon_model("test-flat")
        class Flat(CarbonModel):
            def lifetime(self, deg_ref, deg_technique):
                return LifetimeEstimate(1.0, 3.0, 1.0, 1.0, 0.0,
                                        model=self.name)

        try:
            m = run_experiment(ExperimentConfig(
                rate_rps=30, duration_s=4, seed=0,
                carbon_model="test-flat"))
            assert m.carbon_model == "test-flat"
            assert m.fleet_yearly_kgco2eq == pytest.approx(22.0)
            assert all(e.model == "test-flat"
                       for e in m.per_machine_carbon)
        finally:
            from repro.carbon import registry
            registry._REGISTRY.pop("test-flat", None)


class TestRegistryParityDuplicateCheck:
    def test_duplicate_builtin_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_carbon_model("linear-extension")
            class Imposter(CarbonModel):
                pass


# --------------------------------------------------------------------- #
# ExperimentConfig carbon axis + experiment wiring
# --------------------------------------------------------------------- #
class TestConfigCarbonAxis:
    def test_canonicalization_and_with(self):
        cfg = ExperimentConfig(carbon_model="Reliability_Threshold",
                               carbon_opts={"max_extension": 10.0})
        assert cfg.carbon_model == "reliability-threshold"
        assert cfg.carbon_options == {"max_extension": 10.0}
        cfg2 = cfg.with_carbon_model("linear-extension")
        assert cfg2.carbon_model == "linear-extension"
        assert cfg2.carbon_opts == ()

    def test_fingerprint_tracks_carbon_axis(self):
        cfg = ExperimentConfig()
        assert cfg.fingerprint() != \
            cfg.with_carbon_model("reliability-threshold").fingerprint()
        assert cfg.fingerprint() == ExperimentConfig().fingerprint()

    def test_experiment_prices_with_configured_model(self):
        cfg = ExperimentConfig(rate_rps=30, duration_s=4, seed=0)
        lin = run_experiment(cfg)
        rel = run_experiment(
            cfg.with_carbon_model("reliability-threshold"))
        assert lin.carbon_model == "linear-extension"
        assert rel.carbon_model == "reliability-threshold"
        # same simulation -> identical aging; only the pricing differs
        assert rel.mean_degradation_percentiles == \
            lin.mean_degradation_percentiles
        assert rel.fleet_yearly_kgco2eq != lin.fleet_yearly_kgco2eq

    def test_carbon_comparison_honours_result_model(self):
        cfg = ExperimentConfig(rate_rps=30, duration_s=4, seed=0,
                               carbon_model="reliability-threshold")
        sweep = run_policy_sweep(cfg, policies=("linux", "proposed"))
        est = carbon_comparison(sweep["linux"], sweep["proposed"], 99)
        assert est.model == "reliability-threshold"
        lin = carbon_comparison(sweep["linux"], sweep["proposed"], 99,
                                model="linear-extension")
        assert lin.model == "linear-extension"
        # explicit model reproduces the historical default bit-exactly
        assert lin == estimate(
            sweep["linux"].mean_degradation_percentiles[99],
            sweep["proposed"].mean_degradation_percentiles[99])

    def test_fleet_yearly_under_reprices_exactly(self):
        """Repricing saved degradation data under the result's own model
        must reproduce the collected fleet total bit for bit (fig7's
        one-simulation-many-models path relies on this), and a typo'd
        carbon model must fail before the simulation runs."""
        m = run_experiment(ExperimentConfig(rate_rps=30, duration_s=4,
                                            seed=0))
        assert m.deg_reference is not None and m.deg_reference > 0
        assert m.fleet_yearly_under() == m.fleet_yearly_kgco2eq
        assert m.fleet_yearly_under("linear-extension") == \
            m.fleet_yearly_kgco2eq
        rel = m.fleet_yearly_under("reliability-threshold")
        assert rel != m.fleet_yearly_kgco2eq and rel > 0
        back = ExperimentResult.from_json(m.to_json())
        assert back.fleet_yearly_under("linear-extension") == \
            m.fleet_yearly_kgco2eq
        with pytest.raises(KeyError, match="linear-extension"):
            run_experiment(ExperimentConfig(
                duration_s=4, carbon_model="liner-extension"))

    def test_carbon_comparison_honours_result_opts(self):
        """Regression: a sweep priced with custom carbon_opts must be
        compared under those same opts by default, and the opts must
        survive the JSON round-trip."""
        cfg = ExperimentConfig(rate_rps=30, duration_s=4, seed=0,
                               carbon_opts={"embodied_kg": 500.0})
        sweep = run_policy_sweep(cfg, policies=("linux", "proposed"))
        assert sweep["proposed"].carbon_opts == (("embodied_kg", 500.0),)
        est = carbon_comparison(sweep["linux"], sweep["proposed"], 99)
        assert est.baseline_yearly_kgco2eq == pytest.approx(500.0 / 3.0)
        back = ExperimentResult.from_json(sweep["proposed"].to_json())
        assert back.carbon_opts == (("embodied_kg", 500.0),)
        # opts-priced results re-price under their own opts by default
        assert sweep["proposed"].fleet_yearly_under() == \
            sweep["proposed"].fleet_yearly_kgco2eq

    def test_schema_version_checked_on_load(self):
        m = run_experiment(ExperimentConfig(rate_rps=30, duration_s=4,
                                            seed=0))
        d = m.to_dict()
        d["schema"] = 99
        with pytest.raises(ValueError, match="unsupported result schema"):
            ExperimentResult.from_dict(d)
        with pytest.raises(ValueError, match="unsupported result schema"):
            SweepResult.from_dict({"schema": 99, "axes": ["policy"],
                                   "cells": []})

    def test_structured_carbon_opts_roundtrip(self):
        """Tuple-valued opts must come back as tuples (JSON arrays are
        re-tuplified), preserving dataclass equality."""
        m = run_experiment(ExperimentConfig(rate_rps=30, duration_s=4,
                                            seed=0))
        r = dataclasses.replace(
            m, carbon_opts=(("intensity_opts",
                             {"times_s": (0.0, 3600.0)}),))
        back = ExperimentResult.from_json(r.to_json())
        assert back.carbon_opts == r.carbon_opts

    def test_carbon_greedy_router_takes_model_opt(self):
        from repro.sim import get_router
        r = get_router("carbon-greedy",
                       carbon_model="reliability-threshold")
        assert r.carbon_model.name == "reliability-threshold"
        with pytest.raises(TypeError):
            get_router("carbon-greedy",
                       carbon_model=get_carbon_model("linear-extension"),
                       carbon_opts={"embodied_kg": 1.0})


# --------------------------------------------------------------------- #
# ExperimentResult / SweepResult serialization
# --------------------------------------------------------------------- #
class TestExperimentResultRoundTrip:
    def test_real_result_roundtrip(self):
        m = run_experiment(ExperimentConfig(rate_rps=30, duration_s=4,
                                            seed=0))
        back = ExperimentResult.from_json(m.to_json())
        assert canon(back.to_dict()) == canon(m.to_dict())
        assert back.provenance == m.provenance
        assert back.per_machine_carbon == m.per_machine_carbon
        assert isinstance(back.freq_cv_percentiles, dict)
        assert all(isinstance(k, int) for k in back.freq_cv_percentiles)

    def test_result_is_frozen(self):
        m = run_experiment(ExperimentConfig(rate_rps=30, duration_s=4,
                                            seed=0))
        with pytest.raises(dataclasses.FrozenInstanceError):
            m.completed = 0

    def test_nan_fields_survive(self):
        from repro.sim import Cluster, collect
        cfg = ExperimentConfig(duration_s=4.0)
        cluster = Cluster(cfg)
        cluster.run([], 4.0)
        m = collect(cluster, cfg)
        assert math.isnan(m.mean_latency_s)
        back = ExperimentResult.from_json(m.to_json())
        assert math.isnan(back.mean_latency_s)
        assert canon(back.to_dict()) == canon(m.to_dict())

    def test_property_roundtrip(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        finite = st.floats(allow_nan=False, allow_infinity=False,
                           width=64)
        metric = st.one_of(finite, st.just(float("nan")))
        pct = st.fixed_dictionaries({p: finite
                                     for p in (1, 25, 50, 75, 90, 99)})

        @given(pcts=st.tuples(pct, pct, pct),
               scalars=st.tuples(metric, metric, metric, metric),
               ints=st.tuples(st.integers(0, 10**6), st.integers(0, 10**6)),
               degs=st.lists(finite, min_size=1, max_size=4),
               seed=st.integers(0, 2**31))
        @settings(max_examples=60, deadline=None)
        def run(pcts, scalars, ints, degs, seed):
            carbon = tuple(estimate(abs(d) + 1e-6, 1e-3) for d in degs)
            r = ExperimentResult(
                policy="proposed", num_cores=40, rate_rps=60.0,
                scenario="conversation-poisson",
                freq_cv_percentiles=pcts[0],
                mean_degradation_percentiles=pcts[1],
                idle_norm_percentiles=pcts[2],
                oversub_frac_below=scalars[0],
                task_count_mean=scalars[1],
                mean_latency_s=scalars[2],
                p99_latency_s=scalars[3],
                task_count_max=ints[0], completed=ints[1],
                per_machine_carbon=carbon,
                per_machine_degradation=tuple(degs),
                per_machine_idle_norm=((0.5, -0.1), (1.0,)),
                per_machine_task_samples=((1, 2, 3), (0,)),
                provenance=Provenance(config_hash="abc123def456",
                                      seed=seed))
            back = ExperimentResult.from_json(r.to_json())
            assert canon(back.to_dict()) == canon(r.to_dict())

        run()


class TestSweepResultAcceptance:
    """ISSUE acceptance: a 2x2x2 policy x scenario x router grid must
    save -> load -> to_rows losslessly with provenance intact."""

    @pytest.fixture(scope="class")
    def grid(self):
        return run_policy_sweep(
            ExperimentConfig(rate_rps=30, duration_s=5, seed=0),
            policies=("linux", "proposed"),
            scenarios=("conversation-poisson", "conversation-mmpp"),
            routers=("jsq", "round-robin"))

    def test_mapping_surface(self, grid):
        assert isinstance(grid, SweepResult)
        assert grid.axes == ("policy", "scenario", "router")
        assert len(grid) == 8
        key = ("proposed", "conversation-mmpp", "jsq")
        assert grid[key].policy == "proposed"
        assert set(k[0] for k in grid) == {"linux", "proposed"}

    def test_save_load_lossless(self, grid, tmp_path):
        path = str(tmp_path / "grid.json")
        grid.save(path)
        back = SweepResult.load(path)
        assert back.axes == grid.axes
        assert list(back) == list(grid)
        for key in grid:
            assert canon(back[key].to_dict()) == canon(grid[key].to_dict())
            assert back[key].provenance == grid[key].provenance
            assert back[key].provenance.config_hash
            assert back[key].provenance.seed == 0

    def test_to_rows(self, grid):
        rows = grid.to_rows()
        assert len(rows) == 8
        for row, key in zip(rows, grid):
            assert (row["policy"], row["scenario"], row["router"]) == key
            assert row["config_hash"]
            assert "mean_degradation_p99" in row
            assert "fleet_yearly_kgco2eq" in row
            # per-machine detail stays out of the diffable view
            assert "per_machine_carbon" not in row

    def test_diff_scalars_self_empty(self, grid, tmp_path):
        path = str(tmp_path / "grid.json")
        grid.save(path)
        back = SweepResult.load(path)
        assert grid.diff_scalars(back) == {}

    def test_diff_scalars_reports_missing_cells(self, grid):
        """A dropped grid cell must diff as a diff in both directions —
        the CI drift check relies on `diff == {}` meaning nothing
        moved, cells included."""
        dropped = next(iter(grid))
        subset = SweepResult([(k, grid[k]) for k in grid if k != dropped],
                             axes=grid.axes)
        assert grid.diff_scalars(subset) == \
            {dropped: {"_cell": ("present", "missing")}}
        assert subset.diff_scalars(grid) == \
            {dropped: {"_cell": ("missing", "present")}}

    def test_key_arity_validated(self, grid):
        with pytest.raises(ValueError, match="axes"):
            SweepResult([(("a", "b"), next(iter(grid.values())))],
                        axes=("policy",))
        with pytest.raises(TypeError):
            SweepResult([("linux", "not-a-result")], axes=("policy",))

    def test_single_axis_sweep_keys(self):
        sweep = run_policy_sweep(
            ExperimentConfig(rate_rps=30, duration_s=4, seed=0),
            policies=("linux",))
        assert sweep.axes == ("policy",)
        assert set(sweep) == {"linux"}
        assert sweep["linux"].completed > 0
