"""Property tests for the CoreManager's event-loop fast paths.

The PR-4 hot-path rewrite replaced per-event numpy dispatch with
incremental indices: an idle-score array kept in lockstep with the
idle-history ring buffers, a lazy free-core heap answering Algorithm
1's masked argmax, and a busy-core set backing the oversubscribed-task
speed bound. Every test here drives a manager through arbitrary
assign/release/periodic(idle/wake) sequences and asserts the
incremental answers are IDENTICAL — bitwise, not approximately — to a
from-scratch recompute via the reference implementations
(`repro.core.mapping`, `CoreManager._settled_dvth`).
"""
import numpy as np
import pytest

from repro.core import CoreManager, aging, mapping
from repro.core.temperature import CState

ALL_POLICIES = ("proposed", "linux", "least-aged", "round-robin",
                "aging-greedy")


def make(policy="proposed", n=8, seed=0, **kw):
    return CoreManager(n, policy=policy, rng=np.random.default_rng(seed),
                       **kw)


def reference_busy_max(m: CoreManager, now: float) -> float:
    """The pre-rewrite oversubscribed speed bound: fleet-wide settled
    frequencies, masked to busy cores (all cores when nothing is busy)."""
    freqs = aging.frequency(m.params, m.f0, m._settled_dvth(now))
    busy = m.task_of_core >= 0
    pool = freqs[busy] if busy.any() else freqs
    return float(np.max(pool))


def assert_fast_paths_match_reference(m: CoreManager, now: float) -> None:
    active = m.c_state == CState.ACTIVE
    assigned = m.task_of_core >= 0
    # incremental idle scores == reference row sums, bitwise
    np.testing.assert_array_equal(m.idle_score,
                                  mapping.idle_scores(m.idle_history))
    # free-core heap == reference masked argmax (incl. tie-breaking)
    ref_core = mapping.select_core(active, assigned, m.idle_history)
    assert m._peek_best_free() == ref_core
    assert m.view.best_idle_core() == ref_core
    # busy-core set == reference mask
    assert m._busy_cores == set(int(i) for i in np.flatnonzero(assigned))
    # oversubscribed speed bound == reference vectorized max, bitwise
    assert m._busy_max_frequency(now) == reference_busy_max(m, now)


def drive_random_schedule(m: CoreManager, rng: np.random.Generator,
                          steps: int = 100) -> None:
    live: list[int] = []
    t, tid = 0.0, 0
    for _ in range(steps):
        t += float(rng.uniform(0.01, 0.7))
        act = int(rng.integers(0, 4))
        if act == 0 or not live:
            m.assign(tid, t)
            live.append(tid)
            tid += 1
        elif act == 1:
            victim = live.pop(int(rng.integers(0, len(live))))
            m.release(victim, t)
        elif act == 2:
            m.periodic(t)           # may gate or wake cores (proposed)
        else:
            for _ in range(int(rng.integers(1, 6))):   # saturation burst
                m.assign(tid, t)
                live.append(tid)
                tid += 1
        assert_fast_paths_match_reference(m, t)
    # drain, checking along the way
    for victim in live:
        t += 0.05
        m.release(victim, t)
        assert_fast_paths_match_reference(m, t)


class TestIncrementalMatchesRecompute:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_random_schedules(self, policy):
        for seed in range(4):
            m = make(policy, n=8, seed=seed)
            drive_random_schedule(m, np.random.default_rng(seed * 17 + 1))

    def test_heavily_oversubscribed_small_manager(self):
        """Saturate a 2-core manager so every path (oversub assign,
        promotion, periodic accrual) exercises the incremental
        indices."""
        m = make("proposed", n=2, seed=3)
        t = 0.0
        for tid in range(30):
            t += 0.05
            m.assign(tid, t)
            assert_fast_paths_match_reference(m, t)
        for tid in range(30):
            t += 0.05
            m.release(tid, t)
            assert_fast_paths_match_reference(m, t)
        assert not m.oversub_tasks

    def test_gate_wake_cycles_keep_heap_consistent(self):
        """Proposed's Algorithm-2 corrections shrink and grow the
        working set; the heap must track both transitions."""
        m = make("proposed", n=16, seed=1)
        m.assign(0, 0.0)
        t = 0.0
        for k in range(12):                  # shrink
            t += 1.0
            m.periodic(t)
            assert_fast_paths_match_reference(m, t)
        assert (m.c_state == CState.DEEP_IDLE).any()
        for tid in range(1, 14):             # burst forces wakes
            m.assign(tid, t)
        for k in range(8):
            t += 1.0
            m.periodic(t)
            assert_fast_paths_match_reference(m, t)

    def test_external_cstate_mutation_tolerated(self):
        """Forcing c_state behind the manager's back (test-only pattern)
        must not let the heap hand out a gated core."""
        m = make("proposed", n=4, seed=0)
        m.c_state[:] = CState.DEEP_IDLE
        assert m._peek_best_free() == -1
        assert m._peek_best_free() == mapping.select_core(
            m.c_state == CState.ACTIVE, m.task_of_core >= 0,
            m.idle_history)

    def test_busy_max_is_pure(self):
        m = make("proposed", n=4, seed=2)
        m.assign(0, 0.0)
        dvth = m.dvth.copy()
        last = m.last_update.copy()
        m._busy_max_frequency(123.0)
        np.testing.assert_array_equal(m.dvth, dvth)
        np.testing.assert_array_equal(m.last_update, last)


class TestHypothesisSchedules:
    def test_arbitrary_schedules_property(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(seed=st.integers(0, 10_000),
               policy=st.sampled_from(ALL_POLICIES),
               n=st.sampled_from((2, 5, 8)))
        @settings(max_examples=30, deadline=None)
        def run(seed, policy, n):
            m = make(policy, n=n, seed=seed)
            drive_random_schedule(m, np.random.default_rng(seed), steps=60)

        run()
