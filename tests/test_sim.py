"""Simulator tests: event core, traces, cluster behaviour, paper claims."""
import numpy as np
import pytest

from repro.sim import (EventQueue, ExperimentConfig, carbon_comparison,
                       run_experiment, run_policy_sweep)
from repro.workloads import get_scenario, request_stats


class TestEventQueue:
    def test_ordering(self):
        q = EventQueue()
        seen = []
        q.schedule(2.0, lambda: seen.append("b"))
        q.schedule(1.0, lambda: seen.append("a"))
        q.schedule(1.0, lambda: seen.append("a2"))  # FIFO tie-break
        q.run_until(3.0)
        assert seen == ["a", "a2", "b"]
        assert q.now == 3.0

    def test_schedule_in_during_run(self):
        q = EventQueue()
        seen = []

        def chain(k):
            seen.append(k)
            if k < 3:
                q.schedule_in(0.5, lambda: chain(k + 1))

        q.schedule(0.0, lambda: chain(0))
        q.run_until(10.0)
        assert seen == [0, 1, 2, 3]

    def test_no_past_scheduling(self):
        q = EventQueue()
        q.run_until(5.0)
        seen = []
        q.schedule(1.0, lambda: seen.append(1))  # clamped to now
        q.run_until(6.0)
        assert seen == [1]


class TestTrace:
    def test_deterministic(self):
        sc = get_scenario("conversation-poisson")
        a = sc.generate(rate_rps=60, duration_s=20, seed=3)
        b = sc.generate(rate_rps=60, duration_s=20, seed=3)
        assert a == b

    def test_statistics_match_azure_characterization(self):
        """Synthesized traces must match the Splitwise Azure-conversation
        characterization: input median ~1020, output mean ~211 tokens."""
        stats = request_stats(get_scenario("conversation-poisson").generate(
            rate_rps=200, duration_s=120, seed=0))
        assert 800 < stats["input_median"] < 1300
        assert 150 < stats["output_mean"] < 300

    def test_rate_respected(self):
        reqs = get_scenario("conversation-poisson").generate(
            rate_rps=50, duration_s=100, seed=1)
        assert len(reqs) == pytest.approx(5000, rel=0.1)
        assert all(0 <= r.arrival_s < 100 for r in reqs)


class TestClusterEndToEnd:
    @pytest.fixture(scope="class")
    def sweep(self):
        return run_policy_sweep(ExperimentConfig(num_cores=40, rate_rps=60,
                                                 duration_s=30, seed=0))

    def test_requests_complete(self, sweep):
        for m in sweep.values():
            assert m.completed > 100

    def test_cpu_underutilization_observed(self, sweep):
        """Paper O1/O2 (Fig. 2): low mean concurrent tasks, with bursts."""
        linux = sweep["linux"]
        assert linux.task_count_mean < 5.0       # far below 40 cores
        assert linux.task_count_max >= 2          # bursts exist

    def test_baselines_never_oversubscribe(self, sweep):
        for name in ("linux", "least-aged"):
            assert sweep[name].oversub_frac_below == 0.0
            # all-active, few tasks -> normalized idle stays near 1.0
            assert sweep[name].idle_norm_percentiles[90] > 0.8

    def test_proposed_cuts_underutilization(self, sweep):
        """Paper Fig. 8: >=77% reduction of p90 normalized idle cores."""
        base = sweep["linux"].idle_norm_percentiles[90]
        ours = sweep["proposed"].idle_norm_percentiles[90]
        assert ours < base * (1 - 0.77)

    def test_proposed_oversubscription_below_10pct(self, sweep):
        """Paper: p1 of normalized idle cores stays above -0.1."""
        assert sweep["proposed"].idle_norm_percentiles[1] >= -0.1

    def test_proposed_reduces_mean_degradation(self, sweep):
        """Paper Fig. 6: age-halting cuts mean frequency degradation."""
        for p in (50, 99):
            assert (sweep["proposed"].mean_degradation_percentiles[p]
                    < sweep["linux"].mean_degradation_percentiles[p])
            assert (sweep["proposed"].mean_degradation_percentiles[p]
                    < sweep["least-aged"].mean_degradation_percentiles[p])

    def test_carbon_reduction_ballpark(self, sweep):
        """Paper Fig. 7: 37.67% @ p99 (49.01% @ p50). Accept 25-65% at
        our shorter horizon — the linear-ratio model is duration-robust
        but the idling opportunity grows with cluster underutilization."""
        est = carbon_comparison(sweep["linux"], sweep["proposed"], 99)
        assert 0.25 < est.reduction_frac < 0.65

    def test_service_quality_impact_bounded(self, sweep):
        """Paper: <10% impact on inference service quality."""
        base = sweep["linux"].p99_latency_s
        ours = sweep["proposed"].p99_latency_s
        assert ours < base * 1.10

    def test_determinism(self):
        cfg = ExperimentConfig(policy="proposed", rate_rps=40, duration_s=10,
                               seed=5)
        a = run_experiment(cfg)
        b = run_experiment(cfg)
        assert a.freq_cv_percentiles == b.freq_cv_percentiles
        assert a.completed == b.completed

    def test_legacy_signature_removed(self):
        """The pre-registry run_experiment(policy, **kw) shim is gone;
        a clear TypeError points at ExperimentConfig."""
        with pytest.raises(TypeError, match="ExperimentConfig"):
            run_experiment("proposed")

    def test_promoted_task_duration_recomputed(self):
        """ROADMAP modeling fix: a task promoted from the oversubscription
        queue must have its remaining duration recomputed from the
        promoted core's settled frequency — not keep the submission-time
        time-shared rate for its whole life."""
        from repro.sim.cluster import Machine, OVERSUB_SLOWDOWN
        from repro.sim.events import EventQueue
        from repro.sim.tasks import TASK_DURATIONS_S
        from repro.core import aging

        cfg = ExperimentConfig(num_cores=1, policy="linux", seed=4)
        q = EventQueue()
        m = Machine(0, cfg, q)
        mgr = m.manager
        work = TASK_DURATIONS_S["submit"]
        done_at = {}
        m.run_cpu_task("submit", lambda: done_at.setdefault("A", q.now))
        m.run_cpu_task("submit", lambda: done_at.setdefault("B", q.now))
        assert len(mgr.oversub_tasks) == 1
        s0 = float(mgr.frequencies(0.0)[0])      # fresh core speed
        t_a = work / s0                          # A's completion = B's promotion
        q.run_until(10.0)
        # B progressed at the time-shared rate until t_a, then finished at
        # the promoted core's settled (slightly degraded) frequency.
        waited_work = t_a * (s0 / OVERSUB_SLOWDOWN)
        dvth_at_ta = aging.dvth_after(
            mgr.params, 54.0, 1.0, t_a, 0.0)      # core 0 busy 0..t_a
        s_promoted = aging.frequency_scalar(
            mgr.params, float(mgr.f0[0]), dvth_at_ta)
        expected_b = t_a + (work - waited_work) / s_promoted
        assert done_at["A"] == pytest.approx(t_a, rel=1e-12)
        assert done_at["B"] == pytest.approx(expected_b, rel=1e-9)
        # strictly earlier than the old submission-time-rate semantics
        assert done_at["B"] < work / s0 * OVERSUB_SLOWDOWN
        assert m.running_cpu_tasks == 0 and not m._oversub_inflight

    def test_legacy_trace_shims_removed(self):
        """The deprecated `sim.trace` TraceConfig/generate/trace_stats
        shims are gone (ROADMAP: remove once nothing imports them);
        `repro.workloads` is the only workload spelling."""
        import repro.sim as sim
        for name in ("TraceConfig", "generate", "trace_stats"):
            assert not hasattr(sim, name), name
        with pytest.raises(ImportError):
            import repro.sim.trace  # noqa: F401
