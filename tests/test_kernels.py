"""Pallas kernel correctness: shape/dtype sweeps vs pure-jnp oracles,
executed in interpret mode on CPU (TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aging import DEFAULT_PARAMS
from repro.kernels.aging_update import ops as aging_ops
from repro.kernels.aging_update.ref import aging_update_ref
from repro.kernels.decode_attention import ops as dec_ops
from repro.kernels.decode_attention.ref import decode_attention_ref_explicit
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestAgingUpdateKernel:
    @pytest.mark.parametrize("n", [1, 7, 128, 1024, 5000])
    def test_matches_ref(self, n):
        rng = np.random.default_rng(n)
        dvth = jnp.asarray(rng.uniform(0, 0.05, n), jnp.float32)
        temp = jnp.asarray(rng.choice([48.0, 51.08, 54.0], n), jnp.float32)
        stress = jnp.asarray(rng.choice([0.0, 1.0], n), jnp.float32)
        tau = jnp.asarray(rng.uniform(0, 1e5, n), jnp.float32)
        out = aging_ops.advance_fleet(dvth, temp, stress, tau,
                                      DEFAULT_PARAMS, interpret=True)
        ref = aging_update_ref(dvth, temp, stress, tau, DEFAULT_PARAMS)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-9)

    def test_matches_simulator_math(self):
        """Kernel must agree with the event-loop scalar fast path."""
        from repro.core import aging
        rng = np.random.default_rng(0)
        n = 64
        dvth = rng.uniform(0, 0.05, n)
        temp = rng.choice([48.0, 51.08, 54.0], n)
        stress = rng.choice([0.0, 1.0], n)
        tau = rng.uniform(1.0, 1e5, n)
        out = aging_ops.advance_fleet(dvth, temp, stress, tau,
                                      DEFAULT_PARAMS, interpret=True)
        for i in range(n):
            a = float(aging.adf(DEFAULT_PARAMS, temp[i], stress[i]))
            want = aging.advance_dvth_scalar(DEFAULT_PARAMS, dvth[i], a,
                                             tau[i])
            assert float(out[i]) == pytest.approx(want, rel=1e-5)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,h,hkv,s,d", [
        (1, 4, 4, 128, 64),
        (2, 8, 2, 256, 64),      # GQA
        (1, 4, 1, 128, 128),     # MQA
        (2, 2, 2, 192, 64),      # padding path (192 % 128 != 0)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, b, h, hkv, s, d, dtype):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32).astype(dtype)
        out = fa_ops.attention_bhsd(q, k, v, causal=True, interpret=True)
        ref = fa_ops.attention_bhsd(q, k, v, causal=True, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **tol(dtype))

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.key(1), 3)
        b, h, s, d = 1, 2, 256, 64
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, h, d))
        v = jax.random.normal(ks[2], (b, s, h, d))
        out = fa_ops.attention_bhsd(q, k, v, causal=True, window=window,
                                    interpret=True)
        ref = fa_ops.attention_bhsd(q, k, v, causal=True, window=window,
                                    use_kernel=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_model_attention(self):
        """Kernel agrees with the model's own self_attention path."""
        from repro.models.attention import self_attention
        ks = jax.random.split(jax.random.key(2), 3)
        b, s, h, hkv, d = 2, 128, 8, 4, 64
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        out = fa_ops.attention_bhsd(q, k, v, causal=True, interpret=True)
        ref = self_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestDecodeAttentionKernel:
    @pytest.mark.parametrize("b,h,hkv,s,d", [
        (1, 4, 4, 512, 64),
        (4, 8, 2, 1024, 64),
        (2, 8, 1, 512, 128),
        (2, 4, 4, 640, 64),     # s % block_k != 0 padding path
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, h, hkv, s, d, dtype):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
        kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32).astype(dtype)
        vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32).astype(dtype)
        pos = jnp.asarray(np.random.default_rng(0).integers(1, s, b),
                          jnp.int32)
        out = dec_ops.decode_bhd(q, kc, vc, pos, interpret=True)
        ref = decode_attention_ref_explicit(q, kc, vc, pos)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **tol(dtype))

    def test_sliding_window(self):
        ks = jax.random.split(jax.random.key(1), 3)
        b, h, s, d, w = 2, 4, 512, 64, 128
        q = jax.random.normal(ks[0], (b, h, d))
        kc = jax.random.normal(ks[1], (b, s, h, d))
        vc = jax.random.normal(ks[2], (b, s, h, d))
        pos = jnp.asarray([300, 500], jnp.int32)
        out = dec_ops.decode_bhd(q, kc, vc, pos, window=w, interpret=True)
        ref = decode_attention_ref_explicit(q, kc, vc, pos, window=w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestSSDScanKernel:
    @pytest.mark.parametrize("b,l,h,p,n,chunk", [
        (1, 128, 2, 64, 128, 128),
        (2, 256, 4, 64, 64, 128),
        (1, 200, 2, 32, 64, 128),   # padding path
        (2, 512, 1, 64, 128, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, b, l, h, p, n, chunk, dtype):
        ks = jax.random.split(jax.random.key(0), 5)
        x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32).astype(dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        a_log = jax.random.normal(ks[2], (h,)) * 0.5
        bb = jax.random.normal(ks[3], (b, l, n), jnp.float32).astype(dtype)
        cc = jax.random.normal(ks[4], (b, l, n), jnp.float32).astype(dtype)
        out = ssd_ops.ssd(x, dt, a_log, bb, cc, chunk=chunk, interpret=True)
        ref = ssd_scan_ref(x, dt, a_log, bb, cc)
        rt = 4e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=rt, atol=rt * 5)

    def test_matches_chunked_jnp(self):
        """Kernel == the model's jnp chunked implementation exactly-ish."""
        from repro.models.mamba2 import ssd_chunked
        ks = jax.random.split(jax.random.key(7), 5)
        b, l, h, p, n = 2, 256, 2, 64, 64
        x = jax.random.normal(ks[0], (b, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        a_log = jax.random.normal(ks[2], (h,)) * 0.5
        bb = jax.random.normal(ks[3], (b, l, n))
        cc = jax.random.normal(ks[4], (b, l, n))
        out = ssd_ops.ssd(x, dt, a_log, bb, cc, chunk=128, interpret=True)
        ref, _ = ssd_chunked(x, dt, a_log, bb, cc, chunk=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
