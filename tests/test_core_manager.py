"""Integration tests for the CoreManager runtime (all three policies)."""
import numpy as np
import pytest

from repro.core import CoreManager, OVERSUBSCRIBED, aging
from repro.core.temperature import CState

PAPER_POLICIES = ("proposed", "linux", "least-aged")


def make(policy="proposed", n=16, seed=0, **kw):
    return CoreManager(n, policy=policy, rng=np.random.default_rng(seed), **kw)


class TestLifecycle:
    def test_assign_release_roundtrip(self):
        m = make()
        speed = m.assign(1, 0.0)
        assert 0.5 < speed <= 1.6
        core = m.core_of_task[1]
        assert m.task_of_core[core] == 1
        m.release(1, 2.0)
        assert m.task_of_core[core] == -1
        assert 1 not in m.core_of_task

    def test_oversubscription_when_saturated(self):
        m = make(n=4)
        for t in range(6):
            m.assign(t, 0.0)
        assert len(m.oversub_tasks) == 2
        assert m.metrics.oversub_assigns == 2
        # releasing a core promotes a waiting task
        m.release(0, 1.0)
        assert len(m.oversub_tasks) == 1

    def test_all_policies_roundtrip(self):
        for pol in PAPER_POLICIES:
            m = make(pol, n=8)
            for t in range(20):
                m.assign(t, float(t))
                m.release(t, float(t) + 0.5)
            assert m.task_of_core.max() == -1
            assert not m.oversub_tasks


class TestAgingAccounting:
    def test_busy_core_ages_more(self):
        m = make(n=4)
        m.assign(0, 0.0)
        core = m.core_of_task[0]
        m.release(0, 3600.0)
        m.settle_all(3600.0)
        others = [i for i in range(4) if i != core]
        assert m.dvth[core] > max(m.dvth[i] for i in others)

    def test_deep_idle_core_frozen(self):
        m = make(n=8)
        # no tasks -> periodic will idle most cores
        m.periodic(1.0)
        idle = np.flatnonzero(m.c_state == CState.DEEP_IDLE)
        assert idle.size > 0
        before = m.dvth[idle].copy()
        m.settle_all(3600.0)
        np.testing.assert_array_equal(m.dvth[idle], before)
        active = np.flatnonzero(m.c_state == CState.ACTIVE)
        assert (m.dvth[active] > 0).all()

    def test_settlement_order_independent(self):
        """Settling at intermediate times must not change the result."""
        m1, m2 = make(seed=1), make(seed=1)
        m1.assign(0, 0.0); m2.assign(0, 0.0)
        for t in np.linspace(10, 990, 17):
            m1.settle_all(float(t))
        m1.settle_all(1000.0); m2.settle_all(1000.0)
        np.testing.assert_allclose(m1.dvth, m2.dvth, rtol=1e-9)

    def test_frequencies_start_at_f0(self):
        m = make()
        np.testing.assert_allclose(m.frequencies(0.0), m.f0)


class TestSelectiveIdling:
    def test_idles_unused_cores(self):
        m = make(n=32)
        m.assign(0, 0.0)
        for k in range(8):
            m.periodic(float(k + 1))
        active = int((m.c_state == CState.ACTIVE).sum())
        assert active < 32  # working set shrank toward the 1 running task

    def test_wakes_on_burst(self):
        m = make(n=32, idling_period_s=0.5)
        for k in range(20):
            m.periodic(0.5 * (k + 1))  # shrink working set to ~0 tasks
        shrunk = int((m.c_state == CState.ACTIVE).sum())
        # burst of tasks
        t0 = 11.0
        for t in range(16):
            m.assign(100 + t, t0)
        for k in range(20):
            m.periodic(t0 + 0.5 * (k + 1))
        grown = int((m.c_state == CState.ACTIVE).sum())
        assert grown > shrunk
        assert grown >= 16  # enough cores for the running tasks

    def test_baselines_never_idle(self):
        for pol in ("linux", "least-aged"):
            m = make(pol, n=16)
            for k in range(10):
                m.periodic(float(k + 1))
            assert (m.c_state == CState.ACTIVE).all()


class TestEvenOutBehaviour:
    def test_proposed_beats_linux_on_cv(self):
        """Over a bursty synthetic load, the proposed policy should end
        with lower frequency CV and lower mean degradation than linux —
        the paper's Fig. 6 orderings at unit scale."""
        HOUR = 3600.0
        results = {}
        for pol in ("proposed", "linux"):
            m = make(pol, n=16, seed=42, idling_period_s=10.0)
            rng = np.random.default_rng(0)
            t, tid = 0.0, 0
            while t < 6 * HOUR:
                k = rng.poisson(2)
                ids = []
                for _ in range(k):
                    m.assign(tid, t); ids.append(tid); tid += 1
                for i in ids:
                    m.release(i, t + rng.uniform(1.0, 5.0))
                t += 10.0
                m.periodic(t)
            m.settle_all(6 * HOUR)
            results[pol] = (m.frequency_cv(), m.mean_frequency_degradation())
        assert results["proposed"][1] < results["linux"][1]


class TestMetrics:
    def test_idle_norm_sampled(self):
        m = make(n=8)
        m.assign(0, 0.0)
        m.periodic(1.0)
        assert len(m.metrics.idle_norm_samples) == 1
        v = m.metrics.idle_norm_samples[0]
        assert -1.0 <= v <= 1.0

    def test_snapshot_keys(self):
        m = make()
        snap = m.snapshot()
        assert set(snap) >= {"f0", "f", "dvth", "active", "cv",
                             "mean_degradation"}


class TestManagerInvariants:
    """Hypothesis property tests over random task schedules: the
    CoreManager must preserve its structural invariants under any
    interleaving of assigns/releases/periodics."""

    def test_random_schedule_invariants(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(seed=st.integers(0, 10_000),
               policy=st.sampled_from(PAPER_POLICIES))
        @settings(max_examples=25, deadline=None)
        def run(seed, policy):
            rng = np.random.default_rng(seed)
            m = make(policy, n=8, seed=seed)
            live = set()
            t = 0.0
            tid = 0
            for _ in range(60):
                t += float(rng.uniform(0.01, 0.5))
                act = rng.integers(0, 3)
                if act == 0:
                    m.assign(tid, t)
                    live.add(tid)
                    tid += 1
                elif act == 1 and live:
                    victim = live.pop()
                    m.release(victim, t)
                else:
                    m.periodic(t)
                # --- invariants ---
                n_assigned = int((m.task_of_core >= 0).sum())
                n_oversub = len(m.oversub_tasks)
                assert n_assigned + n_oversub == len(live)
                # a core never holds a task while deep idle
                idle = m.c_state == CState.DEEP_IDLE
                assert (m.task_of_core[idle] == -1).all()
                # dvth monotone: frequencies never exceed f0
                assert (m.frequencies(t) <= m.f0 + 1e-12).all()
                # core<->task maps are mutually consistent
                for task, core in m.core_of_task.items():
                    if core >= 0:
                        assert m.task_of_core[core] == task
                # baselines never deep idle
                if policy != "proposed":
                    assert not idle.any()

        run()

    def test_oversub_metric_monotone(self):
        m = make(n=2)
        for i in range(5):
            m.assign(i, 0.0)
        before = m.metrics.oversub_task_seconds
        for i in range(5):
            m.release(i, 1.0)
        assert m.metrics.oversub_task_seconds >= before


class TestOversubscription:
    def test_speed_bounded_by_fastest_busy_core(self):
        """An oversubscribed task time-shares busy cores, so its speed
        bound is the settled frequency of the fastest *busy* core — a
        pristine power-gated core must not inflate it (pre-PR-3 bug:
        np.max over all cores with stale dVth)."""
        m = make("proposed", n=8, seed=3)
        m.assign(0, 0.0)
        for k in range(30):                    # shrink the working set
            m.periodic(float(k + 1))
        assert (m.c_state == CState.DEEP_IDLE).any()
        now = 31.0
        # make a power-gated core the fleet's fastest by construction
        gated = int(np.flatnonzero(m.c_state == CState.DEEP_IDLE)[0])
        m.f0[gated] = m.f0.max() + 0.5
        # saturate every free working-set core, then oversubscribe
        tid = 1
        while ((m.c_state == CState.ACTIVE) & (m.task_of_core < 0)).any():
            m.assign(tid, now)
            tid += 1
        speed = m.assign(tid, now)
        assert m.core_of_task[tid] == OVERSUBSCRIBED
        freqs = aging.frequency(m.params, m.f0, m._settled_dvth(now))
        busy = m.task_of_core >= 0
        assert speed == float(freqs[busy].max())
        # the old all-cores bound would have picked the gated core
        assert speed < float(freqs.max())

    def test_speed_falls_back_to_fleet_max_when_nothing_busy(self):
        m = make("proposed", n=4, seed=0)
        m.c_state[:] = CState.DEEP_IDLE        # force an empty working set
        speed = m.assign(0, 1.0)
        assert m.core_of_task[0] == OVERSUBSCRIBED
        freqs = aging.frequency(m.params, m.f0, m._settled_dvth(1.0))
        assert speed == float(freqs.max())

    def test_oversub_seconds_counted_exactly_once(self):
        """Pin the T_oversub integral for a hand-built schedule: one task
        waits from t=0 to its promotion at t=2.5 (integral 2.5), a second
        waits 4.0 -> 4.6 (integral 0.6). The pre-PR-3 code added the
        periodic accrual AND the full wall time again at promotion."""
        m = make("linux", n=1, idling_period_s=1.0)
        m.assign(0, 0.0)                       # occupies the only core
        m.assign(1, 0.0)                       # oversubscribed
        m.periodic(1.0)
        assert m.metrics.oversub_task_seconds == pytest.approx(1.0)
        m.periodic(2.0)
        assert m.metrics.oversub_task_seconds == pytest.approx(2.0)
        m.release(0, 2.5)                      # promotes task 1 at 2.5
        assert m.core_of_task[1] == 0
        assert m.metrics.oversub_task_seconds == pytest.approx(2.5)
        m.release(1, 3.0)                      # on-core time is not oversub
        assert m.metrics.oversub_task_seconds == pytest.approx(2.5)
        m.assign(2, 4.0)
        m.assign(3, 4.0)                       # oversubscribed
        m.release(3, 4.6)                      # released while still waiting
        assert m.metrics.oversub_task_seconds == pytest.approx(3.1)
        m.periodic(5.0)                        # no waiting tasks -> no accrual
        assert m.metrics.oversub_task_seconds == pytest.approx(3.1)
