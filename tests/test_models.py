"""Model zoo tests: per-arch smoke (reduced configs, CPU), decode/prefill
consistency, SSD chunked-vs-naive oracle, MoE dispatch oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_smoke_config
from repro.models import Model
from repro.models import mamba2, moe
from repro.models.attention import causal_mask, decode_attention, self_attention

B, S = 2, 32


def make_batch(cfg, key, seq=S):
    n_front = cfg.frontend_tokens if cfg.frontend != "none" else 0
    batch = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size)}
    if n_front:
        batch["embeds"] = jax.random.normal(
            key, (B, n_front, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", all_arch_names())
class TestArchSmoke:
    """Assigned-architecture smoke tests: one forward/train step on CPU,
    asserting output shapes and no NaNs (reduced same-family configs)."""

    def test_train_step(self, arch):
        cfg = get_smoke_config(arch)
        model = Model(cfg)
        key = jax.random.key(0)
        params = model.init(key)
        batch = make_batch(cfg, key)
        loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
        assert loss.shape == ()
        assert jnp.isfinite(loss)
        assert 2.0 < float(loss) < 12.0  # ~ln(vocab) at init
        finite = jax.tree.map(lambda g: bool(jnp.isfinite(g).all()), grads)
        assert all(jax.tree.leaves(finite))

    def test_prefill_decode_shapes(self, arch):
        cfg = get_smoke_config(arch)
        model = Model(cfg)
        key = jax.random.key(1)
        params = model.init(key)
        batch = make_batch(cfg, key)
        n_front = cfg.frontend_tokens if cfg.frontend != "none" else 0
        max_len = S + n_front + 8
        logits, cache = jax.jit(
            lambda p, t, e: model.prefill(p, t, e, max_len=max_len)
        )(params, batch["tokens"], batch.get("embeds"))
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
        step = jax.jit(model.decode_step)
        for _ in range(3):
            logits, cache = step(params, cache, tok)
            assert logits.shape == (B, 1, cfg.padded_vocab)
            assert bool(jnp.isfinite(logits).all())
            tok = jnp.argmax(logits[:, :, :cfg.vocab_size], -1).astype(
                jnp.int32)

    def test_decode_matches_prefill(self, arch):
        """Teacher-forcing consistency: decoding token t with the cache of
        tokens [0..t) must reproduce the full-prefill logits at t."""
        cfg = get_smoke_config(arch)
        if cfg.num_experts:
            # capacity drops are sequence-length dependent; disable them so
            # teacher-forcing equivalence is exact (see moe.py docstring)
            cfg = dataclasses.replace(
                cfg, moe_capacity_factor=float(cfg.num_experts))
        model = Model(cfg)
        key = jax.random.key(2)
        params = model.init(key)
        batch = make_batch(cfg, key, seq=16)
        tokens = batch["tokens"]
        embeds = batch.get("embeds")
        n_front = cfg.frontend_tokens if cfg.frontend != "none" else 0

        prefix, rest = tokens[:, :12], tokens[:, 12:]
        full_logits, _ = jax.jit(
            lambda p, t, e: model.prefill(p, t, e, max_len=16 + n_front)
        )(params, tokens, embeds)
        _, cache = jax.jit(
            lambda p, t, e: model.prefill(p, t, e, max_len=16 + n_front)
        )(params, prefix, embeds)
        step = jax.jit(model.decode_step)
        logits = None
        for i in range(rest.shape[1]):
            logits, cache = step(params, cache, rest[:, i:i + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, 0], np.float32),
            rtol=0.08, atol=0.15)


class TestSSD:
    @pytest.mark.parametrize("l,chunk", [(32, 8), (64, 16), (128, 128)])
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_chunked_matches_reference(self, l, chunk, dtype):
        key = jax.random.key(0)
        b, h, p, n = 2, 4, 8, 16
        ks = jax.random.split(key, 5)
        dt = jnp.dtype(dtype)
        x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32).astype(dt)
        dts = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        a_log = jax.random.normal(ks[2], (h,)) * 0.5
        bb = jax.random.normal(ks[3], (b, l, n), jnp.float32).astype(dt)
        cc = jax.random.normal(ks[4], (b, l, n), jnp.float32).astype(dt)
        y_ref, h_ref = mamba2.ssd_reference(x, dts, a_log, bb, cc)
        y_chk, h_chk = mamba2.ssd_chunked(x, dts, a_log, bb, cc, chunk=chunk)
        tol = 2e-2 if dtype == "bfloat16" else 2e-4
        np.testing.assert_allclose(np.asarray(y_chk, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=tol, atol=tol * 5)
        np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_ref),
                                   rtol=1e-3, atol=1e-3)

    def test_decode_step_matches_scan(self):
        key = jax.random.key(1)
        b, l, h, p, n = 2, 8, 4, 8, 16
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, l, h, p))
        dts = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        a_log = jax.random.normal(ks[2], (h,)) * 0.5
        bb = jax.random.normal(ks[3], (b, l, n))
        cc = jax.random.normal(ks[4], (b, l, n))
        y_ref, h_ref = mamba2.ssd_reference(x, dts, a_log, bb, cc)
        state = jnp.zeros((b, h, p, n))
        ys = []
        for t in range(l):
            y, state = mamba2.ssd_decode_step(
                state, x[:, t], dts[:, t], a_log, bb[:, t], cc[:, t])
            ys.append(y)
        np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(state), np.asarray(h_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_initial_state_carried(self):
        """Chunked prefill then decode == one long reference scan."""
        key = jax.random.key(2)
        b, l, h, p, n = 1, 16, 2, 4, 8
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, l, h, p))
        dts = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        a_log = jnp.zeros((h,))
        bb = jax.random.normal(ks[3], (b, l, n))
        cc = jax.random.normal(ks[4], (b, l, n))
        y_all, h_all = mamba2.ssd_reference(x, dts, a_log, bb, cc)
        _, h_pre = mamba2.ssd_chunked(x[:, :12], dts[:, :12], a_log,
                                      bb[:, :12], cc[:, :12], chunk=4)
        state = h_pre
        for t in range(12, l):
            y, state = mamba2.ssd_decode_step(
                state, x[:, t], dts[:, t], a_log, bb[:, t], cc[:, t])
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_all[:, -1]),
                                   rtol=1e-4, atol=1e-4)


class TestMoE:
    def _setup(self, e=4, k=2, cf=8.0):
        cfg = dataclasses.replace(
            get_smoke_config("granite-moe-3b-a800m"),
            num_experts=e, experts_per_token=k, moe_capacity_factor=cf)
        key = jax.random.key(0)
        d, f = cfg.d_model, cfg.d_ff
        ks = jax.random.split(key, 5)
        params = {
            "router": jax.random.normal(ks[0], (d, e)) * 0.02,
            "w_gate": jax.random.normal(ks[1], (e, d, f)) * 0.02,
            "w_up": jax.random.normal(ks[2], (e, d, f)) * 0.02,
            "w_down": jax.random.normal(ks[3], (e, f, d)) * 0.02,
        }
        x = jax.random.normal(ks[4], (2, 16, d))
        return cfg, params, x

    def test_matches_dense_reference_at_high_capacity(self):
        """With capacity_factor large enough that nothing drops, the
        sorted-capacity dispatch must equal the dense oracle."""
        cfg, params, x = self._setup(cf=8.0)
        y, aux = moe.moe_ffn(x, params, cfg)
        y_ref, aux_ref = moe.moe_ffn_dense_reference(x, params, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)

    def test_capacity_drops_bounded(self):
        """At cf=1.0 some tokens may drop, but output stays finite and
        close in norm to the reference."""
        cfg, params, x = self._setup(cf=1.0)
        y, _ = moe.moe_ffn(x, params, cfg)
        assert bool(jnp.isfinite(y).all())
        y_ref, _ = moe.moe_ffn_dense_reference(x, params, cfg)
        # dropped fraction is small at init (balanced router)
        rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
        assert rel < 0.5

    def test_aux_loss_balanced_router_near_one(self):
        cfg, params, x = self._setup()
        probs = moe.router_probs(x, params["router"])
        _, idx = jax.lax.top_k(probs, cfg.experts_per_token)
        aux = moe.load_balance_loss(probs, idx, cfg.num_experts)
        assert 0.9 < float(aux) < 1.6  # ~1.0 when perfectly balanced

    def test_decode_single_token(self):
        cfg, params, _ = self._setup()
        x = jax.random.normal(jax.random.key(9), (4, 1, cfg.d_model))
        y, _ = moe.moe_ffn(x, params, cfg)
        y_ref, _ = moe.moe_ffn_dense_reference(x, params, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-5)


class TestAttentionCore:
    def test_sliding_window_mask(self):
        m = causal_mask(8, 8, window=3)
        assert bool(m[5, 5]) and bool(m[5, 4]) and bool(m[5, 3])
        assert not bool(m[5, 2])  # outside window
        assert not bool(m[2, 5])  # future

    def test_decode_matches_full_attention(self):
        key = jax.random.key(0)
        b, s, h, hkv, d = 2, 10, 4, 2, 16
        ks = jax.random.split(key, 3)
        q_all = jax.random.normal(ks[0], (b, s, h, d))
        k_all = jax.random.normal(ks[1], (b, s, hkv, d))
        v_all = jax.random.normal(ks[2], (b, s, hkv, d))
        full = self_attention(q_all, k_all, v_all, causal=True)
        out = decode_attention(q_all[:, -1:], k_all, v_all, jnp.int32(s))
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=1e-5, atol=1e-5)

    def test_decode_sliding_window(self):
        key = jax.random.key(1)
        b, s, h, d, w = 1, 12, 2, 8, 4
        ks = jax.random.split(key, 3)
        q_all = jax.random.normal(ks[0], (b, s, h, d))
        k_all = jax.random.normal(ks[1], (b, s, h, d))
        v_all = jax.random.normal(ks[2], (b, s, h, d))
        full = self_attention(q_all, k_all, v_all, causal=True, window=w)
        out = decode_attention(q_all[:, -1:], k_all, v_all, jnp.int32(s),
                               window=w)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=1e-5, atol=1e-5)
