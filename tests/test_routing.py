"""Tests for the pluggable cluster-routing subsystem (`repro.sim.routing`):
registry semantics, the jsq golden pin against the pre-registry
hard-coded Cluster behaviour, aging/carbon-aware routing effects, and
the policy x scenario x router sweep grid."""
import math

import numpy as np
import pytest

from repro.sim import (Cluster, ClusterRouter, ExperimentConfig, FleetView,
                       available_routers, canonical_router_name, collect,
                       get_router, register_router, run_experiment,
                       run_policy_sweep)
from repro.sim.cluster import (IB_LINK_BW_BPS, KV_BYTES_PER_TOKEN,
                               RequestState)
from repro.workloads import get_scenario

BUILTINS = ("jsq", "round-robin", "power-of-two", "least-aged-cpu",
            "carbon-greedy")


class TestRegistry:
    def test_builtins_present(self):
        assert set(BUILTINS) <= set(available_routers())

    def test_roundtrip_every_registered_name(self):
        for name in available_routers():
            r = get_router(name)
            assert isinstance(r, ClusterRouter)
            assert r.name == name

    def test_name_normalization(self):
        assert canonical_router_name("Least_Aged_CPU") == "least-aged-cpu"
        assert type(get_router("power_of_two")) is \
            type(get_router("power-of-two"))

    def test_unknown_name_lists_available(self):
        with pytest.raises(KeyError, match="jsq"):
            get_router("definitely-not-a-router")

    def test_fresh_instance_per_call(self):
        assert get_router("round-robin") is not get_router("round-robin")

    def test_router_opts_forwarded(self):
        assert get_router("least-aged-cpu", slack=5).slack == 5
        with pytest.raises(ValueError):
            get_router("least-aged-cpu", slack=-1)
        with pytest.raises(TypeError):
            get_router("jsq", bogus_opt=1)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_router("jsq")
            class Imposter(ClusterRouter):
                pass

    def test_config_canonicalizes_router(self):
        cfg = ExperimentConfig(router="Carbon_Greedy",
                               router_opts={"slack": 3})
        assert cfg.router == "carbon-greedy"
        assert cfg.router_options == {"slack": 3}
        assert cfg.with_router("jsq").router_opts == ()

    def test_out_of_range_router_index_rejected(self):
        @register_router("test-broken")
        class Broken(ClusterRouter):
            def select_prompt(self, fleet):
                return fleet.n_prompt  # off by one

        try:
            cluster = Cluster(ExperimentConfig(router="test-broken"))
            with pytest.raises(ValueError, match="outside"):
                cluster.submit_request(get_scenario(
                    "conversation-poisson").generate(
                        rate_rps=10, duration_s=1, seed=0)[0])
        finally:
            from repro.sim import routing
            routing._REGISTRY.pop("test-broken", None)


class _HardcodedJSQCluster(Cluster):
    """The exact request-placement code `Cluster` hard-coded before
    routing became pluggable — the golden reference for the jsq router."""

    def submit_request(self, req):
        rs = RequestState(req, remaining=req.output_tokens,
                          t_arrival=self.queue.now)
        pi = min(self.prompt_instances, key=lambda p: len(p.queue) + p.busy)
        pi.enqueue(rs, self._prefill_done)

    def _prefill_done(self, rs):
        ti = min(self.token_instances, key=lambda t: t.load)
        flow_s = rs.req.input_tokens * KV_BYTES_PER_TOKEN / IB_LINK_BW_BPS
        self.queue.schedule_in(flow_s, lambda: ti.receive_kv(rs))


class TestJSQGolden:
    @pytest.mark.parametrize("policy", ("proposed", "linux"))
    def test_jsq_bit_exact_vs_hardcoded(self, policy):
        """The jsq router must reproduce the pre-registry hard-coded
        placement bit-exactly: same completions, same latencies."""
        cfg = ExperimentConfig(policy=policy, rate_rps=50, duration_s=12,
                               seed=11, router="jsq")
        trace = get_scenario(cfg.scenario).generate(
            rate_rps=cfg.rate_rps, duration_s=cfg.duration_s, seed=cfg.seed)
        results = []
        for cls in (Cluster, _HardcodedJSQCluster):
            cluster = cls(cfg)
            cluster.run(list(trace), cfg.duration_s)
            results.append(sorted((rs.req.arrival_s,
                                   rs.t_first_token, rs.t_done)
                                  for rs in cluster.completed))
        assert len(results[0]) > 0
        assert results[0] == results[1]


class TestRoutingBehaviour:
    @pytest.mark.parametrize("router", BUILTINS)
    def test_completes_and_deterministic(self, router):
        cfg = ExperimentConfig(rate_rps=40, duration_s=8, seed=2,
                               router=router)
        a, b = run_experiment(cfg), run_experiment(cfg)
        assert a.completed > 0
        assert a.router == router
        assert a.mean_latency_s == b.mean_latency_s
        assert a.fleet_degradation_cv == b.fleet_degradation_cv

    def test_least_aged_cpu_lowers_fleet_degradation_cv(self):
        """The aging-aware router must even out cross-machine aging:
        lower CV of per-machine mean degradation than load-only jsq."""
        cfg = ExperimentConfig(rate_rps=60, duration_s=30, seed=0)
        jsq = run_experiment(cfg)
        aged = run_experiment(cfg.with_router("least-aged-cpu"))
        assert aged.fleet_degradation_cv < jsq.fleet_degradation_cv

    def test_round_robin_cycles(self):
        r = get_router("round-robin")

        class _Fleet:
            n_prompt, n_token = 3, 4

        picks = [r.select_prompt(_Fleet()) for _ in range(6)]
        assert picks == [0, 1, 2, 0, 1, 2]
        assert [r.select_token(_Fleet()) for _ in range(5)] == [0, 1, 2, 3, 0]

    def test_fleet_view_read_only_surface(self):
        cluster = Cluster(ExperimentConfig())
        fleet = cluster.fleet
        assert isinstance(fleet, FleetView)
        assert fleet.n_prompt == 5 and fleet.n_token == 17
        assert fleet.prompt_depths().shape == (5,)
        assert fleet.token_loads().shape == (17,)
        snaps = fleet.token_aging()
        assert len(snaps) == 17
        s = snaps[0]
        assert s.mean_degradation == 0.0  # fresh fleet at t=0
        assert s.active_cores == cluster.cfg.num_cores
        assert s.mean_f0 > 0 and s.freq_cv > 0


class TestFleetMetrics:
    def test_per_machine_carbon_aggregation(self):
        m = run_experiment(ExperimentConfig(rate_rps=40, duration_s=8,
                                            seed=1))
        assert len(m.per_machine_carbon) == 22
        total = sum(e.yearly_kgco2eq for e in m.per_machine_carbon)
        assert m.fleet_yearly_kgco2eq == pytest.approx(total)
        assert all(e.yearly_kgco2eq > 0 for e in m.per_machine_carbon)
        assert m.fleet_degradation_cv > 0

    def test_starved_run_reports_nan_not_perfect_service(self):
        """No completions must yield NaN latencies and completed=0 — a
        starved config can never rank as winning a latency sweep."""
        cfg = ExperimentConfig(duration_s=5.0)
        cluster = Cluster(cfg)
        cluster.run([], 5.0)
        m = collect(cluster, cfg)
        assert m.completed == 0
        assert math.isnan(m.mean_latency_s)
        assert math.isnan(m.p99_latency_s)


class TestSweepGrid:
    def test_policy_scenario_router_grid(self):
        """The ROADMAP's third experiment axis: (policy, scenario,
        router)-keyed grids from one call."""
        cfg = ExperimentConfig(rate_rps=30, duration_s=6, seed=0)
        grid = run_policy_sweep(
            cfg, policies=("linux", "proposed"),
            scenarios=("conversation-poisson", "conversation-mmpp"),
            routers=("jsq", "least-aged-cpu"))
        assert len(grid) == 8
        for (policy, scenario, router), m in grid.items():
            assert m.policy == policy
            assert m.scenario == scenario
            assert m.router == router
            assert m.completed > 0

    def test_policy_router_grid_without_scenarios(self):
        grid = run_policy_sweep(
            ExperimentConfig(rate_rps=30, duration_s=6, seed=0),
            policies=("linux",), routers=("jsq", "round-robin"))
        assert set(grid) == {("linux", "jsq"), ("linux", "round-robin")}

    def test_single_axis_keys_unchanged(self):
        """routers=None preserves the PR-1/PR-2 key shapes."""
        cfg = ExperimentConfig(rate_rps=30, duration_s=6, seed=0)
        by_policy = run_policy_sweep(cfg, policies=("linux",))
        assert set(by_policy) == {"linux"}
        by_ps = run_policy_sweep(cfg, policies=("linux",),
                                 scenarios=("conversation-poisson",))
        assert set(by_ps) == {("linux", "conversation-poisson")}
