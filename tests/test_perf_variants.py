"""§Perf optimization variants must be EXACT (up to fp tolerance) against
the baseline formulations — correctness gates for the hillclimb."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.models.attention import chunked_self_attention, self_attention


class TestChunkedAttention:
    @pytest.mark.parametrize("window", [0, 24])
    @pytest.mark.parametrize("chunk", [16, 64])
    def test_matches_naive(self, window, chunk):
        ks = jax.random.split(jax.random.key(0), 3)
        b, s, h, hkv, d = 2, 96, 4, 2, 32
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, hkv, d))
        v = jax.random.normal(ks[2], (b, s, hkv, d))
        naive = self_attention(q, k, v, causal=True, window=window)
        chunked = chunked_self_attention(q, k, v, causal=True,
                                         window=window, chunk=chunk)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                                   rtol=2e-5, atol=2e-5)

    def test_differentiable(self):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 32, 2, 16))
        k = jax.random.normal(ks[1], (1, 32, 2, 16))
        v = jax.random.normal(ks[2], (1, 32, 2, 16))

        def loss_naive(q):
            return jnp.sum(self_attention(q, k, v) ** 2)

        def loss_chunked(q):
            return jnp.sum(chunked_self_attention(q, k, v, chunk=8) ** 2)

        g1 = jax.grad(loss_naive)(q)
        g2 = jax.grad(loss_chunked)(q)
        np.testing.assert_allclose(np.asarray(g2), np.asarray(g1),
                                   rtol=1e-4, atol=1e-4)

    def test_model_loss_identical(self):
        cfg = get_smoke_config("llama3-8b")
        cfg_c = dataclasses.replace(cfg, attn_chunk=16)
        key = jax.random.key(2)
        params = Model(cfg).init(key)
        batch = {"tokens": jax.random.randint(key, (2, 48), 0,
                                              cfg.vocab_size)}
        l1 = jax.jit(Model(cfg).loss)(params, batch)
        l2 = jax.jit(Model(cfg_c).loss)(params, batch)
        assert float(l1) == pytest.approx(float(l2), rel=2e-3)


class TestMLAAbsorbed:
    def test_decode_matches_naive(self):
        cfg = get_smoke_config("minicpm3-4b")
        cfg_a = dataclasses.replace(cfg, mla_absorb=True)
        key = jax.random.key(0)
        model = Model(cfg)
        model_a = Model(cfg_a)
        params = model.init(key)
        tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
        _, cache = jax.jit(
            lambda p, t: model.prefill(p, t, None, max_len=16))(params,
                                                                tokens)
        tok = jnp.asarray([[3], [7]], jnp.int32)
        logits_naive, c1 = jax.jit(model.decode_step)(params, cache, tok)
        logits_abs, c2 = jax.jit(model_a.decode_step)(params, cache, tok)
        np.testing.assert_allclose(
            np.asarray(logits_abs[..., :cfg.vocab_size], np.float32),
            np.asarray(logits_naive[..., :cfg.vocab_size], np.float32),
            rtol=0.05, atol=0.05)
        # layer>0 latents inherit bf16 rounding differences from the
        # absorbed attention in earlier layers — tolerance, not equality
        np.testing.assert_allclose(np.asarray(c2["latent"], np.float32),
                                   np.asarray(c1["latent"], np.float32),
                                   rtol=0.05, atol=0.02)

    def test_multi_step_consistency(self):
        cfg = get_smoke_config("minicpm3-4b")
        cfg_a = dataclasses.replace(cfg, mla_absorb=True)
        key = jax.random.key(1)
        model, model_a = Model(cfg), Model(cfg_a)
        params = model.init(key)
        tokens = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
        _, cache_n = jax.jit(
            lambda p, t: model.prefill(p, t, None, max_len=16))(params,
                                                                tokens)
        cache_a = jax.tree.map(lambda x: x, cache_n)
        step_n = jax.jit(model.decode_step)
        step_a = jax.jit(model_a.decode_step)
        tok_n = tok_a = jnp.asarray([[5]], jnp.int32)
        for _ in range(4):
            ln, cache_n = step_n(params, cache_n, tok_n)
            la, cache_a = step_a(params, cache_a, tok_a)
            tok_n = jnp.argmax(ln[..., :cfg.vocab_size], -1).astype(
                jnp.int32)
            tok_a = jnp.argmax(la[..., :cfg.vocab_size], -1).astype(
                jnp.int32)
            assert int(tok_n[0, 0]) == int(tok_a[0, 0])


class TestSeqParallelNoMesh:
    def test_identity_on_cpu(self):
        """Without a mesh the constraint is a no-op: loss unchanged."""
        cfg = get_smoke_config("llama3-8b")
        cfg_sp = dataclasses.replace(cfg, seq_parallel=True)
        key = jax.random.key(0)
        params = Model(cfg).init(key)
        batch = {"tokens": jax.random.randint(key, (2, 32), 0,
                                              cfg.vocab_size)}
        l1 = jax.jit(Model(cfg).loss)(params, batch)
        l2 = jax.jit(Model(cfg_sp).loss)(params, batch)
        assert float(l1) == float(l2)


class TestLengthShardedDecode:
    def test_matches_naive_under_mesh(self):
        """Exercise the REAL length-sharded math (not the no-mesh
        fallback) under a trivial 1x1 mesh."""
        import jax
        from repro.models.attention import (decode_attention,
                                            decode_attention_length_sharded)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ks = jax.random.split(jax.random.key(0), 3)
        b, s, h, hkv, d = 2, 64, 8, 2, 16
        q = jax.random.normal(ks[0], (b, 1, h, d))
        kc = jax.random.normal(ks[1], (b, s, hkv, d))
        vc = jax.random.normal(ks[2], (b, s, hkv, d))
        pos = jnp.asarray([40, 64], jnp.int32)
        with jax.set_mesh(mesh):
            out = jax.jit(lambda *a: decode_attention_length_sharded(*a))(
                q, kc, vc, pos)
        ref = decode_attention(q, kc, vc, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [0, 16])
    def test_window_and_scalar_pos(self, window):
        import jax
        from repro.models.attention import (decode_attention,
                                            decode_attention_length_sharded)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ks = jax.random.split(jax.random.key(1), 3)
        b, s, h, d = 1, 48, 4, 8
        q = jax.random.normal(ks[0], (b, 1, h, d))
        kc = jax.random.normal(ks[1], (b, s, h, d))
        vc = jax.random.normal(ks[2], (b, s, h, d))
        pos = jnp.int32(37)
        with jax.set_mesh(mesh):
            out = jax.jit(lambda *a: decode_attention_length_sharded(
                *a, window=window))(q, kc, vc, pos)
        ref = decode_attention(q, kc, vc, pos, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestSWARingBuffer:
    def test_ring_matches_full_window_decode(self):
        """Ring-buffer decode must produce the same logits as the naive
        full-length cache with window masking, across many steps
        (including wrap-around)."""
        cfg = dataclasses.replace(get_smoke_config("mixtral-8x22b"),
                                  sliding_window=16)
        cfg_r = dataclasses.replace(cfg, swa_ring=True)
        model, model_r = Model(cfg), Model(cfg_r)
        key = jax.random.key(0)
        params = model.init(key)
        tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
        _, cache = jax.jit(
            lambda p, t: model.prefill(p, t, None, max_len=48))(params,
                                                                tokens)
        _, cache_r = jax.jit(
            lambda p, t: model_r.prefill(p, t, None, max_len=48))(params,
                                                                  tokens)
        assert cache_r["k"].shape[2] == 16  # ring sized to the window
        step = jax.jit(model.decode_step)
        step_r = jax.jit(model_r.decode_step)
        tok = tok_r = jnp.asarray([[3], [9]], jnp.int32)
        for i in range(24):  # runs past the wrap-around at pos 16
            l1, cache = step(params, cache, tok)
            l2, cache_r = step_r(params, cache_r, tok_r)
            np.testing.assert_allclose(
                np.asarray(l2[..., :cfg.vocab_size], np.float32),
                np.asarray(l1[..., :cfg.vocab_size], np.float32),
                rtol=0.05, atol=0.05, err_msg=f"step {i}")
            tok = jnp.argmax(l1[..., :cfg.vocab_size], -1).astype(jnp.int32)
            tok_r = jnp.argmax(l2[..., :cfg.vocab_size], -1).astype(
                jnp.int32)
            np.testing.assert_array_equal(np.asarray(tok),
                                          np.asarray(tok_r))

    def test_short_prefill_pad_path(self):
        cfg = dataclasses.replace(get_smoke_config("mixtral-8x22b"),
                                  sliding_window=64, swa_ring=True)
        model = Model(cfg)
        params = model.init(jax.random.key(1))
        tokens = jax.random.randint(jax.random.key(2), (1, 8), 0,
                                    cfg.vocab_size)
        logits, cache = jax.jit(
            lambda p, t: model.prefill(p, t, None, max_len=128))(params,
                                                                 tokens)
        assert cache["k"].shape[2] == 64
        assert bool(jnp.isfinite(logits).all())
