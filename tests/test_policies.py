"""Tests for the pluggable policy registry, the ExperimentConfig API,
and post-refactor equivalence with the pre-registry CoreManager.

GOLD holds seeded `ExperimentMetrics` captured from the pre-refactor
enum/if-elif implementation (policy hardcoded inside CoreManager); the
refactored proposed/linux/least-aged policies must reproduce them
within 1e-9.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (CoreManager, CorePolicy, OVERSUBSCRIBED,
                        available_policies, get_policy, register_policy)
from repro.core.aging import (AgingParams, _adf_unscaled,
                              adf_unscaled_cached, solve_k)
from repro.core.policies import canonical_policy_name
from repro.sim import ExperimentConfig, run_experiment, run_policy_sweep

ALL_POLICIES = ("proposed", "linux", "least-aged", "round-robin",
                "aging-greedy")

# Captured from the seed (pre-refactor) implementation:
#   run_experiment(Policy.<P>, num_cores=40, rate_rps=50, duration_s=15,
#                  seed=7)
# `proposed` re-captured twice since: after the PR-3 oversubscription
# bugfix (speed bounded by the fastest *busy* core), and after the PR-4
# promoted-task fix (a task promoted from the oversubscription queue now
# has its remaining duration recomputed from the promoted core's settled
# frequency instead of keeping the submission-time time-shared rate, so
# promoted tasks finish earlier and free cores sooner); linux/least-aged
# never oversubscribe and still match the pre-refactor capture
# bit-exactly — they pin that neither fix nor the PR-4 fast-path rewrite
# perturbs the non-oversubscribed trajectory.
GOLD = {
    "proposed": {
        "freq_cv_p50": 0.03956814163709267,
        "deg_p50": 0.011173444895245375,
        "deg_p99": 0.01137506880964343,
        "idle_p90": 0.075,
        "mean_latency_s": 6.84847392093811,
        "completed": 186,
    },
    "linux": {
        "freq_cv_p50": 0.0399780035035772,
        "deg_p50": 0.01699604059754733,
        "deg_p99": 0.017512041999825097,
        "idle_p90": 1.0,
        "mean_latency_s": 6.845652774348468,
        "completed": 192,
    },
    "least-aged": {
        "freq_cv_p50": 0.03997596950427362,
        "deg_p50": 0.016996332326598446,
        "deg_p99": 0.017512094707309137,
        "idle_p90": 1.0,
        "mean_latency_s": 6.695974653777007,
        "completed": 192,
    },
}


class TestRegistry:
    def test_roundtrip_every_registered_name(self):
        for name in available_policies():
            p = get_policy(name)
            assert isinstance(p, CorePolicy)
            assert p.name == name
            # and a manager can actually run a task lifecycle with it
            m = CoreManager(4, policy=name, rng=np.random.default_rng(0))
            m.assign(0, 0.0)
            m.release(0, 1.0)
            m.periodic(2.0)
            assert m.metrics.assigns == 1

    def test_builtins_present(self):
        assert set(ALL_POLICIES) <= set(available_policies())

    def test_unknown_name_lists_available(self):
        with pytest.raises(KeyError, match="proposed"):
            get_policy("definitely-not-a-policy")

    def test_name_normalization(self):
        assert canonical_policy_name("Least_Aged") == "least-aged"
        assert type(get_policy("least_aged")) is type(get_policy("least-aged"))

    def test_fresh_instance_per_call(self):
        assert get_policy("linux") is not get_policy("linux")

    def test_policy_opts_forwarded(self):
        p = get_policy("linux", stickiness=0.7)
        assert p.stickiness == 0.7
        with pytest.raises(TypeError):
            get_policy("proposed", bogus_opt=1)

    def test_custom_policy_registers_and_runs(self):
        @register_policy("test-first-free")
        class FirstFree(CorePolicy):
            def select_core(self, view):
                free = np.flatnonzero(view.active_mask & ~view.assigned_mask)
                return int(free[0]) if free.size else -1

        try:
            m = CoreManager(4, policy="test-first-free",
                            rng=np.random.default_rng(0))
            assert m.assign(0, 0.0) > 0
            assert m.core_of_task[0] == 0
        finally:
            from repro.core.policies import registry
            registry._REGISTRY.pop("test-first-free", None)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_policy("linux")
            class Imposter(CorePolicy):
                pass


class TestCoreViewIsolation:
    def test_view_arrays_read_only(self):
        m = CoreManager(8, policy="proposed", rng=np.random.default_rng(0))
        view = m.view
        for arr in (view.dvth, view.f0, view.idle_history, view.cum_work,
                    view.dvth_now()):
            with pytest.raises(ValueError):
                arr[...] = 1.0

    def test_bad_idle_correction_rejected_before_mutation(self):
        """A policy returning a busy core in to_idle must fail atomically:
        no partial c_state / idle-history mutation."""
        from repro.core import IdleCorrection

        class BadIdler(CorePolicy):
            def select_core(self, view):
                return 0

            def periodic(self, view):
                # core 1 is free (idleable), core 0 runs a task
                return IdleCorrection(to_idle=np.array([1, 0]))

        m = CoreManager(4, policy=BadIdler(), rng=np.random.default_rng(0))
        m.assign(0, 0.0)
        c_state = m.c_state.copy()
        hist = m.idle_history.copy()
        with pytest.raises(ValueError, match="run tasks"):
            m.periodic(1.0)
        np.testing.assert_array_equal(m.c_state, c_state)
        np.testing.assert_array_equal(m.idle_history, hist)

    def test_instance_plus_name_only_kwargs_rejected(self):
        with pytest.raises(TypeError, match="policy_opts"):
            CoreManager(4, policy=get_policy("linux"),
                        policy_opts={"stickiness": 0.7})

    def test_legacy_linux_stickiness_kwarg_removed(self):
        """The PR-1 compatibility kwarg is gone; options travel via
        policy_opts only."""
        with pytest.raises(TypeError):
            CoreManager(4, policy="linux", linux_stickiness=0.7)

    def test_dvth_now_settles_without_mutation(self):
        m = CoreManager(4, policy="linux", rng=np.random.default_rng(0))
        m.assign(0, 0.0)
        m.now = 3600.0
        before = m.dvth.copy()
        settled = m.view.dvth_now()
        assert (settled >= before).all() and settled.sum() > before.sum()
        np.testing.assert_array_equal(m.dvth, before)  # no mutation
        m.settle_all(3600.0)
        np.testing.assert_allclose(m.dvth, settled, rtol=1e-12)


class TestNewPolicies:
    def test_round_robin_cycles_cores(self):
        m = CoreManager(4, policy="round-robin",
                        rng=np.random.default_rng(0))
        cores = []
        for t in range(8):
            m.assign(t, float(t))
            cores.append(m.core_of_task[t])
            m.release(t, float(t) + 0.25)
        assert cores == [0, 1, 2, 3, 0, 1, 2, 3]

    def test_aging_greedy_picks_least_degraded(self):
        m = CoreManager(4, policy="aging-greedy",
                        rng=np.random.default_rng(0))
        # Work core 2 hard so its settled dVth leads; the next pick must
        # avoid it... but all other cores idle-aged equally, so instead
        # check the argmin property directly.
        m.assign(0, 0.0)
        first = m.core_of_task[0]
        m.release(0, 7200.0)
        m.assign(1, 7200.0)
        second = m.core_of_task[1]
        assert second != first  # the worked core is now the most aged
        settled = m.view.dvth_now()
        free = m.view.active_mask & (m.task_of_core < 0)
        assert settled[second] <= settled[free].min() + 1e-18

    def test_new_policies_never_idle(self):
        for name in ("round-robin", "aging-greedy"):
            m = CoreManager(16, policy=name, rng=np.random.default_rng(0))
            for k in range(10):
                m.periodic(float(k + 1))
            assert (m.c_state == 0).all()

    def test_oversubscription_roundtrip(self):
        for name in ("round-robin", "aging-greedy"):
            m = CoreManager(2, policy=name, rng=np.random.default_rng(0))
            for t in range(4):
                m.assign(t, 0.0)
            assert len(m.oversub_tasks) == 2
            assert m.core_of_task[3] == OVERSUBSCRIBED
            for t in range(4):
                m.release(t, 1.0)
            assert not m.oversub_tasks


class TestEquivalenceWithPreRefactor:
    @pytest.fixture(scope="class")
    def metrics(self):
        cfg = ExperimentConfig(num_cores=40, rate_rps=50, duration_s=15,
                               seed=7)
        return {name: run_experiment(cfg.with_policy(name))
                for name in GOLD}

    @pytest.mark.parametrize("name", sorted(GOLD))
    def test_seeded_metrics_match(self, metrics, name):
        m, gold = metrics[name], GOLD[name]
        assert m.freq_cv_percentiles[50] == pytest.approx(
            gold["freq_cv_p50"], abs=1e-9)
        assert m.mean_degradation_percentiles[50] == pytest.approx(
            gold["deg_p50"], abs=1e-9)
        assert m.mean_degradation_percentiles[99] == pytest.approx(
            gold["deg_p99"], abs=1e-9)
        assert m.idle_norm_percentiles[90] == pytest.approx(
            gold["idle_p90"], abs=1e-9)
        assert m.mean_latency_s == pytest.approx(
            gold["mean_latency_s"], abs=1e-9)
        assert m.completed == gold["completed"]

    def test_spelling_construction_matches_canonical(self):
        runs = {}
        for pol in ("proposed", "PROPOSED"):
            m = CoreManager(8, policy=pol, rng=np.random.default_rng(3))
            for t in range(30):
                m.assign(t, float(t))
                m.release(t, float(t) + 0.4)
                m.periodic(float(t) + 1.0)
            m.settle_all(40.0)
            runs[str(pol)] = m.dvth.copy()
        a, b = runs.values()
        np.testing.assert_array_equal(a, b)


class TestPolicySweep:
    def test_sweep_by_string_names_alone(self):
        sweep = run_policy_sweep(
            ExperimentConfig(num_cores=40, rate_rps=40, duration_s=10,
                             seed=3),
            policies=ALL_POLICIES)
        assert set(sweep) == set(ALL_POLICIES)
        for name, m in sweep.items():
            assert m.policy == name
            assert m.completed > 0
        # only the proposed technique shrinks the working set
        assert sweep["proposed"].idle_norm_percentiles[90] < 0.9
        for name in ("linux", "least-aged", "round-robin", "aging-greedy"):
            assert sweep[name].idle_norm_percentiles[90] == pytest.approx(1.0)

    def test_sweep_keeps_opts_for_matching_policy_any_spelling(self):
        """A non-canonical sweep spelling of cfg.policy must not drop
        cfg.policy_opts (names are normalized before matching)."""
        cfg = ExperimentConfig(policy="linux",
                               policy_opts={"stickiness": 0.9},
                               rate_rps=40, duration_s=5, seed=2)
        direct = run_experiment(cfg)
        swept = run_policy_sweep(cfg, policies=("Linux",))
        assert set(swept) == {"linux"}
        assert (swept["linux"].freq_cv_percentiles
                == direct.freq_cv_percentiles)


class TestExperimentConfig:
    def test_frozen(self):
        cfg = ExperimentConfig()
        with pytest.raises(dataclasses.FrozenInstanceError):
            cfg.num_cores = 8

    def test_hashable_and_replace(self):
        cfg = ExperimentConfig(policy="linux",
                               policy_opts={"stickiness": 0.5})
        assert cfg.policy_options == {"stickiness": 0.5}
        assert hash(cfg) == hash(cfg.replace())
        assert cfg.replace(seed=9).seed == 9
        assert cfg.with_policy("proposed").policy_opts == ()

    def test_opts_order_normalized(self):
        """Equal logical opts must compare/hash equal whatever form or
        order they were supplied in (configs key caches)."""
        a = ExperimentConfig(policy_opts=(("b", 2), ("a", 1)))
        b = ExperimentConfig(policy_opts={"a": 1, "b": 2})
        assert a == b and hash(a) == hash(b)

    def test_normalizes_spelling(self):
        assert ExperimentConfig(policy="Least_Aged").policy == "least-aged"
        assert ExperimentConfig(policy="Round_Robin").policy == "round-robin"
        assert (ExperimentConfig(scenario="Conversation_MMPP").scenario
                == "conversation-mmpp")

    def test_validation(self):
        with pytest.raises(ValueError):
            ExperimentConfig(num_cores=0)
        with pytest.raises(ValueError):
            ExperimentConfig(n_prompt=0)

    def test_config_plus_kwargs_rejected(self):
        with pytest.raises(TypeError):
            run_experiment(ExperimentConfig(), num_cores=8)


class TestAdfCacheKeying:
    def test_keyed_on_values_not_identity(self):
        """id(params) reuse after GC must never serve stale factors: the
        cache is keyed on the frozen params fields, so distinct values
        always compute distinct factors (and equal values may share)."""
        for e0 in (0.15, 0.1897, 0.25):
            p = solve_k(AgingParams(E0=e0))
            got = adf_unscaled_cached(p, 54.0, 1.0)
            assert got == pytest.approx(_adf_unscaled(p, 54.0, 1.0),
                                        rel=1e-12)
            del p  # allow id reuse for the next iteration — must not alias

    def test_equal_params_share_cache_entry(self):
        p1 = solve_k(AgingParams())
        p2 = solve_k(AgingParams())
        assert p1 is not p2 and p1 == p2
        assert (adf_unscaled_cached(p1, 54.0, 1.0)
                == adf_unscaled_cached(p2, 54.0, 1.0))

    def test_cached_matches_uncached_for_nonunit_stress(self):
        """The pre-PR-3 manager-local cache dropped the stress**n factor
        (benign only because STRESS_ACTIVE == 1.0); the relocated cache
        must agree with `_adf_unscaled` for any stress level."""
        p = solve_k(AgingParams())
        for stress in (0.25, 0.5, 0.75, 1.0, 2.0):
            for t_c in (48.0, 51.08, 54.0):
                assert (adf_unscaled_cached(p, t_c, stress)
                        == _adf_unscaled(p, t_c, stress))
        assert adf_unscaled_cached(p, 54.0, 0.5) != \
            adf_unscaled_cached(p, 54.0, 1.0)
        assert adf_unscaled_cached(p, 54.0, 0.0) == 0.0
