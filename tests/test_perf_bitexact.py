"""Golden-pinned bit-exactness suite for the PR-4 simulator fast path.

GOLD holds full `ExperimentMetrics` captured from the PRE-optimization
implementation (promoted-task modeling fix applied, hot paths still the
original per-event numpy dispatch). The optimized simulator — heap-based
core selection, incremental idle scores, busy-subset oversubscription
bound, fleet-batched settlement, deque queues, O(1) decode-completion
detection — must reproduce every number. Values were verified bitwise
(repr-identical) against the pre-optimization code on the capture
machine; the pinned tolerance of 1e-12 (vs the repo's usual 1e-9) only
absorbs cross-platform libm ulps.

Also pins `run_policy_sweep(parallel=N)` == the serial sweep on a
3x2x2 grid: per-cell seeding lives entirely in each cell's frozen
config, so worker processes reproduce the serial results exactly.
"""
import math

import numpy as np
import pytest

from repro.sim import ExperimentConfig, run_experiment, run_policy_sweep

TOL = 1e-12

CELLS = {
    "proposed": ExperimentConfig(num_cores=40, rate_rps=50, duration_s=15,
                                 seed=7),
    "linux": ExperimentConfig(policy="linux", num_cores=40, rate_rps=50,
                              duration_s=15, seed=7),
    "least-aged": ExperimentConfig(policy="least-aged", num_cores=40,
                                   rate_rps=50, duration_s=15, seed=7),
    # second cell exercises a non-default scenario + aging-aware router
    "proposed-mmpp-aged": ExperimentConfig(
        policy="proposed", scenario="conversation-mmpp",
        router="least-aged-cpu", rate_rps=40, duration_s=10, seed=3),
}

GOLD = {
    "proposed": {
        "freq_cv_percentiles": {
            1: 0.028915308966174516, 25: 0.03392200273075075,
            50: 0.03956814163709267, 75: 0.04474988224676765,
            90: 0.052577631345300545, 99: 0.05651684584460714},
        "mean_degradation_percentiles": {
            1: 0.01078339183319639, 25: 0.010927154879033412,
            50: 0.011173444895245375, 75: 0.011263866496560946,
            90: 0.011327687356627696, 99: 0.01137506880964343},
        "idle_norm_percentiles": {
            1: -0.075, 25: 0.0, 50: 0.025, 75: 0.025, 90: 0.075, 99: 1.0},
        "oversub_frac_below": 0.0030303030303030303,
        "task_count_mean": 0.45181818181818184,
        "task_count_max": 12,
        "mean_latency_s": 6.84847392093811,
        "p99_latency_s": 12.96702192419078,
        "completed": 186,
        "fleet_degradation_cv": 0.015017404804864014,
        "fleet_yearly_kgco2eq": 1256.5360812461565,
    },
    "linux": {
        "freq_cv_percentiles": {
            1: 0.02896339775131182, 25: 0.03374273790198157,
            50: 0.0399780035035772, 75: 0.04472689532154083,
            90: 0.05243541176807128, 99: 0.05643424861071352},
        "mean_degradation_percentiles": {
            1: 0.01653061560876518, 25: 0.016838715684914005,
            50: 0.01699604059754733, 75: 0.017350928891948624,
            90: 0.017427161587444836, 99: 0.017512041999825097},
        "idle_norm_percentiles": {
            1: 0.925, 25: 0.975, 50: 1.0, 75: 1.0, 90: 1.0, 99: 1.0},
        "oversub_frac_below": 0.0,
        "task_count_mean": 0.41393939393939394,
        "task_count_max": 6,
        "mean_latency_s": 6.845652774348468,
        "p99_latency_s": 13.281451920953165,
        "completed": 192,
        "fleet_degradation_cv": 0.015193261583642674,
        "fleet_yearly_kgco2eq": 1927.6294411313045,
    },
    "least-aged": {
        "freq_cv_percentiles": {
            1: 0.0289632953332969, 25: 0.03374211247600363,
            50: 0.03997596950427362, 75: 0.044725516511392165,
            90: 0.052435684680154374, 99: 0.05643286007969888},
        "mean_degradation_percentiles": {
            1: 0.016530537087270432, 25: 0.016838506294655713,
            50: 0.016996332326598446, 75: 0.017350977766074534,
            90: 0.017427158691634005, 99: 0.017512094707309137},
        "idle_norm_percentiles": {
            1: 0.925, 25: 0.975, 50: 1.0, 75: 1.0, 90: 1.0, 99: 1.0},
        "oversub_frac_below": 0.0,
        "task_count_mean": 0.4103030303030303,
        "task_count_max": 6,
        "mean_latency_s": 6.695974653777007,
        "p99_latency_s": 12.265554519093937,
        "completed": 192,
        "fleet_degradation_cv": 0.015198877568723157,
        "fleet_yearly_kgco2eq": 1927.63250963261,
    },
    "proposed-mmpp-aged": {
        "freq_cv_percentiles": {
            1: 0.02606572685057002, 25: 0.03592799911413752,
            50: 0.041160087839373416, 75: 0.0473705820504461,
            90: 0.04791314844749198, 99: 0.054067821039909675},
        "mean_degradation_percentiles": {
            1: 0.010694229394002984, 25: 0.010890442191191667,
            50: 0.010994269445354707, 75: 0.011193918718551122,
            90: 0.011348885709066645, 99: 0.011416982341791698},
        "idle_norm_percentiles": {
            1: -0.05, 25: 0.0, 50: 0.025, 75: 0.025,
            90: 0.3424999999999962, 99: 1.0},
        "oversub_frac_below": 0.004545454545454545,
        "task_count_mean": 0.41818181818181815,
        "task_count_max": 14,
        "mean_latency_s": 3.94816806315291,
        "p99_latency_s": 8.804968378426421,
        "completed": 62,
        "fleet_degradation_cv": 0.017758259754216115,
        "fleet_yearly_kgco2eq": 1332.9488686904274,
    },
}


class TestOptimizedMatchesPreOptimizationGoldens:
    @pytest.mark.parametrize("cell", sorted(CELLS))
    def test_full_metrics_pinned(self, cell):
        m = run_experiment(CELLS[cell])
        gold = GOLD[cell]
        for field in ("freq_cv_percentiles", "mean_degradation_percentiles",
                      "idle_norm_percentiles"):
            got = getattr(m, field)
            for p, v in gold[field].items():
                assert got[p] == pytest.approx(v, abs=TOL), (field, p)
        for field in ("oversub_frac_below", "task_count_mean",
                      "mean_latency_s", "p99_latency_s",
                      "fleet_degradation_cv", "fleet_yearly_kgco2eq"):
            assert getattr(m, field) == pytest.approx(gold[field],
                                                      abs=TOL), field
        assert m.task_count_max == gold["task_count_max"]
        assert m.completed == gold["completed"]


def _assert_metrics_identical(a, b, key):
    """Field-by-field exact equality of two ExperimentMetrics (same
    process/platform -> no tolerance at all)."""
    assert a.policy == b.policy and a.scenario == b.scenario
    assert a.router == b.router
    assert a.completed == b.completed, key
    assert a.task_count_max == b.task_count_max
    for field in ("freq_cv_percentiles", "mean_degradation_percentiles",
                  "idle_norm_percentiles"):
        assert getattr(a, field) == getattr(b, field), (key, field)
    for field in ("oversub_frac_below", "task_count_mean",
                  "mean_latency_s", "p99_latency_s",
                  "fleet_degradation_cv", "fleet_yearly_kgco2eq"):
        va, vb = getattr(a, field), getattr(b, field)
        assert va == vb or (math.isnan(va) and math.isnan(vb)), (key, field)
    np.testing.assert_array_equal(a.per_machine_cv, b.per_machine_cv)
    np.testing.assert_array_equal(a.per_machine_degradation,
                                  b.per_machine_degradation)
    assert a.per_machine_carbon == b.per_machine_carbon


class TestParallelSweepIdentical:
    def test_3x2x2_grid_matches_serial(self):
        cfg = ExperimentConfig(rate_rps=40.0, duration_s=10.0, seed=0)
        policies = ("linux", "least-aged", "proposed")
        scenarios = ("conversation-poisson", "conversation-mmpp")
        routers = ("jsq", "least-aged-cpu")
        serial = run_policy_sweep(cfg, policies=policies,
                                  scenarios=scenarios, routers=routers)
        par = run_policy_sweep(cfg, policies=policies, scenarios=scenarios,
                               routers=routers, parallel=2)
        assert list(par) == list(serial)     # same keys, same order
        for key in serial:
            _assert_metrics_identical(serial[key], par[key], key)

    def test_parallel_one_and_none_fall_back_to_serial_path(self):
        cfg = ExperimentConfig(rate_rps=40.0, duration_s=5.0, seed=1)
        a = run_policy_sweep(cfg, policies=("linux",))
        b = run_policy_sweep(cfg, policies=("linux",), parallel=1)
        _assert_metrics_identical(a["linux"], b["linux"], "linux")
