"""Tests for the pluggable workload-scenario subsystem.

SCENARIO_GOLD pins a seeded fingerprint of every built-in scenario
(count, first request, last arrival, token sums) so any change to a
scenario's RNG draw sequence is caught; LEGACY_GOLD pins values captured
from the pre-subsystem `sim.trace.generate` (seed commit), which the
`conversation-poisson` scenario must reproduce bit-exactly.
"""
import dataclasses
import io

import numpy as np
import pytest

from repro.sim import ExperimentConfig, TaskIdAllocator, run_policy_sweep
from repro.workloads import (ReplayScenario, Request, Scenario,
                             available_scenarios, canonical_scenario_name,
                             export_csv_str, get_scenario, load_csv, mixes,
                             register_scenario, request_stats, splice,
                             time_scale)
from repro.workloads.arrivals import MMPPArrivals, PoissonArrivals

# Fingerprint per scenario at (rate_rps=50, duration_s=30, seed=11):
# (n_requests, first arrival, first in/out tokens, last arrival,
#  sum inputs, sum outputs)
SCENARIO_GOLD = {
    "code-poisson": (1448, 0.004591848626348808, 6593, 39,
                     29.97896439939197, 3822024, 28488),
    "conversation-constant": (1500, 0.02, 1052, 498,
                              29.99999999999945, 2243274, 319643),
    "conversation-diurnal": (1501, 0.0028699053914680046, 2895, 84,
                             29.9623805389845, 2205115, 329333),
    "conversation-flashcrowd": (1543, 0.007323333942804692, 793, 83,
                                29.99532852923168, 2279307, 335831),
    "conversation-mmpp": (957, 0.041154948310679465, 662, 103,
                          29.99815345560464, 1383374, 208663),
    "conversation-poisson": (1448, 0.004591848626348808, 3247, 438,
                             29.97896439939197, 2081384, 301368),
    "longcontext-poisson": (1448, 0.004591848626348808, 13573, 796,
                            29.97896439939197, 10010453, 585101),
    "mixed-poisson": (1490, 0.004591848626348808, 2895, 84,
                      29.99486284581264, 2676684, 241250),
}

# Captured from the seed-commit sim.trace.generate(TraceConfig(
#   rate_rps=60, duration_s=20, seed=3)) — the bit-exactness contract.
LEGACY_GOLD = {
    "n": 1190,
    "first_arrival": 0.0018335802113006638,
    "first_in": 116,
    "first_out": 203,
    "sum_in": 1742936,
}


class TestRegistry:
    def test_at_least_six_builtins(self):
        assert len(available_scenarios()) >= 6
        assert "conversation-poisson" in available_scenarios()

    def test_roundtrip_every_registered_name(self):
        for name in available_scenarios():
            sc = get_scenario(name)
            assert sc.name == name
            reqs = sc.generate(rate_rps=30, duration_s=5, seed=0)
            assert reqs, name
            assert all(0 <= r.arrival_s < 5 for r in reqs)
            assert all(r.req_id == i for i, r in enumerate(reqs))

    def test_name_normalization(self):
        assert canonical_scenario_name("Conversation_Poisson") == \
            "conversation-poisson"
        a = get_scenario("conversation_poisson")
        b = get_scenario("CONVERSATION-POISSON")
        assert a.name == b.name == "conversation-poisson"

    def test_unknown_name_lists_available(self):
        with pytest.raises(KeyError, match="conversation-poisson"):
            get_scenario("definitely-not-a-scenario")

    def test_factory_opts_forwarded(self):
        sc = get_scenario("conversation-mmpp", burst_factor=12.0)
        reqs = sc.generate(rate_rps=40, duration_s=10, seed=0)
        assert reqs
        with pytest.raises(TypeError):
            get_scenario("conversation-poisson", bogus_opt=1)

    def test_custom_scenario_registers_and_runs(self):
        @register_scenario("test-tiny")
        def tiny() -> Scenario:
            return Scenario("test-tiny", mixes.CONVERSATION,
                            lambda rate, dur: PoissonArrivals(rate))

        try:
            reqs = get_scenario("test-tiny").generate(30, 5, 0)
            assert reqs == get_scenario(
                "conversation-poisson").generate(30, 5, 0)
        finally:
            from repro.workloads import registry
            registry._REGISTRY.pop("test-tiny", None)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_scenario("conversation-poisson")
            def imposter():
                pass


class TestSeededGoldenDeterminism:
    @pytest.mark.parametrize("name", sorted(SCENARIO_GOLD))
    def test_matches_pinned_fingerprint(self, name):
        reqs = get_scenario(name).generate(rate_rps=50, duration_s=30,
                                           seed=11)
        n, t0, in0, out0, t_last, sum_in, sum_out = SCENARIO_GOLD[name]
        assert len(reqs) == n
        assert reqs[0].arrival_s == t0
        assert (reqs[0].input_tokens, reqs[0].output_tokens) == (in0, out0)
        assert reqs[-1].arrival_s == t_last
        assert sum(r.input_tokens for r in reqs) == sum_in
        assert sum(r.output_tokens for r in reqs) == sum_out

    def test_every_builtin_covered(self):
        assert set(SCENARIO_GOLD) == set(available_scenarios())

    @pytest.mark.parametrize("name", sorted(SCENARIO_GOLD))
    def test_regenerate_equal(self, name):
        sc = get_scenario(name)
        assert (sc.generate(40, 10, seed=7)
                == get_scenario(name).generate(40, 10, seed=7))

    def test_seed_changes_stream(self):
        sc = get_scenario("conversation-poisson")
        assert sc.generate(40, 10, seed=0) != sc.generate(40, 10, seed=1)


class TestLegacyBitExactness:
    def test_conversation_poisson_matches_seed_generator(self):
        reqs = get_scenario("conversation-poisson").generate(
            rate_rps=60, duration_s=20, seed=3)
        assert len(reqs) == LEGACY_GOLD["n"]
        assert reqs[0].arrival_s == LEGACY_GOLD["first_arrival"]
        assert reqs[0].input_tokens == LEGACY_GOLD["first_in"]
        assert reqs[0].output_tokens == LEGACY_GOLD["first_out"]
        assert sum(r.input_tokens for r in reqs) == LEGACY_GOLD["sum_in"]

    def test_traceconfig_shim_removed(self):
        """PR 2's deprecated TraceConfig/generate shims are gone; the
        bit-exactness contract lives on in
        `test_conversation_poisson_matches_seed_generator`."""
        with pytest.raises(ImportError):
            from repro.sim import TraceConfig  # noqa: F401


class TestMixStatistics:
    """Each token mix must match its published characterization."""

    def _sample(self, mix, n=20_000, seed=0):
        rng = np.random.default_rng(seed)
        pairs = [mix.sample_one(rng) for _ in range(n)]
        return (np.array([p[0] for p in pairs]),
                np.array([p[1] for p in pairs]))

    def test_conversation_matches_azure_characterization(self):
        """Splitwise Azure-conversation: input median ~1020 /
        mean ~1155, output mean ~211."""
        n_in, n_out = self._sample(mixes.CONVERSATION)
        assert 900 < np.median(n_in) < 1150
        assert n_in.mean() < 1600        # heavy tail, clipped at 8192
        assert 170 < n_out.mean() < 260

    def test_code_long_in_short_out(self):
        """Splitwise Azure-code: ~2k-token prompts, tiny completions."""
        n_in, n_out = self._sample(mixes.CODE)
        assert 1600 < np.median(n_in) < 2400
        assert np.median(n_out) < 50
        assert n_out.mean() < 60

    def test_long_context_document_scale(self):
        n_in, n_out = self._sample(mixes.LONG_CONTEXT)
        assert np.median(n_in) > 4000
        assert 150 < np.median(n_out) < 600

    def test_blended_between_components(self):
        n_in, n_out = self._sample(mixes.BLENDED)
        conv_in, _ = self._sample(mixes.CONVERSATION)
        code_out_med = np.median(self._sample(mixes.CODE)[1])
        # blend median input sits above pure conversation (code pulls up)
        assert np.median(n_in) > np.median(conv_in)
        # and blend output median sits above pure code
        assert np.median(n_out) > code_out_med

    def test_mean_rate_preserved_across_arrival_shapes(self):
        """Temporal scenarios modulate *around* rate_rps, they don't
        change delivered volume (long-horizon check)."""
        for name in ("conversation-diurnal", "conversation-mmpp",
                     "conversation-flashcrowd", "conversation-constant"):
            reqs = get_scenario(name).generate(rate_rps=50,
                                               duration_s=600, seed=4)
            rate = len(reqs) / 600.0
            assert rate == pytest.approx(50.0, rel=0.15), name

    def test_flashcrowd_overhanging_spike_keeps_mean_rate(self):
        """A spike window extending past the trace end must still
        normalize to the configured mean rate (overlap-aware)."""
        sc = get_scenario("conversation-flashcrowd",
                          spike_start_frac=0.95, spike_duration_frac=0.2)
        rates = [len(sc.generate(40, 100, seed=s)) / 100
                 for s in range(10)]
        assert np.mean(rates) == pytest.approx(40.0, rel=0.1)

    def test_diurnal_swings_within_a_trace(self):
        """Default period is one cycle per trace, so the day/night swing
        is visible at benchmark durations (phase=0: peak first half)."""
        reqs = get_scenario("conversation-diurnal").generate(50, 300,
                                                             seed=2)
        ts = np.array([r.arrival_s for r in reqs])
        assert (ts < 150).sum() > 1.5 * (ts >= 150).sum()

    def test_mmpp_burstier_than_poisson(self):
        """Index of dispersion of per-second counts: MMPP >> Poisson."""

        def dispersion(name):
            reqs = get_scenario(name).generate(50, 300, seed=9)
            counts = np.bincount(
                np.array([int(r.arrival_s) for r in reqs]), minlength=300)
            return counts.var() / counts.mean()

        assert dispersion("conversation-mmpp") > \
            3 * dispersion("conversation-poisson")


class TestTraceIO:
    def _mk(self, n=50, seed=0):
        return get_scenario("conversation-poisson").generate(30, 10, seed)

    def test_csv_roundtrip_equality(self):
        reqs = self._mk()
        text = export_csv_str(reqs)
        back = load_csv(io.StringIO(text))
        assert back == reqs

    def test_export_every_scenario_roundtrips(self):
        for name in available_scenarios():
            reqs = get_scenario(name).generate(30, 5, seed=2)
            back = load_csv(io.StringIO(export_csv_str(reqs)))
            assert back == reqs, name

    def test_load_requires_azure_schema(self):
        with pytest.raises(ValueError, match="ContextTokens"):
            load_csv(io.StringIO("time,in,out\n1,2,3\n"))

    def test_load_sorts_and_renumbers(self):
        text = ("TIMESTAMP,ContextTokens,GeneratedTokens\n"
                "5.0,100,10\n2.0,200,20\n9.0,300,30\n")
        reqs = load_csv(io.StringIO(text))
        assert [r.req_id for r in reqs] == [0, 1, 2]
        # relative float timestamps pass through un-rebased...
        assert [r.arrival_s for r in reqs] == [2.0, 5.0, 9.0]
        assert [r.input_tokens for r in reqs] == [200, 100, 300]
        # ...unless rebasing is forced
        rebased = load_csv(io.StringIO(text), rebase=True)
        assert [r.arrival_s for r in rebased] == [0.0, 3.0, 7.0]

    def test_load_accepts_iso_timestamps(self):
        text = ("TIMESTAMP,ContextTokens,GeneratedTokens\n"
                "2024-05-01 00:00:00,100,10\n"
                "2024-05-01 00:00:30,200,20\n")
        reqs = load_csv(io.StringIO(text))
        assert [r.arrival_s for r in reqs] == [0.0, 30.0]

    def test_load_accepts_azure_seven_digit_fractions(self):
        """The real Azure trace carries 7 fractional digits, which
        Python 3.10's fromisoformat rejects unnormalized."""
        text = ("TIMESTAMP,ContextTokens,GeneratedTokens\n"
                "2023-11-16 18:15:46.6805900,100,10\n"
                "2023-11-16 18:15:47.1805901,200,20\n")
        reqs = load_csv(io.StringIO(text))
        assert reqs[0].arrival_s == 0.0
        assert reqs[1].arrival_s == pytest.approx(0.5, abs=1e-6)

    def test_load_rejects_mixed_timestamp_kinds(self):
        """One absolute datetime among relative floats would rebase the
        floats into garbage — refuse instead."""
        text = ("TIMESTAMP,ContextTokens,GeneratedTokens\n"
                "0.5,100,10\n2024-05-01 00:00:00,200,20\n")
        with pytest.raises(ValueError, match="mixes"):
            load_csv(io.StringIO(text))

    def test_splice_window(self):
        reqs = self._mk()
        window = splice(reqs, start_s=2.0, stop_s=6.0)
        assert window
        assert all(0 <= r.arrival_s < 4.0 for r in window)
        assert [r.req_id for r in window] == list(range(len(window)))

    def test_time_scale_changes_rate(self):
        reqs = self._mk()
        fast = time_scale(reqs, 0.5)
        assert max(r.arrival_s for r in fast) == pytest.approx(
            0.5 * max(r.arrival_s for r in reqs))
        with pytest.raises(ValueError):
            time_scale(reqs, 0.0)

    def test_replay_scenario_rescales_and_truncates(self):
        source = get_scenario("conversation-poisson").generate(20, 60, 5)
        sc = ReplayScenario.from_requests(source, name="azure-conv")
        out = sc.generate(rate_rps=40, duration_s=10, seed=999)
        assert out == sc.generate(rate_rps=40, duration_s=10, seed=0)
        assert all(r.arrival_s < 10 for r in out)
        rate = len(out) / 10.0
        assert rate == pytest.approx(40.0, rel=0.25)
        # token counts come from the recorded trace, untouched
        assert {(r.input_tokens, r.output_tokens) for r in out} <= \
            {(r.input_tokens, r.output_tokens) for r in source}

    def test_replay_loops_to_fill_requested_duration(self):
        """A short recording must cover duration_s (the scenario
        contract), looping end-to-end; loop=False emits it once."""
        source = get_scenario("conversation-poisson").generate(20, 60, 5)
        looped = ReplayScenario.from_requests(source).generate(
            rate_rps=60, duration_s=120)
        assert max(r.arrival_s for r in looped) > 100
        assert len(looped) / 120 == pytest.approx(60.0, rel=0.05)
        assert [r.req_id for r in looped] == list(range(len(looped)))
        once = ReplayScenario.from_requests(source, loop=False).generate(
            rate_rps=60, duration_s=120)
        assert len(once) == len(source)
        assert max(r.arrival_s for r in once) < 25

    def test_replay_degenerate_window_does_not_crash(self):
        """A spliced window with one request (or identical timestamps)
        has zero span: replay it at t=0 instead of raising."""
        source = [Request(0, 5.0, 100, 10), Request(1, 5.0, 200, 20)]
        sc = ReplayScenario.from_requests(source, start_s=5.0, stop_s=6.0)
        out = sc.generate(rate_rps=40, duration_s=10, seed=0)
        assert [r.arrival_s for r in out] == [0.0, 0.0]
        single = ReplayScenario.from_requests([Request(0, 5.0, 100, 10)],
                                              loop=False)
        assert len(single.generate(rate_rps=40, duration_s=10)) == 1

    def test_replay_from_csv_file(self, tmp_path):
        from repro.workloads import export_csv
        source = self._mk()
        path = tmp_path / "azure_conv.csv"
        export_csv(source, path)
        sc = ReplayScenario.from_csv(path)
        assert sc.name == "azure_conv"
        assert tuple(sc.requests) == tuple(source)


class TestRequestStats:
    def test_empty_stream_returns_zero_dict(self):
        stats = request_stats([])
        assert stats["n_requests"] == 0
        assert all(v == 0 for v in stats.values())
        assert not any(np.isnan(v) for v in stats.values())

    def test_trace_stats_shim_removed(self):
        with pytest.raises(ImportError):
            from repro.sim import trace_stats  # noqa: F401

    def test_basic_stats(self):
        reqs = [Request(0, 1.0, 100, 10), Request(1, 2.0, 300, 30)]
        stats = request_stats(reqs)
        assert stats["n_requests"] == 2
        assert stats["input_mean"] == 200.0
        assert stats["output_median"] == 20.0
        assert stats["mean_rate_rps"] == pytest.approx(1.0)


class TestExperimentIntegration:
    def test_config_normalizes_and_hashes(self):
        a = ExperimentConfig(scenario="Conversation_MMPP",
                             scenario_opts={"burst_factor": 8.0})
        b = ExperimentConfig(scenario="conversation-mmpp",
                             scenario_opts=(("burst_factor", 8.0),))
        assert a == b and hash(a) == hash(b)
        assert a.scenario_options == {"burst_factor": 8.0}
        assert a.with_scenario("code-poisson").scenario_opts == ()

    def test_policy_scenario_grid_sweep(self):
        cfg = ExperimentConfig(num_cores=40, rate_rps=40, duration_s=5,
                               seed=3)
        grid = run_policy_sweep(cfg, policies=("linux", "proposed"),
                                scenarios=("conversation-poisson",
                                           "conversation-mmpp"))
        assert set(grid) == {(p, s)
                             for p in ("linux", "proposed")
                             for s in ("conversation-poisson",
                                       "conversation-mmpp")}
        for (p, s), m in grid.items():
            assert m.policy == p and m.scenario == s
            assert m.completed >= 0

    def test_grid_entry_matches_single_run(self):
        from repro.sim import run_experiment
        cfg = ExperimentConfig(rate_rps=40, duration_s=5, seed=3,
                               scenario="conversation-mmpp")
        single = run_experiment(cfg)
        grid = run_policy_sweep(cfg, policies=("proposed",),
                                scenarios=("conversation-mmpp",))
        m = grid[("proposed", "conversation-mmpp")]
        assert m.freq_cv_percentiles == single.freq_cv_percentiles
        assert m.completed == single.completed


class TestTaskIdAllocation:
    def test_per_allocator_monotone_independent(self):
        a, b = TaskIdAllocator(), TaskIdAllocator()
        ids_a = [a.next_id() for _ in range(5)]
        ids_b = [b.next_id() for _ in range(3)]
        assert ids_a == [0, 1, 2, 3, 4]
        assert ids_b == [0, 1, 2]           # no cross-allocator bleed

    def test_interleaved_clusters_get_independent_ids(self):
        """Two clusters built side by side (concurrent experiments) must
        both start their task-id streams at 0."""
        from repro.sim import Cluster
        cfg = ExperimentConfig(rate_rps=40, duration_s=2, seed=0)
        c1, c2 = Cluster(cfg), Cluster(cfg)
        t1 = c1.machines[0].task_ids.new("submit")
        t2 = c2.machines[0].task_ids.new("submit")
        assert t1.task_id == 0 and t2.task_id == 0

    def test_cluster_machines_share_one_stream(self):
        from repro.sim import Cluster
        cfg = ExperimentConfig(rate_rps=40, duration_s=2, seed=0)
        c = Cluster(cfg)
        ids = [c.machines[i].task_ids.next_id() for i in range(4)]
        assert ids == [0, 1, 2, 3]
