"""Distribution-layer tests: sharding specs, HLO collective parsing, and
a subprocess dry-run smoke (512 host devices can't coexist with the
single-device test process)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import hlo_analysis
from repro.distributed import sharding as shd
from repro.models import Model


class FakeMesh:
    """Duck-typed mesh (shape dict + axis_names) for spec unit tests."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestParamSpecs:
    @pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x22b",
                                      "mamba2-2.7b", "minicpm3-4b",
                                      "zamba2-7b",
                                      "seamless-m4t-large-v2"])
    def test_specs_divide_evenly(self, arch):
        """Every sharded dim must divide by its mesh axes (JAX requirement
        at jit boundaries)."""
        cfg = get_config(arch)
        model = Model(cfg)
        abstract = model.abstract_params()
        specs = shd.param_specs(abstract, MESH)

        def check(a, s):
            assert len(s) == a.ndim, (a.shape, s)
            for dim, ax in zip(a.shape, s):
                if ax is not None:
                    assert dim % shd.axis_size(MESH, ax) == 0, (a.shape, s)
        jax.tree.map(check, abstract, specs,
                     is_leaf=lambda x: isinstance(x, P))

    def test_vocab_sharded(self):
        cfg = get_config("llama3-8b")
        specs = shd.param_specs(Model(cfg).abstract_params(), MESH)
        assert specs["embed"] == P("model", None)
        assert specs["unembed"] == P("model", None)

    def test_layer_stacking_stripped(self):
        cfg = get_config("llama3-8b")
        specs = shd.param_specs(Model(cfg).abstract_params(), MESH)
        # stacked (L, D, H*hd) column-parallel: leading None then rule
        assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
        assert specs["layers"]["attn"]["wo"] == P(None, "model", None)

    def test_moe_expert_weights_sharded_on_ff(self):
        cfg = get_config("mixtral-8x22b")
        specs = shd.param_specs(Model(cfg).abstract_params(), MESH)
        assert specs["layers"]["mlp"]["w_gate"] == P(None, None, None,
                                                     "model")
        assert specs["layers"]["mlp"]["w_down"] == P(None, None, "model",
                                                     None)


class TestCacheSpecs:
    def test_kv_cache_heads_or_seq(self):
        # kv heads 8 < 16 -> seq gets the model axis
        spec = shd.kv_cache_spec(MESH, (32, 128, 32768, 8, 128))
        assert spec == P(None, "data", "model", None, None)
        # kv heads 32 -> heads take the model axis
        spec = shd.kv_cache_spec(MESH, (32, 128, 32768, 32, 128))
        assert spec == P(None, "data", None, "model", None)

    def test_batch_one_replicated(self):
        spec = shd.kv_cache_spec(MESH, (32, 1, 4096, 8, 128))
        assert spec[1] is None

    def test_hybrid_nested_cache(self):
        cfg = get_config("zamba2-7b")
        from repro.models.config import INPUT_SHAPES
        pass  # (dryrun import not needed here; jax already initialized
        # single-device in this test process)

        # build abstract cache shapes manually for the nested case:
        model = Model(cfg)
        tok = jax.ShapeDtypeStruct((2, 31), jnp.int32)
        abstract = jax.eval_shape(
            lambda p, t: model.prefill(p, t, None, max_len=32)[1],
            model.abstract_params(), tok)
        specs = shd.cache_specs(cfg, abstract, MESH)
        # grouped ssm conv cache: (G, per, B, W-1, C)
        conv_spec = specs["ssm"]["conv"]
        assert len(conv_spec) == 5
        assert conv_spec[-1] == "model"  # conv channels divisible


class TestHLOAnalysis:
    def test_collective_parsing(self):
        hlo = """
  %all-gather.1 = bf16[4,128]{1,0} all-gather(%x), replica_groups={}
  %ar = (f32[16,16]{1,0}, f32[8]{0}) all-reduce(%a, %b), to_apply=%add
  %notacoll = f32[2]{0} add(%c, %d)
  %rs = f32[64]{0} reduce-scatter(%e), dimensions={0}
"""
        out = hlo_analysis.collective_bytes(hlo)
        assert out["all-gather"] == 4 * 128 * 2
        assert out["all-reduce"] == 16 * 16 * 4 + 8 * 4
        assert out["reduce-scatter"] == 64 * 4
        assert out["total"] == (4 * 128 * 2 + 16 * 16 * 4 + 8 * 4 + 64 * 4)
        assert out["count"] == 3

    def test_ignores_done_ops(self):
        hlo = ("  %ag = bf16[8]{0} all-gather-start(%x)\n"
               "  %agd = bf16[8]{0} all-gather-done(%ag)\n")
        out = hlo_analysis.collective_bytes(hlo)
        assert out["count"] == 1


@pytest.mark.slow
class TestDryRunSmoke:
    """Subprocess dry-run: proves the 512-device multi-pod lowering works
    end-to-end (one fast config; the full 80-combo sweep is offline)."""

    def test_llama3_decode_both_meshes(self):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", "llama3-8b", "--shape", "decode_32k",
               "--mesh", "both"]
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=1200,
                             env={**__import__("os").environ,
                                  "PYTHONPATH": "src"},
                             cwd=__import__("os").path.dirname(
                                 __import__("os").path.dirname(__file__)))
        assert "ALL DRY-RUNS PASSED" in out.stdout, out.stdout + out.stderr
        assert "16x16" in out.stdout and "2x16x16" in out.stdout


class TestCostExtrapolation:
    """Unit tests for the reduced-depth cost extrapolation algebra."""

    def test_coll_comb_linear(self):
        import os
        jax.devices()  # lock the backend to 1 device BEFORE importing
        saved = os.environ.get("XLA_FLAGS")
        from repro.launch import dryrun
        # dryrun sets XLA_FLAGS at import (required for __main__ use);
        # undo it so later test processes/subprocesses are unaffected.
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved
        a = {"all-reduce": 10.0, "total": 10.0}
        b = {"all-reduce": 4.0, "all-gather": 2.0, "total": 6.0}
        out = dryrun._coll_comb(a, b, 1.0, -1.0)
        assert out["all-reduce"] == 6.0
        assert out["all-gather"] == 0.0  # clamped at zero

    def test_linear_extrapolation_exact_for_linear_costs(self):
        """f(L) = non + L*layer must be recovered exactly from L=2,4."""
        non, layer, L = 7.0, 3.0, 32
        c1 = non + 2 * layer
        c2 = non + 4 * layer
        steps = (L - 2) / 2
        assert c1 + (c2 - c1) * steps == non + L * layer
