"""Tests for fleet-batched aging settlement (`repro.sim.fleetstate`)."""
import numpy as np
import pytest

from repro.core import CoreManager
from repro.sim.fleetstate import FleetAgingSettler, settle_fleet


def build_fleet(n_machines=6, num_cores=8, policy="proposed"):
    """Managers with heterogeneous per-core regimes (busy / idle / gated)."""
    ms = [CoreManager(num_cores, policy=policy,
                      rng=np.random.default_rng(100 + i))
          for i in range(n_machines)]
    tid = 0
    for i, m in enumerate(ms):
        for _ in range(i % (num_cores // 2 + 1)):
            m.assign(tid, 0.1 * i)
            tid += 1
        if i % 2:
            m.periodic(0.5)          # proposed gates spare cores
    return ms


class TestNumpyBackendBitExact:
    def test_matches_sequential_settle_all(self):
        """The stacked advance must reproduce per-machine settle_all
        bit-for-bit — the serial numpy path stays golden-exact."""
        a = build_fleet()
        b = build_fleet()
        for k in range(1, 6):
            now = 7.3 * k
            for m in a:
                m.settle_all(now)
            FleetAgingSettler(b).settle(now)
            for ma, mb in zip(a, b):
                np.testing.assert_array_equal(ma.dvth, mb.dvth)
                np.testing.assert_array_equal(ma.last_update,
                                              mb.last_update)
                assert ma.now == mb.now

    def test_noop_when_already_settled(self):
        ms = build_fleet(n_machines=2)
        s = FleetAgingSettler(ms)
        s.settle(5.0)
        before = [m.dvth.copy() for m in ms]
        s.settle(5.0)                 # no elapsed time anywhere
        for m, d in zip(ms, before):
            np.testing.assert_array_equal(m.dvth, d)
            assert m.now == 5.0

    def test_settle_fleet_wrapper(self):
        ms = build_fleet(n_machines=2)
        settle_fleet(ms, 3.0)
        assert all(m.now == 3.0 for m in ms)
        assert all((m.last_update == 3.0).all() for m in ms)


class TestValidation:
    def test_rejects_heterogeneous_core_counts(self):
        ms = [CoreManager(4, rng=np.random.default_rng(0)),
              CoreManager(8, rng=np.random.default_rng(1))]
        with pytest.raises(ValueError, match="homogeneous"):
            FleetAgingSettler(ms)

    def test_rejects_heterogeneous_params(self):
        import dataclasses
        from repro.core import aging
        p2 = aging.solve_k(dataclasses.replace(aging.DEFAULT_PARAMS,
                                               E0=0.25))
        ms = [CoreManager(4, rng=np.random.default_rng(0)),
              CoreManager(4, aging_params=p2,
                          rng=np.random.default_rng(1))]
        with pytest.raises(ValueError, match="homogeneous"):
            FleetAgingSettler(ms)

    def test_rejects_empty_and_bad_backend(self):
        with pytest.raises(ValueError, match="at least one"):
            FleetAgingSettler([])
        with pytest.raises(ValueError, match="backend"):
            FleetAgingSettler([CoreManager(4)], backend="tpu-magic")

    def test_auto_backend_resolves(self):
        s = FleetAgingSettler([CoreManager(4)], backend="auto")
        assert s.backend in ("numpy", "jax")


class TestJaxBackend:
    def test_jax_matches_numpy_within_float32(self):
        """The Pallas-kernel path is float32: same physics to ~1e-6,
        explicitly not bit-exact (which is why the Cluster default
        stays numpy)."""
        pytest.importorskip("jax")
        a = build_fleet(n_machines=3, num_cores=8)
        b = build_fleet(n_machines=3, num_cores=8)
        FleetAgingSettler(a, backend="numpy").settle(11.0)
        FleetAgingSettler(b, backend="jax").settle(11.0)
        for ma, mb in zip(a, b):
            np.testing.assert_allclose(ma.dvth, mb.dvth,
                                       rtol=2e-6, atol=1e-8)


class TestClusterIntegration:
    def test_cluster_uses_batched_settler(self):
        from repro.sim import Cluster, ExperimentConfig
        c = Cluster(ExperimentConfig())
        assert c.fleet_settler.backend == "numpy"
        assert len(c.fleet_settler.managers) == 22
