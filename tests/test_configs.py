"""Assigned-architecture configs must match the pool table EXACTLY."""
import pytest

from repro.configs import all_arch_names, get_config, get_smoke_config

# (L, d_model, H, kv, d_ff, vocab) per the assignment
ASSIGNED = {
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
}


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_exact_assigned_numbers(arch):
    cfg = get_config(arch)
    l, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.num_layers == l
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.citation


def test_all_ten_present():
    assert len(all_arch_names()) == 10
    assert set(all_arch_names()) == set(ASSIGNED)


def test_family_specifics():
    assert get_config("granite-moe-3b-a800m").num_experts == 40
    assert get_config("granite-moe-3b-a800m").experts_per_token == 8
    assert get_config("mixtral-8x22b").num_experts == 8
    assert get_config("mixtral-8x22b").experts_per_token == 2
    assert get_config("mixtral-8x22b").sliding_window == 4096
    assert get_config("mamba2-2.7b").ssm_state == 128
    assert get_config("mamba2-2.7b").attn_type == "none"
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("zamba2-7b").hybrid_period == 6
    assert get_config("minicpm3-4b").attn_type == "mla"
    assert get_config("seamless-m4t-large-v2").encoder_layers == 24
    assert get_config("internvl2-2b").frontend == "vision"
    assert get_config("seamless-m4t-large-v2").frontend == "audio"


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_smoke_configs_reduced(arch):
    """Smoke variants must honor the reduction limits."""
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 5
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_param_counts_sane(arch):
    """Analytic parameter counts are within 2x of the model-card scale."""
    expected_b = {
        "granite-moe-3b-a800m": 3.3e9, "internvl2-2b": 1.9e9,
        "mamba2-2.7b": 2.7e9, "seamless-m4t-large-v2": 2.3e9,
        "minicpm3-4b": 4.0e9, "mixtral-8x22b": 141e9, "zamba2-7b": 7.5e9,
        "granite-3-8b": 8.1e9, "llama3-8b": 8.0e9,
        "phi3-medium-14b": 14e9,
    }[arch]
    got = get_config(arch).param_count()
    assert 0.5 * expected_b < got < 2.0 * expected_b, got
