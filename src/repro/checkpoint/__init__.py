"""npz checkpoint store."""
