"""Checkpointing: flat-key .npz store for params/opt-state + JSON metadata.

No orbax offline; this implements atomic-rename checkpoints with step
retention, which is what the training driver needs.
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            # npz has no bf16: store the raw bits; restore() re-views via
            # the template dtype.
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save(directory: str, step: int, params, opt_state=None, extra: dict | None = None,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory)
    np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(extra or {})}, f)
    final = os.path.join(directory, f"step_{step:08d}")
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if re.fullmatch(r"step_\d{8}", d))
    for d in ckpts[:-keep]:
        full = os.path.join(directory, d)
        for f in os.listdir(full):
            os.unlink(os.path.join(full, f))
        os.rmdir(full)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory)
                   if re.fullmatch(r"step_\d{8}", d))
    return int(ckpts[-1][5:]) if ckpts else None


def restore(directory: str, template, step: int | None = None,
            name: str = "params.npz"):
    """Restore a pytree matching `template`'s structure."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}", name)
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for pth, leaf in leaves:
        key = _SEP.join(_path_str(p) for p in pth)
        arr = data[key]
        if (leaf.dtype == jax.numpy.bfloat16
                and arr.dtype == np.uint16):
            arr = arr.view(jax.numpy.bfloat16)
        if isinstance(leaf, np.ndarray):
            # numpy template leaf: restore as numpy, dtype preserved.
            # Routing through jax here would silently truncate float64
            # state to float32 (x64 is disabled by default), breaking
            # bit-exact resume for hosts that checkpoint f64 state.
            out.append(np.asarray(arr, dtype=leaf.dtype))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def meta(directory: str, step: int | None = None) -> dict:
    step = step if step is not None else latest_step(directory)
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)
