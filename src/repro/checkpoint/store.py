"""Checkpointing: flat-key .npz store for params/opt-state + JSON metadata.

No orbax offline; this implements atomic-rename checkpoints with step
retention, which is what the training driver needs.

Every array file's sha256 + byte length is recorded in `meta.json` at
save time and verified on restore: a truncated or bit-flipped newest
checkpoint makes `restore()` fall back to the latest earlier step that
verifies (with a warning) instead of resuming from garbage. An
explicitly requested `step=` stays strict and raises. Checkpoints
written before digests existed carry no record and load as before.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
import warnings

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            # npz has no bf16: store the raw bits; restore() re-views via
            # the template dtype.
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save(directory: str, step: int, params, opt_state=None, extra: dict | None = None,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory)
    files = ["params.npz"]
    np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
    if opt_state is not None:
        files.append("opt_state.npz")
        np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
    digests = {n: _digest(os.path.join(tmp, n)) for n in files}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(extra or {}), "digests": digests}, f)
    final = os.path.join(directory, f"step_{step:08d}")
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _digest(path: str) -> dict:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return {"sha256": h.hexdigest(), "bytes": os.path.getsize(path)}


def _verify(directory: str, step: int, name: str) -> str | None:
    """Check `name` in checkpoint `step` against its recorded digest.
    Returns a human-readable defect description, or None when the file
    passes (or predates digest records)."""
    stepdir = os.path.join(directory, f"step_{step:08d}")
    path = os.path.join(stepdir, name)
    if not os.path.isfile(path):
        return f"missing {name}"
    try:
        with open(os.path.join(stepdir, "meta.json")) as f:
            rec = json.load(f).get("digests", {}).get(name)
    except (OSError, json.JSONDecodeError) as e:
        return f"unreadable meta.json ({e})"
    if rec is None:
        return None
    size = os.path.getsize(path)
    if size != rec["bytes"]:
        return f"{name} is {size} bytes, expected {rec['bytes']}"
    if _digest(path)["sha256"] != rec["sha256"]:
        return f"{name} does not match its recorded sha256"
    return None


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if re.fullmatch(r"step_\d{8}", d))
    for d in ckpts[:-keep]:
        full = os.path.join(directory, d)
        for f in os.listdir(full):
            os.unlink(os.path.join(full, f))
        os.rmdir(full)


def _steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    return sorted(int(d[5:]) for d in os.listdir(directory)
                  if re.fullmatch(r"step_\d{8}", d))


def latest_step(directory: str) -> int | None:
    ckpts = _steps(directory)
    return ckpts[-1] if ckpts else None


def restore(directory: str, template, step: int | None = None,
            name: str = "params.npz"):
    """Restore a pytree matching `template`'s structure.

    Without `step=`, the newest checkpoint is digest-verified first; if
    it is corrupt (truncated write, bit rot) the newest *earlier* step
    that verifies is restored instead, with a warning. An explicit
    `step=` is strict: a failed check raises `ValueError`.
    """
    explicit = step is not None
    if step is None:
        step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    defect = _verify(directory, step, name)
    if defect is not None:
        if explicit:
            raise ValueError(f"checkpoint step {step} in {directory} "
                             f"failed verification: {defect}")
        for cand in reversed(_steps(directory)[:-1]):
            if _verify(directory, cand, name) is None:
                warnings.warn(
                    f"newest checkpoint (step {step}) in {directory} "
                    f"failed verification: {defect}; falling back to "
                    f"step {cand}", RuntimeWarning, stacklevel=2)
                step = cand
                break
        else:
            raise ValueError(
                f"checkpoint step {step} in {directory} failed "
                f"verification ({defect}) and no earlier step verifies")
    path = os.path.join(directory, f"step_{step:08d}", name)
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for pth, leaf in leaves:
        key = _SEP.join(_path_str(p) for p in pth)
        arr = data[key]
        if (leaf.dtype == jax.numpy.bfloat16
                and arr.dtype == np.uint16):
            arr = arr.view(jax.numpy.bfloat16)
        if isinstance(leaf, np.ndarray):
            # numpy template leaf: restore as numpy, dtype preserved.
            # Routing through jax here would silently truncate float64
            # state to float32 (x64 is disabled by default), breaking
            # bit-exact resume for hosts that checkpoint f64 state.
            out.append(np.asarray(arr, dtype=leaf.dtype))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def meta(directory: str, step: int | None = None) -> dict:
    step = step if step is not None else latest_step(directory)
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)
