"""ExperimentConfig — the one frozen object that defines an experiment.

Replaces the positional-kwarg piles previously duplicated across
`sim/runner.py`, `benchmarks/*` and `examples/*`:

    cfg = ExperimentConfig(policy="proposed", num_cores=40,
                           rate_rps=70.0, duration_s=120.0, seed=1)
    metrics = run_experiment(cfg)
    sweep = run_policy_sweep(cfg, policies=("linux", "proposed"))

The policy is addressed by registry name (see `repro.core.policies`);
`policy_opts` carries constructor options for it (e.g.
`policy="linux", policy_opts={"stickiness": 0.5}`). The workload is
likewise addressed by scenario registry name (see `repro.workloads`)
with `scenario_opts` for its factory (e.g.
`scenario="conversation-mmpp", scenario_opts={"burst_factor": 8.0}`).
The dataclass is frozen and hashable, so configs can key caches and
result dicts.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Mapping

from repro.carbon.registry import canonical_carbon_model_name
from repro.core.policies import canonical_policy_name
from repro.faults.registry import canonical_fault_model_name
from repro.hardware.inventory import canonical_fleet_name
from repro.power.registry import canonical_power_model_name
from repro.sim.routing import canonical_router_name
from repro.workloads import canonical_scenario_name


def _deep_freeze(value):
    """Hashable mirror of nested opts: mappings become sorted item
    tuples, sequences become tuples (fleet rows carry nested opts)."""
    if isinstance(value, Mapping):
        return tuple(sorted((str(k), _deep_freeze(v))
                            for k, v in value.items()))
    if isinstance(value, (list, tuple)):
        return tuple(_deep_freeze(v) for v in value)
    return value


@dataclasses.dataclass(frozen=True)
class ExperimentConfig:
    """Full specification of one cluster experiment (paper §6.1)."""

    # policy under test (registry name + constructor options)
    policy: str = "proposed"
    policy_opts: tuple[tuple[str, Any], ...] = ()
    # per-machine host CPU
    num_cores: int = 40
    idling_period_s: float = 1.0
    # cluster topology (Splitwise phase-splitting deployment)
    n_prompt: int = 5
    n_token: int = 17
    # cluster-level request routing (router registry name + constructor
    # options; see `repro.sim.routing`)
    router: str = "jsq"
    router_opts: tuple[tuple[str, Any], ...] = ()
    # carbon accounting (model registry name + constructor options; see
    # `repro.carbon` — prices per-machine embodied carbon in the result)
    carbon_model: str = "linear-extension"
    carbon_opts: tuple[tuple[str, Any], ...] = ()
    # machine power accounting (model registry name + constructor options;
    # see `repro.power` — prices measured per-core state residencies into
    # energy and operational carbon in the result)
    power_model: str = "flat-tdp"
    power_opts: tuple[tuple[str, Any], ...] = ()
    # workload (scenario registry name + factory options; the scenario
    # receives rate_rps / duration_s / seed at generation time)
    scenario: str = "conversation-poisson"
    scenario_opts: tuple[tuple[str, Any], ...] = ()
    rate_rps: float = 60.0
    duration_s: float = 120.0
    # bookkeeping
    seed: int = 0
    sample_period_s: float = 0.1
    # residency-window width for temporal power x intensity integration;
    # 0.0 = auto (`max(idling_period_s, duration_s / 1024)`)
    power_window_s: float = 0.0
    # simulation engine: "event" = per-machine event loop (bit-exact
    # small-scale reference), "fleet" = vectorized time-stepped engine
    # (`repro.sim.fleetsim`) for fleet-scale horizons. `engine_opts`
    # carries FleetEngine options (dt_s, backend, checkpoint_dir,
    # checkpoint_every_s, resume).
    engine: str = "event"
    engine_opts: tuple[tuple[str, Any], ...] = ()
    # fault injection (model registry name + constructor options; see
    # `repro.faults` — the sixth axis). "none" (the default) builds no
    # fault machinery at all: bit-exact with pre-fault behavior, and
    # omitted from `fingerprint()` so historical hashes survive.
    fault_model: str = "none"
    fault_opts: tuple[tuple[str, Any], ...] = ()
    # fleet hardware composition (see `repro.hardware` — the seventh
    # axis). "uniform" (the default) keeps every machine on the
    # implicit reference SKU with `num_cores` cores: bit-exact with
    # pre-hardware behavior and omitted from `fingerprint()` so
    # historical hashes survive. Other specs: a catalog SKU name, a
    # "sku:count+sku:count" string, or "mixed" with
    # `fleet_opts={"rows": ((sku, count, opts?), ...)}`.
    fleet: str = "uniform"
    fleet_opts: tuple[tuple[str, Any], ...] = ()
    # streaming telemetry (repro.telemetry): False = zero-cost off.
    # `telemetry_opts` carries TelemetryHub options (window_s,
    # max_events, max_windows, timeline_every, timeline_maxlen) plus the
    # runner-level `export_dir` (write JSONL/trace/series/prom exports
    # there after the run).
    telemetry: bool = False
    telemetry_opts: tuple[tuple[str, Any], ...] = ()

    def __post_init__(self):
        # Normalize: accept any hyphen/underscore spelling for registry
        # names and a dict for opts — store canonical + frozen. Always
        # sorted, so equal logical opts hash equally regardless of the
        # order (or form) they were supplied in.
        object.__setattr__(self, "policy",
                           canonical_policy_name(self.policy))
        object.__setattr__(self, "scenario",
                           canonical_scenario_name(self.scenario))
        object.__setattr__(self, "router",
                           canonical_router_name(self.router))
        object.__setattr__(self, "carbon_model",
                           canonical_carbon_model_name(self.carbon_model))
        object.__setattr__(self, "power_model",
                           canonical_power_model_name(self.power_model))
        object.__setattr__(self, "fault_model",
                           canonical_fault_model_name(self.fault_model))
        object.__setattr__(self, "fleet",
                           canonical_fleet_name(self.fleet))
        for field in ("policy_opts", "scenario_opts", "router_opts",
                      "carbon_opts", "power_opts", "telemetry_opts",
                      "engine_opts", "fault_opts"):
            opts = getattr(self, field)
            if isinstance(opts, Mapping):
                opts = opts.items()
            object.__setattr__(self, field, tuple(sorted(opts)))
        # fleet_opts may nest row tuples with their own opts dicts —
        # deep-freeze so the config stays hashable.
        fopts = self.fleet_opts
        if isinstance(fopts, Mapping):
            fopts = fopts.items()
        object.__setattr__(self, "fleet_opts",
                           tuple(sorted((str(k), _deep_freeze(v))
                                        for k, v in fopts)))
        if self.num_cores < 1:
            raise ValueError(f"num_cores must be >= 1, got {self.num_cores}")
        if self.n_prompt < 1 or self.n_token < 1:
            raise ValueError("need at least one prompt and one token "
                             f"instance, got {self.n_prompt}/{self.n_token}")
        if self.power_window_s < 0.0:
            raise ValueError(f"power_window_s must be >= 0, got "
                             f"{self.power_window_s}")
        if self.engine not in ("event", "fleet"):
            raise ValueError(f"unknown engine {self.engine!r}: expected "
                             f"'event' or 'fleet'")

    @property
    def n_machines(self) -> int:
        return self.n_prompt + self.n_token

    @property
    def policy_options(self) -> dict[str, Any]:
        """`policy_opts` as a plain kwargs dict."""
        return dict(self.policy_opts)

    @property
    def scenario_options(self) -> dict[str, Any]:
        """`scenario_opts` as a plain kwargs dict."""
        return dict(self.scenario_opts)

    @property
    def router_options(self) -> dict[str, Any]:
        """`router_opts` as a plain kwargs dict."""
        return dict(self.router_opts)

    @property
    def carbon_options(self) -> dict[str, Any]:
        """`carbon_opts` as a plain kwargs dict."""
        return dict(self.carbon_opts)

    @property
    def power_options(self) -> dict[str, Any]:
        """`power_opts` as a plain kwargs dict."""
        return dict(self.power_opts)

    @property
    def fault_options(self) -> dict[str, Any]:
        """`fault_opts` as a plain kwargs dict."""
        return dict(self.fault_opts)

    @property
    def fleet_options(self) -> dict[str, Any]:
        """`fleet_opts` as a plain kwargs dict (rows stay tuples)."""
        return dict(self.fleet_opts)

    @property
    def telemetry_options(self) -> dict[str, Any]:
        """`telemetry_opts` as a plain kwargs dict."""
        return dict(self.telemetry_opts)

    @property
    def engine_options(self) -> dict[str, Any]:
        """`engine_opts` as a plain kwargs dict."""
        return dict(self.engine_opts)

    @property
    def resolved_power_window_s(self) -> float:
        """Residency-window width with the auto default applied."""
        if self.power_window_s > 0.0:
            return self.power_window_s
        return max(self.idling_period_s, self.duration_s / 1024.0)

    def fingerprint(self) -> str:
        """Stable short hash of every field — the provenance key that
        says whether two `ExperimentResult`s came from the same
        experiment. Robust to opt ordering (opts are stored sorted).

        Fields still at their defaults that postdate existing pinned
        goldens (`engine`, `engine_opts`, `fault_model`, `fault_opts`,
        `fleet`, `fleet_opts`) are omitted from the payload, so configs
        that don't use them
        keep their historical hashes — a default-engine, faultless
        config fingerprints identically to one built before the fields
        existed."""
        payload_dict = dataclasses.asdict(self)
        if self.engine == "event" and not self.engine_opts:
            del payload_dict["engine"]
            del payload_dict["engine_opts"]
        if self.fault_model == "none" and not self.fault_opts:
            del payload_dict["fault_model"]
            del payload_dict["fault_opts"]
        if self.fleet == "uniform" and not self.fleet_opts:
            del payload_dict["fleet"]
            del payload_dict["fleet_opts"]
        payload = json.dumps(payload_dict, sort_keys=True, default=repr)
        return hashlib.sha256(payload.encode()).hexdigest()[:12]

    def replace(self, **changes) -> "ExperimentConfig":
        """Frozen-friendly copy-with-overrides."""
        return dataclasses.replace(self, **changes)

    def with_policy(self, policy: str,
                    **policy_opts) -> "ExperimentConfig":
        """Same experiment, different policy (opts reset unless given)."""
        return dataclasses.replace(self, policy=policy,
                                   policy_opts=tuple(sorted(
                                       policy_opts.items())))

    def with_scenario(self, scenario: str,
                      **scenario_opts) -> "ExperimentConfig":
        """Same experiment, different workload (opts reset unless given)."""
        return dataclasses.replace(self, scenario=scenario,
                                   scenario_opts=tuple(sorted(
                                       scenario_opts.items())))

    def with_router(self, router: str,
                    **router_opts) -> "ExperimentConfig":
        """Same experiment, different routing (opts reset unless given)."""
        return dataclasses.replace(self, router=router,
                                   router_opts=tuple(sorted(
                                       router_opts.items())))

    def with_carbon_model(self, carbon_model: str,
                          **carbon_opts) -> "ExperimentConfig":
        """Same experiment, different carbon accounting (opts reset
        unless given)."""
        return dataclasses.replace(self, carbon_model=carbon_model,
                                   carbon_opts=tuple(sorted(
                                       carbon_opts.items())))

    def with_power_model(self, power_model: str,
                         **power_opts) -> "ExperimentConfig":
        """Same experiment, different power accounting (opts reset
        unless given)."""
        return dataclasses.replace(self, power_model=power_model,
                                   power_opts=tuple(sorted(
                                       power_opts.items())))

    def with_engine(self, engine: str, **engine_opts) -> "ExperimentConfig":
        """Same experiment, different simulation engine (opts reset
        unless given; see `repro.sim.fleetsim.FleetEngine`)."""
        return dataclasses.replace(self, engine=engine,
                                   engine_opts=tuple(sorted(
                                       engine_opts.items())))

    def with_fault_model(self, fault_model: str,
                         **fault_opts) -> "ExperimentConfig":
        """Same experiment, different fault injection (opts reset
        unless given; see `repro.faults`)."""
        return dataclasses.replace(self, fault_model=fault_model,
                                   fault_opts=tuple(sorted(
                                       fault_opts.items())))

    def with_fleet(self, fleet: str, **fleet_opts) -> "ExperimentConfig":
        """Same experiment, different hardware composition (opts reset
        unless given; see `repro.hardware`)."""
        return dataclasses.replace(self, fleet=fleet,
                                   fleet_opts=tuple(sorted(
                                       fleet_opts.items())))

    def with_telemetry(self, **telemetry_opts) -> "ExperimentConfig":
        """Same experiment, telemetry recording on (opts reset unless
        given; see `repro.telemetry.TelemetryHub` + `export_dir`)."""
        return dataclasses.replace(self, telemetry=True,
                                   telemetry_opts=tuple(sorted(
                                       telemetry_opts.items())))
