"""CPU inference-task model (paper Table 2).

Every step of the serving workflow lands on the host CPU as a short task;
the paper models these eleven (extended splitwise-sim) and allocates each
a dedicated core via `CPU.assign_core_to_cpu_task`. Durations are
millisecond-scale host work; values are our measured-order-of-magnitude
estimates for a production serving stack (tokenization-adjacent submit
paths are the longest; bookkeeping completions are the shortest).
"""
from __future__ import annotations

import dataclasses

# Table 2 task types -> nominal duration (seconds) on an unaged core.
# Millisecond-scale host work for a production serving stack; the
# tokenization-adjacent submit path and batch assembly dominate.
TASK_DURATIONS_S: dict[str, float] = {
    "submit": 0.020,            # Executor.submit (incl. tokenization path)
    "submit_chain": 0.010,      # Executor.submit_chain
    "submit_flow": 0.0075,      # Executor.submit_flow
    "submit_task": 0.0075,      # Executor.submit_task
    "finish_flow": 0.005,       # Executor.finish_flow
    "finish_request": 0.010,    # Executor.finish_request (detokenize/respond)
    "finish_task": 0.005,       # Executor.finish_task
    "alloc_memory": 0.0125,     # Instance.alloc_memory (KV block tables)
    "free_memory": 0.0075,      # Instance.free_memory
    "start_iteration": 0.015,   # ORCAInstance.start_iteration (batch build)
    "flow_completion": 0.005,   # Link.flow_completion (KV-cache transfer)
}

@dataclasses.dataclass
class CPUTask:
    name: str
    task_id: int

    @property
    def duration_s(self) -> float:
        return TASK_DURATIONS_S[self.name]


class TaskIdAllocator:
    """Per-simulation monotonically increasing CPU-task ids.

    Replaces the old module-global `itertools.count()` +
    `reset_task_ids()` pattern: each `Cluster` / `InferenceEngine` owns
    its own allocator, so concurrently running experiments can never
    interleave ids (the manager's oversubscription FIFO orders waiting
    tasks by id, which requires ids to be per-simulation monotone).
    """

    __slots__ = ("_next",)

    def __init__(self) -> None:
        self._next = 0

    def next_id(self) -> int:
        tid = self._next
        self._next += 1
        return tid

    def new(self, name: str) -> CPUTask:
        return CPUTask(name, self.next_id())
