"""Cluster-level metric aggregation (paper §6.1.3).

`collect` reads a finished `Cluster` and produces the frozen,
serializable `ExperimentResult` (see `repro.sim.results`); the
embodied-carbon columns are priced by the experiment's configured
carbon model (`cfg.carbon_model`, see `repro.carbon`).
"""
from __future__ import annotations

import numpy as np

from repro.carbon import get_carbon_model, reference_degradation
from repro.carbon.base import CarbonModel, LifetimeEstimate
from repro.carbon.intensity import ConstantIntensity
from repro.carbon.models import HOURS_PER_YEAR
from repro.power import get_power_model
from repro.power.base import PowerModel
from repro.sim.cluster import Cluster
from repro.sim.config import ExperimentConfig
from repro.sim.results import ExperimentResult, Provenance

PERCENTILES = (1, 25, 50, 75, 90, 99)
_SECONDS_PER_YEAR = HOURS_PER_YEAR * 3600.0


def _role_weighted_cv(degs: np.ndarray, n_prompt: int) -> float:
    """Cross-machine degradation CV within each serving role, weighted
    by machine count (see `ExperimentResult.fleet_degradation_cv`)."""
    parts = []
    for group in (degs[:n_prompt], degs[n_prompt:]):
        mean = float(group.mean()) if len(group) else 0.0
        if mean > 0:
            parts.append((len(group), float(group.std()) / mean))
    if not parts:
        return float("nan")
    total = sum(n for n, _ in parts)
    return sum(n * cv for n, cv in parts) / total


def collect(cluster: Cluster, cfg: ExperimentConfig,
            carbon_model: CarbonModel | None = None,
            power_model: PowerModel | None = None,
            telemetry=None) -> ExperimentResult:
    """Aggregate a finished cluster run into an `ExperimentResult`.

    The config supplies the experiment identity (policy / scenario /
    router / carbon model / power model + opts) and the provenance
    fingerprint; the pre-PR-5 `collect(cluster, policy, num_cores,
    rate_rps, ...)` keyword pile is gone. `carbon_model` /
    `power_model` let a caller that already resolved `cfg.carbon_model`
    / `cfg.power_model` (e.g. `run_experiment`'s fail-fast check) pass
    them in instead of constructing them twice. `telemetry` (a
    `repro.telemetry.TelemetryHub`) additionally receives the fleet's
    per-window power / energy / intensity / operational-carbon rows.
    """
    cvs, degs, idle_all = [], [], []
    task_samples = []
    for m in cluster.machines:
        snap = m.manager.snapshot()
        cvs.append(snap["cv"])
        degs.append(snap["mean_degradation"])
        idle_all.extend(m.manager.metrics.idle_norm_samples)
        task_samples.append(np.asarray(m.task_count_samples))
    cvs = np.asarray(cvs)
    degs = np.asarray(degs)
    idle_all = np.asarray(idle_all) if idle_all else np.zeros(1)
    # Streaming latency aggregate (ROADMAP 1d): the cluster observed
    # each completion as it happened; in exact mode the aggregate
    # evaluates the same numpy expressions over the same sample order
    # the historical per-request array did, so pinned goldens hold.
    # When nothing completed it reports NaN, not a fabricated perfect
    # latency of 0.0 that would rank a starved config as winning.
    mean_latency = cluster.latency.mean()
    p99_latency = cluster.latency.percentile(99)
    all_tasks = np.concatenate(task_samples) if task_samples else np.zeros(1)

    elapsed = max(m.manager.now for m in cluster.machines)
    residencies = tuple(m.manager.residency() for m in cluster.machines)
    robustness = None
    if cluster.faults is not None:
        fc = cluster.faults
        robustness = fc.robustness(elapsed)
        # conservation residual: requests still in flight at the horizon
        robustness["pending_requests"] = (
            fc.submitted - cluster.completed_count
            - fc.failed_requests - fc.rejected_requests)
    return price_and_build(
        cfg,
        cvs=cvs,
        degs=degs,
        idle_norm_percentiles=percentile_dict(idle_all),
        oversub_frac_below=float((idle_all < -0.1).mean()),
        task_count_mean=float(all_tasks.mean()),
        task_count_max=int(all_tasks.max()),
        mean_latency_s=mean_latency,
        p99_latency_s=p99_latency,
        completed=cluster.completed_count,
        aging_params=cluster.machines[0].manager.params,
        elapsed=elapsed,
        residencies=residencies,
        robustness=robustness,
        per_machine_idle_norm=tuple(
            tuple(float(x) for x in m.manager.metrics.idle_norm_samples)
            for m in cluster.machines),
        per_machine_task_samples=tuple(
            tuple(int(x) for x in samples) for samples in task_samples),
        engine="event",
        carbon_model=carbon_model,
        power_model=power_model,
        fleet_inventory=cluster.inventory,
        telemetry=telemetry,
    )


def percentile_dict(x) -> dict[int, float]:
    """The result schema's standard percentile summary of a sample."""
    return {p: float(np.percentile(x, p)) for p in PERCENTILES}


def price_and_build(cfg: ExperimentConfig, *,
                    cvs, degs,
                    idle_norm_percentiles: dict[int, float],
                    oversub_frac_below: float,
                    task_count_mean: float, task_count_max: int,
                    mean_latency_s: float, p99_latency_s: float,
                    completed: int,
                    aging_params, elapsed: float,
                    residencies,
                    per_machine_idle_norm=None,
                    per_machine_task_samples=None,
                    engine: str = "event",
                    robustness: dict | None = None,
                    carbon_model: CarbonModel | None = None,
                    power_model: PowerModel | None = None,
                    fleet_inventory=None,
                    telemetry=None) -> ExperimentResult:
    """Price per-machine aging + residencies into carbon/power columns
    and assemble the `ExperimentResult`. Shared by both engines: the
    event path (`collect`, from a finished `Cluster`) and the fleet
    path (`repro.sim.fleetsim`, from stacked arrays) feed the same
    observables through the exact same pricing expressions, so a parity
    diff between engines compares simulation physics, not accounting.

    `fleet_inventory` (a `repro.hardware.FleetInventory`, None on the
    uniform default) switches pricing from fleet-wide constants to each
    machine's own SKU: per-SKU embodied figures and baseline lifespans
    on the carbon side, TDP-scaled power/energy, per-SKU aging
    references, and `t0_s`-phase-shifted intensity signals.
    """
    cvs = np.asarray(cvs)
    degs = np.asarray(degs)
    inv = fleet_inventory

    # Fleet-level aging imbalance + per-machine embodied carbon vs the
    # worst-case linear-aging reference at the same horizon, priced by
    # the experiment's configured carbon model.
    fleet_cv = _role_weighted_cv(degs, cfg.n_prompt)
    deg_ref = reference_degradation(aging_params, elapsed)
    model = carbon_model if carbon_model is not None else \
        get_carbon_model(cfg.carbon_model, **cfg.carbon_options)
    if inv is None:
        per_machine_carbon = tuple(
            model.lifetime(deg_ref, max(float(d), 0.0)) for d in degs)
    else:
        # Each machine prices against its own SKU: its embodied figure
        # and baseline lifespan, and the aging reference of its own
        # process corner (f_nominal enters the linear reference).
        models = inv.carbon_models(cfg.carbon_model, cfg.carbon_options)
        deg_refs = tuple(reference_degradation(p, elapsed)
                         for p in inv.aging_params)
        per_machine_carbon = tuple(
            models[i].lifetime(deg_refs[i], max(float(d), 0.0))
            for i, d in enumerate(degs))
    fleet_yearly = float(sum(e.yearly_kgco2eq for e in per_machine_carbon))

    # Operational side: price each machine's measured C-state residency
    # through the configured power model, and its energy through the
    # carbon model's grid intensity (flat world-average when the model
    # carries none) — window by window, so time-of-day carbon variation
    # genuinely reaches the headline numbers.
    power = power_model if power_model is not None else \
        get_power_model(cfg.power_model, **cfg.power_options)
    residencies = tuple(residencies)
    intensity = getattr(model, "intensity", None)
    if intensity is None:
        intensity = ConstantIntensity()
    if inv is None:
        energies = tuple(power.energy_kwh(r) for r in residencies)
        op_kg = float(sum(power.operational_g(r, intensity)
                          for r in residencies)) / 1000.0
    else:
        # TDP-scaled per SKU; operational carbon integrates against the
        # machine's own (possibly phase-shifted) intensity signal.
        energies = tuple(inv.power_scales[i] * power.energy_kwh(r)
                         for i, r in enumerate(residencies))
        op_kg = float(sum(
            inv.power_scales[i]
            * power.operational_g(r, inv.intensity_for(i, intensity))
            for i, r in enumerate(residencies))) / 1000.0
    fleet_energy = float(sum(energies))
    if elapsed > 0:
        yearly_op = op_kg * (_SECONDS_PER_YEAR / elapsed)
        mean_power_w = (fleet_energy * 3.6e6
                        / (elapsed * len(residencies)))
    else:
        yearly_op = mean_power_w = float("nan")

    if telemetry is not None:
        _emit_carbon_windows(telemetry, residencies, power, intensity)

    return ExperimentResult(
        policy=cfg.policy,
        num_cores=cfg.num_cores,
        rate_rps=cfg.rate_rps,
        scenario=cfg.scenario,
        freq_cv_percentiles=percentile_dict(cvs),
        mean_degradation_percentiles=percentile_dict(degs),
        idle_norm_percentiles=idle_norm_percentiles,
        oversub_frac_below=oversub_frac_below,
        task_count_mean=task_count_mean,
        task_count_max=task_count_max,
        mean_latency_s=mean_latency_s,
        p99_latency_s=p99_latency_s,
        completed=completed,
        router=cfg.router,
        carbon_model=cfg.carbon_model,
        carbon_opts=cfg.carbon_opts,
        fleet_degradation_cv=fleet_cv,
        per_machine_carbon=per_machine_carbon,
        fleet_yearly_kgco2eq=fleet_yearly,
        deg_reference=float(deg_ref),
        power_model=cfg.power_model,
        power_opts=cfg.power_opts,
        per_machine_energy_kwh=energies,
        per_machine_residency=residencies,
        fleet_energy_kwh=fleet_energy,
        mean_machine_power_w=mean_power_w,
        fleet_operational_kgco2eq=op_kg,
        fleet_yearly_operational_kgco2eq=yearly_op,
        fleet_yearly_total_kgco2eq=fleet_yearly + yearly_op,
        per_machine_cv=tuple(float(x) for x in cvs),
        per_machine_degradation=tuple(float(x) for x in degs),
        per_machine_idle_norm=per_machine_idle_norm,
        per_machine_task_samples=per_machine_task_samples,
        engine=engine,
        fault_model=cfg.fault_model,
        fault_opts=cfg.fault_opts,
        fleet=cfg.fleet,
        fleet_opts=cfg.fleet_opts,
        per_machine_sku=(None if inv is None else inv.sku_names),
        **(robustness or {}),
        provenance=Provenance(config_hash=cfg.fingerprint(),
                              seed=cfg.seed),
    )


def _emit_carbon_windows(telemetry, residencies, power, intensity) -> None:
    """Fleet per-window power/energy/intensity/operational-carbon rows
    into the hub's `fleet/carbon_windows` timeline — the same windowed
    integral `operational_g` prices, kept visible instead of collapsed
    to one scalar. Row layout: `(window_s, fleet_power_w, energy_kwh,
    g_per_kwh, operational_g)`; pure reads of frozen residencies."""
    fleet: dict[float, list[float]] = {}    # t_start -> [elapsed, joules]
    for r in residencies:
        f = r.mean_busy_frequency
        n = r.num_cores
        for t_start, elapsed, bf, if_, gf in r.iter_windows():
            w = fleet.setdefault(t_start, [0.0, 0.0])
            w[0] = max(w[0], elapsed)
            w[1] += power.machine_power_w(bf, if_, gf, f, n) * elapsed
    tl = telemetry.timeline("fleet/carbon_windows",
                            maxlen=max(len(fleet), 1))
    for t_start in sorted(fleet):
        elapsed, joules = fleet[t_start]
        g = intensity.g_per_kwh(t_start + 0.5 * elapsed)
        kwh = joules / 3.6e6
        power_w = joules / elapsed if elapsed > 0 else 0.0
        tl.record(t_start, (elapsed, power_w, kwh, g, kwh * g))


def carbon_comparison(linux_metrics: ExperimentResult,
                      technique_metrics: ExperimentResult,
                      percentile: int = 99,
                      model: str | CarbonModel | None = None,
                      ) -> LifetimeEstimate:
    """Fig. 7: estimate yearly embodied carbon from the p-th percentile of
    mean-frequency-degradation performance (paper uses p99 and p50).

    `model` selects the carbon model (registry name or instance); the
    default honours the technique result's own `carbon_model` *and*
    `carbon_opts`, so a sweep run under `reliability-threshold` — or a
    custom `embodied_kg` — is compared under exactly that pricing. A
    name passed explicitly is built with default opts.
    """
    if model is None:
        model = get_carbon_model(technique_metrics.carbon_model,
                                 **dict(technique_metrics.carbon_opts))
    elif not isinstance(model, CarbonModel):
        model = get_carbon_model(model)
    deg_linux = linux_metrics.mean_degradation_percentiles[percentile]
    deg_tech = technique_metrics.mean_degradation_percentiles[percentile]
    return model.lifetime(deg_linux, deg_tech)
