"""Cluster-level metric aggregation (paper §6.1.3)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import carbon
from repro.sim.cluster import Cluster

PERCENTILES = (1, 25, 50, 75, 90, 99)


@dataclasses.dataclass
class ExperimentMetrics:
    policy: str
    num_cores: int
    rate_rps: float
    scenario: str
    # paper Fig. 6: CV of per-server core-frequency distribution, and mean
    # frequency degradation, percentiled across the cluster's machines.
    freq_cv_percentiles: dict
    mean_degradation_percentiles: dict
    # paper Fig. 8: normalized idle cores distribution (negative = oversub)
    idle_norm_percentiles: dict
    oversub_frac_below: float      # fraction of samples below -0.1
    # paper Fig. 2: concurrent CPU tasks per machine
    task_count_mean: float
    task_count_max: int
    # service quality (NaN when nothing completed — a starved config must
    # never rank as winning a latency comparison)
    mean_latency_s: float
    p99_latency_s: float
    completed: int
    # cluster-routing axis (see `repro.sim.routing`)
    router: str = "jsq"
    # fleet-level aging imbalance: cross-machine CV of per-machine mean
    # frequency degradation, computed within each serving role (prompt /
    # token) and machine-count-weighted. A cluster router can only level
    # aging among peers serving the same phase — the prompt/token role
    # gap is deployment topology, not routing quality — so mixing roles
    # into one CV would swamp the quantity routing actually controls.
    fleet_degradation_cv: float = float("nan")
    # per-machine embodied-carbon estimates vs the worst-case
    # linear-aging reference at the same horizon, and their fleet total
    per_machine_carbon: list = None
    fleet_yearly_kgco2eq: float = float("nan")
    # raw per-machine values for downstream carbon estimates
    per_machine_cv: np.ndarray = None
    per_machine_degradation: np.ndarray = None
    per_machine_idle_norm: list = None
    per_machine_task_samples: list = None


def _role_weighted_cv(degs: np.ndarray, n_prompt: int) -> float:
    """Cross-machine degradation CV within each serving role, weighted
    by machine count (see `ExperimentMetrics.fleet_degradation_cv`)."""
    parts = []
    for group in (degs[:n_prompt], degs[n_prompt:]):
        mean = float(group.mean()) if len(group) else 0.0
        if mean > 0:
            parts.append((len(group), float(group.std()) / mean))
    if not parts:
        return float("nan")
    total = sum(n for n, _ in parts)
    return sum(n * cv for n, cv in parts) / total


def collect(cluster: Cluster, policy: str, num_cores: int,
            rate_rps: float,
            scenario: str = "conversation-poisson",
            router: str = "jsq") -> ExperimentMetrics:
    cvs, degs, idle_all = [], [], []
    task_samples = []
    for m in cluster.machines:
        snap = m.manager.snapshot()
        cvs.append(snap["cv"])
        degs.append(snap["mean_degradation"])
        idle_all.extend(m.manager.metrics.idle_norm_samples)
        task_samples.append(np.asarray(m.task_count_samples))
    cvs = np.asarray(cvs)
    degs = np.asarray(degs)
    idle_all = np.asarray(idle_all) if idle_all else np.zeros(1)
    if cluster.completed:
        lat = np.asarray([rs.t_done - rs.t_arrival
                          for rs in cluster.completed])
        mean_latency = float(lat.mean())
        p99_latency = float(np.percentile(lat, 99))
    else:
        # Nothing completed: report NaN, not a fabricated perfect
        # latency of 0.0 that would rank a starved config as winning.
        mean_latency = p99_latency = float("nan")
    all_tasks = np.concatenate(task_samples) if task_samples else np.zeros(1)

    # Fleet-level aging imbalance + per-machine embodied carbon vs the
    # worst-case linear-aging reference at the same horizon.
    fleet_cv = _role_weighted_cv(degs, len(cluster.prompt_instances))
    elapsed = max(m.manager.now for m in cluster.machines)
    deg_ref = carbon.reference_degradation(
        cluster.machines[0].manager.params, elapsed)
    per_machine_carbon = [carbon.estimate(deg_ref, max(float(d), 0.0))
                          for d in degs]

    def pct(x):
        return {p: float(np.percentile(x, p)) for p in PERCENTILES}

    return ExperimentMetrics(
        policy=policy,
        num_cores=num_cores,
        rate_rps=rate_rps,
        scenario=scenario,
        freq_cv_percentiles=pct(cvs),
        mean_degradation_percentiles=pct(degs),
        idle_norm_percentiles=pct(idle_all),
        oversub_frac_below=float((idle_all < -0.1).mean()),
        task_count_mean=float(all_tasks.mean()),
        task_count_max=int(all_tasks.max()),
        mean_latency_s=mean_latency,
        p99_latency_s=p99_latency,
        completed=len(cluster.completed),
        router=router,
        fleet_degradation_cv=fleet_cv,
        per_machine_carbon=per_machine_carbon,
        fleet_yearly_kgco2eq=float(sum(e.yearly_kgco2eq
                                       for e in per_machine_carbon)),
        per_machine_cv=cvs,
        per_machine_degradation=degs,
        per_machine_idle_norm=[np.asarray(m.manager.metrics.idle_norm_samples)
                               for m in cluster.machines],
        per_machine_task_samples=task_samples,
    )


def carbon_comparison(linux_metrics: ExperimentMetrics,
                      technique_metrics: ExperimentMetrics,
                      percentile: int = 99) -> carbon.CarbonEstimate:
    """Fig. 7: estimate yearly embodied carbon from the p-th percentile of
    mean-frequency-degradation performance (paper uses p99 and p50)."""
    deg_linux = linux_metrics.mean_degradation_percentiles[percentile]
    deg_tech = technique_metrics.mean_degradation_percentiles[percentile]
    return carbon.estimate(deg_linux, deg_tech)
