"""Cluster-level request routing — the paper's *second* allocation layer.

The technique allocates at two levels: per-core task mapping inside one
server (Algorithm 1, `repro.core.policies`) and "aging-aware inference
task allocation" across the fleet (paper §5). This module makes the
fleet-level decision pluggable the same way `repro.core.policies` and
`repro.workloads` made the per-core and workload axes pluggable: a
string-keyed registry of `ClusterRouter` strategies that decide

  * which *prompt* instance admits an arriving request, and
  * which *token* instance receives its KV-cache flow,

given a read-only `FleetView` (per-instance queue depths / decode loads
plus per-machine CPU aging snapshots: mean frequency degradation,
frequency CV, active-core count).

Built-ins:

  jsq            — join-shortest-queue / least-loaded (bit-exact with the
                   previously hard-coded `Cluster` behaviour)
  round-robin    — cyclic placement strawman
  power-of-two   — sample two instances, take the less loaded (Mitzenmacher)
  least-aged-cpu — among load-feasible instances, route toward the
                   machine with the freshest host CPU (evens fleet aging)
  carbon-greedy  — EcoServe-style: among load-feasible instances, pick the
                   placement minimizing projected fleet yearly embodied
                   carbon under a pluggable `repro.carbon` model
                   (default `linear-extension`); NBTI aging is concave
                   in time, so the marginal carbon of one more task is
                   smallest on the *most* aged machine — old servers
                   soak up load while fresh ones amortize slowly.
  footprint-greedy — carbon-greedy plus the task's *operational* grams
                   under a `repro.power` model and a time-varying grid
                   intensity: full-footprint marginal scoring that
                   re-weights the embodied/operational trade hour by
                   hour.
  generation-aware — GreenLLM-style placement over mixed fleets
                   (`repro.hardware`): pin latency-tolerant decode on
                   the oldest-generation / most-aged feasible machines
                   and steer prompt bursts toward the newest SKUs,
                   sized by the pending request's prompt/decode token
                   counts. Degrades to load-feasible jsq tie-breaking
                   on the uniform default fleet.

Routers are per-cluster objects (they may carry cursors or RNG-driven
state) and must route through the `FleetView` only — they never see the
`Cluster` or mutate machine state.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import numpy as np

from repro.carbon import get_carbon_model, reference_degradation
from repro.carbon.base import BASELINE_LIFESPAN_YEARS, CarbonModel
from repro.carbon.intensity import ConstantIntensity, get_intensity
from repro.core import aging, temperature
from repro.power import get_power_model
from repro.power.base import PowerModel
from repro.registry import Registry, canonical_name


# --------------------------------------------------------------------- #
# read-only fleet state
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class MachineAging:
    """Point-in-time aging snapshot of one machine's host CPU (the data a
    fleet scheduler would read from per-server aging sensors, paper §5)."""

    machine_id: int
    mean_degradation: float   # mean(f0 - f) over cores, settled to `now`
    freq_cv: float            # std(f)/mean(f) over cores
    active_cores: int         # cores not power-gated (C6)
    mean_dvth: float          # mean threshold-voltage shift [V]
    mean_f0: float            # mean process-variation initial frequency


class FleetView:
    """Read-only window onto a `Cluster` for routing decisions.

    Mirrors `repro.core.policies.CoreView` one level up: routers get
    load and aging observability but no mutable handles. Aging
    snapshots are settled to `now` *without* mutating manager state
    (`CoreManager._settled_dvth` is pure), so a router that never reads
    them — e.g. `jsq` — leaves the simulation bit-exact.
    """

    __slots__ = ("_c",)

    def __init__(self, cluster):
        self._c = cluster

    # -- shape / clock ------------------------------------------------- #
    @property
    def now(self) -> float:
        return self._c.queue.now

    @property
    def n_prompt(self) -> int:
        return len(self._c.prompt_instances)

    @property
    def n_token(self) -> int:
        return len(self._c.token_instances)

    @property
    def rng(self) -> np.random.Generator:
        """Cluster-owned router RNG (seeded from the experiment seed)."""
        return self._c.router_rng

    @property
    def aging_params(self) -> aging.AgingParams:
        return self._c.machines[0].manager.params

    @property
    def num_cores(self) -> int:
        """Host-CPU core count per machine (homogeneous fleet)."""
        return self._c.machines[0].manager.num_cores

    # -- hardware (heterogeneous-fleet layer) -------------------------- #
    # Per-machine SKU columns in fleet order (prompt machines first,
    # then token machines — the order `Cluster` builds them). On the
    # uniform default fleet (`cluster.inventory is None`) these return
    # constants, so reading them never breaks bit-exactness.
    def generations(self) -> np.ndarray:
        """(n_machines,) int — hardware generation per machine (0 on
        the uniform default fleet)."""
        inv = self._c.inventory
        if inv is None:
            return np.zeros(len(self._c.machines), dtype=np.int64)
        return np.asarray(inv.generations, dtype=np.int64)

    def core_counts(self) -> np.ndarray:
        """(n_machines,) int — host-CPU core count per machine."""
        inv = self._c.inventory
        if inv is None:
            return np.full(len(self._c.machines),
                           self._c.machines[0].manager.num_cores,
                           dtype=np.int64)
        return np.asarray(inv.num_cores, dtype=np.int64)

    def sku_names(self) -> tuple:
        """Per-machine SKU registry names, fleet order (`None` per
        machine on the uniform default fleet)."""
        inv = self._c.inventory
        if inv is None:
            return (None,) * len(self._c.machines)
        return inv.sku_names

    def prompt_generations(self) -> np.ndarray:
        """(n_prompt,) int — generation of each prompt instance's host."""
        return self.generations()[: self.n_prompt]

    def token_generations(self) -> np.ndarray:
        """(n_token,) int — generation of each token instance's host."""
        g = self.generations()
        return g[self.n_prompt: self.n_prompt + self.n_token]

    # -- pending request (size-aware routing hook) --------------------- #
    # The cluster stamps the request being placed just before each
    # routing call, so routers can weigh request *size* (e.g. steer
    # prompt bursts to fast new SKUs). 0.0 outside a routing call.
    @property
    def pending_prompt_tokens(self) -> float:
        """Prompt length [tokens] of the request being routed."""
        req = self._c.pending_request
        return 0.0 if req is None else float(req.input_tokens)

    @property
    def pending_decode_tokens(self) -> float:
        """Decode length [tokens] of the request being routed."""
        req = self._c.pending_request
        return 0.0 if req is None else float(req.output_tokens)

    # -- load ---------------------------------------------------------- #
    def prompt_depths(self) -> np.ndarray:
        """(n_prompt,) int — queued + in-flight prefills per instance."""
        return np.asarray([len(p.queue) + p.busy
                           for p in self._c.prompt_instances])

    def token_loads(self) -> np.ndarray:
        """(n_token,) int — active + pending decode requests per instance."""
        return np.asarray([t.load for t in self._c.token_instances])

    # -- health (fault layer) ------------------------------------------ #
    # All-True / all-zero with the default "none" fault model; a
    # health-aware router can weight these without breaking bit-exactness
    # of faultless runs (it just reads constants).
    def prompt_up(self) -> np.ndarray:
        """(n_prompt,) bool — prompt machine is powered (not rebooting)."""
        return np.asarray([getattr(p.machine, "up", True)
                           for p in self._c.prompt_instances])

    def token_up(self) -> np.ndarray:
        """(n_token,) bool — token machine is powered (not rebooting)."""
        return np.asarray([getattr(t.machine, "up", True)
                           for t in self._c.token_instances])

    def machine_up(self) -> np.ndarray:
        """(n_machines,) bool — per-machine power state, fleet order."""
        return np.asarray([getattr(m, "up", True)
                           for m in self._c.machines])

    def offline_cores(self) -> np.ndarray:
        """(n_machines,) int — permanently failed cores per machine."""
        return np.asarray([int(m.manager.failed.sum())
                           for m in self._c.machines])

    # -- aging --------------------------------------------------------- #
    def _snapshot(self, machine) -> MachineAging:
        m = machine.manager
        dvth = m._settled_dvth(self.now)
        f = aging.frequency(m.params, m.f0, dvth)
        return MachineAging(
            machine_id=machine.machine_id,
            mean_degradation=float(np.mean(m.f0 - f)),
            freq_cv=float(np.std(f) / np.mean(f)),
            active_cores=int((m.c_state == temperature.CState.ACTIVE).sum()),
            mean_dvth=float(np.mean(dvth)),
            mean_f0=float(np.mean(m.f0)),
        )

    def prompt_aging(self, indices=None) -> tuple[MachineAging, ...]:
        """Snapshots of the prompt machines' CPUs; pass `indices` to
        snapshot only candidate instances (each snapshot settles every
        core of its machine — skipping non-candidates matters on the
        per-request hot path)."""
        inst = self._c.prompt_instances
        if indices is None:
            indices = range(len(inst))
        return tuple(self._snapshot(inst[i].machine) for i in indices)

    def token_aging(self, indices=None) -> tuple[MachineAging, ...]:
        """Snapshots of the token machines' CPUs (see `prompt_aging`)."""
        inst = self._c.token_instances
        if indices is None:
            indices = range(len(inst))
        return tuple(self._snapshot(inst[i].machine) for i in indices)


# --------------------------------------------------------------------- #
# protocol + registry
# --------------------------------------------------------------------- #
class ClusterRouter:
    """Base class for cluster-level request-routing strategies.

    Subclasses register under a string key with `@register_router(name)`
    and are instantiated per-cluster via `get_router(name, **opts)`.
    Both hooks return an *index* (into the prompt / token instance
    lists), not a machine id.
    """

    #: canonical registry key, set by @register_router
    name: ClassVar[str] = "?"

    def select_prompt(self, fleet: FleetView) -> int:
        """Pick the prompt instance that admits the next request."""
        raise NotImplementedError

    def select_token(self, fleet: FleetView) -> int:
        """Pick the token instance that receives a finished prefill's
        KV-cache flow."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"


# Shared registry mechanics (`repro.registry.Registry`) — one
# implementation for the policy / scenario / router axes.
_ROUTERS = Registry(
    noun="router", kind="cluster router", decorator="register_router",
    expects="ClusterRouter subclass",
    check=lambda cls: isinstance(cls, type) and issubclass(cls,
                                                           ClusterRouter),
)
#: historical module-level alias (tests clean up through it)
_REGISTRY = _ROUTERS.store


def canonical_router_name(name: str) -> str:
    """Normalize a user-supplied router key ("Power_Of_Two" style)."""
    return canonical_name(name)


def register_router(name: str):
    """Class decorator: register a `ClusterRouter` subclass under `name`."""
    return _ROUTERS.register(name)


def get_router(name: str, **opts) -> ClusterRouter:
    """Instantiate the router registered under `name` with `opts`."""
    return _ROUTERS.get(name, **opts)


def available_routers() -> tuple[str, ...]:
    """Sorted canonical names of every registered router."""
    return _ROUTERS.available()


# --------------------------------------------------------------------- #
# built-ins
# --------------------------------------------------------------------- #
@register_router("jsq")
class JSQRouter(ClusterRouter):
    """Join-shortest-queue prompts + least-loaded tokens.

    Bit-exact with the behaviour `Cluster` hard-coded before routing
    became pluggable (golden-pinned in tests): first minimum wins ties,
    and no aging state or RNG is read.
    """

    def select_prompt(self, fleet: FleetView) -> int:
        return int(np.argmin(fleet.prompt_depths()))

    def select_token(self, fleet: FleetView) -> int:
        return int(np.argmin(fleet.token_loads()))


@register_router("round-robin")
class RoundRobinRouter(ClusterRouter):
    """Cyclic placement, load- and aging-oblivious."""

    def __init__(self):
        self._p = 0
        self._t = 0

    def select_prompt(self, fleet: FleetView) -> int:
        i = self._p % fleet.n_prompt
        self._p += 1
        return i

    def select_token(self, fleet: FleetView) -> int:
        i = self._t % fleet.n_token
        self._t += 1
        return i


@register_router("power-of-two")
class PowerOfTwoRouter(ClusterRouter):
    """Sample two instances uniformly, route to the less loaded one
    (the power-of-two-choices load balancer). Uses the cluster's
    seeded router RNG, so runs stay reproducible."""

    @staticmethod
    def _pick(rng: np.random.Generator, loads: np.ndarray) -> int:
        n = len(loads)
        if n == 1:
            return 0
        i, j = rng.choice(n, size=2, replace=False)
        return int(i if loads[i] <= loads[j] else j)

    def select_prompt(self, fleet: FleetView) -> int:
        return self._pick(fleet.rng, fleet.prompt_depths())

    def select_token(self, fleet: FleetView) -> int:
        return self._pick(fleet.rng, fleet.token_loads())


def _feasible(loads: np.ndarray, slack: int) -> np.ndarray:
    """Indices whose load is within `slack` of the minimum — the
    candidates an aging/carbon-aware router may choose among without
    sacrificing service quality."""
    return np.flatnonzero(loads <= loads.min() + slack)


@register_router("least-aged-cpu")
class LeastAgedCPURouter(ClusterRouter):
    """Route toward the machines with the freshest host CPUs.

    Among instances whose load is within `slack` of the fleet minimum,
    pick the one whose host CPU shows the smallest settled mean
    frequency degradation. The default `slack=0` strictly refines jsq:
    load placement quality is untouched and only ties — which jsq breaks
    by a fixed index bias — are broken toward the freshest machine,
    evening out cross-machine aging (lower fleet degradation CV).
    Raising `slack` trades queue evenness for stronger wear-leveling;
    NBTI's concave time dependence makes large slacks overshoot.
    """

    def __init__(self, slack: int = 0):
        if slack < 0:
            raise ValueError(f"slack must be >= 0, got {slack}")
        self.slack = slack

    def _select(self, loads, snapshot) -> int:
        cand = _feasible(loads, self.slack)
        if len(cand) == 1:
            return int(cand[0])
        deg = [s.mean_degradation for s in snapshot(cand)]
        return int(cand[int(np.argmin(deg))])

    def select_prompt(self, fleet: FleetView) -> int:
        return self._select(fleet.prompt_depths(), fleet.prompt_aging)

    def select_token(self, fleet: FleetView) -> int:
        return self._select(fleet.token_loads(), fleet.token_aging)


@register_router("carbon-greedy")
class CarbonGreedyRouter(ClusterRouter):
    """Minimize projected fleet yearly embodied carbon (EcoServe-style).

    For each load-feasible candidate, project the machine's mean
    degradation after absorbing one more task interval (`tau_s` of
    active-allocated NBTI stress on its mean dVth) and price the whole
    fleet with a pluggable `repro.carbon` model against a worst-case
    linear-aging reference at the same horizon. NBTI is concave in
    accumulated stress time, so the marginal carbon of a task is
    smallest on the most-aged machine: carbon-greedy concentrates load
    on old CPUs and shelters fresh ones — the opposite of
    `least-aged-cpu`, and the trade EcoServe exploits.

    `carbon_model` is a registry name (or `CarbonModel` instance) with
    `carbon_opts` for its constructor; the default `linear-extension`
    is bit-exact with the pre-subsystem hard-coded scoring, and
    `reliability-threshold` sharpens the concavity (steeper marginal
    differences between fresh and aged machines).
    """

    def __init__(self, slack: int = 2, tau_s: float = 0.01,
                 carbon_model="linear-extension", carbon_opts=None):
        if slack < 0:
            raise ValueError(f"slack must be >= 0, got {slack}")
        if tau_s <= 0.0:
            raise ValueError(f"tau_s must be > 0, got {tau_s}")
        self.slack = slack
        self.tau_s = tau_s
        if isinstance(carbon_model, CarbonModel):
            if carbon_opts:
                raise TypeError("carbon_opts only apply when carbon_model "
                                "is a registry name, got an instance")
            self.carbon_model = carbon_model
        else:
            self.carbon_model = get_carbon_model(carbon_model,
                                                 **dict(carbon_opts or {}))

    def _select(self, fleet: FleetView, loads, snapshot) -> int:
        cand = _feasible(loads, self.slack)
        if len(cand) == 1:
            return int(cand[0])
        params = fleet.aging_params
        deg_ref = reference_degradation(params, fleet.now)
        adf_active = params.K * aging.adf_unscaled_cached(
            params, temperature.TEMP_ACTIVE_ALLOCATED_C,
            temperature.STRESS_ACTIVE)
        lifetime = self.carbon_model.lifetime
        # Fleet totals across candidates share every j != i term, so the
        # argmin over projected fleet carbon reduces to the candidate's
        # own marginal increase.
        best, best_delta = int(cand[0]), np.inf
        for i, s in zip(cand, snapshot(cand)):
            dvth_next = aging.advance_dvth_scalar(
                params, s.mean_dvth, adf_active, self.tau_s)
            deg_next = s.mean_degradation \
                + s.mean_f0 * (dvth_next - s.mean_dvth) / params.headroom
            delta = (lifetime(deg_ref, max(deg_next, 0.0)).yearly_kgco2eq
                     - lifetime(deg_ref, max(s.mean_degradation, 0.0))
                     .yearly_kgco2eq)
            if delta < best_delta:
                best, best_delta = int(i), delta
        return best

    def select_prompt(self, fleet: FleetView) -> int:
        return self._select(fleet, fleet.prompt_depths(),
                            fleet.prompt_aging)

    def select_token(self, fleet: FleetView) -> int:
        return self._select(fleet, fleet.token_loads(), fleet.token_aging)


@register_router("footprint-greedy")
class FootprintGreedyRouter(CarbonGreedyRouter):
    """Minimize the task's full footprint: embodied AND operational.

    Extends `carbon-greedy`'s marginal scoring with the task's
    operational grams under a `repro.power` model and a time-varying
    grid intensity:

      embodied_g    = delta yearly embodied [kg/yr] * 1000
                        * embodied_horizon_years
      operational_g = marginal_task_w(f_i) * (tau_s / f_i) / 3.6e6
                        * intensity(now)

    where `f_i` is the candidate machine's settled mean frequency. The
    two terms genuinely pull apart: NBTI concavity makes embodied
    cheapest on the *most*-aged machine, while an `ondemand`-governor
    power model makes a task's energy `tau * (min_w / f + (max_w -
    min_w))` — *highest* there (slower core, longer on-time). The
    intensity term re-weights that trade hour by hour, so placement
    leans operational during dirty-grid hours and embodied during clean
    ones. Under `flat-tdp` the marginal watts are zero and the router
    degenerates to `carbon-greedy`.

    `intensity=None` (default) borrows the carbon model's own
    `.intensity` when it has one (e.g. `operational-embodied`), so one
    diurnal spec can drive pricing, policy, and routing coherently.
    """

    def __init__(self, slack: int = 2, tau_s: float = 0.01,
                 carbon_model="linear-extension", carbon_opts=None,
                 power_model="minmax-linear", power_opts=None,
                 intensity=None, intensity_opts=None,
                 embodied_horizon_years: float = BASELINE_LIFESPAN_YEARS):
        super().__init__(slack=slack, tau_s=tau_s,
                         carbon_model=carbon_model,
                         carbon_opts=carbon_opts)
        if embodied_horizon_years <= 0.0:
            raise ValueError(f"embodied_horizon_years must be > 0, got "
                             f"{embodied_horizon_years}")
        if isinstance(power_model, PowerModel):
            if power_opts:
                raise TypeError("power_opts only apply when power_model "
                                "is a registry name, got an instance")
            self.power_model = power_model
        else:
            self.power_model = get_power_model(power_model,
                                               **dict(power_opts or {}))
        if intensity is not None:
            self.intensity = get_intensity(intensity,
                                           **dict(intensity_opts or {}))
        else:
            self.intensity = getattr(self.carbon_model, "intensity", None)
            if self.intensity is None:
                self.intensity = ConstantIntensity()
        self.embodied_horizon_years = embodied_horizon_years

    def _select(self, fleet: FleetView, loads, snapshot) -> int:
        cand = _feasible(loads, self.slack)
        if len(cand) == 1:
            return int(cand[0])
        params = fleet.aging_params
        deg_ref = reference_degradation(params, fleet.now)
        adf_active = params.K * aging.adf_unscaled_cached(
            params, temperature.TEMP_ACTIVE_ALLOCATED_C,
            temperature.STRESS_ACTIVE)
        lifetime = self.carbon_model.lifetime
        i_now = self.intensity.g_per_kwh(fleet.now)
        n_cores = fleet.num_cores
        best, best_score = int(cand[0]), np.inf
        for i, s in zip(cand, snapshot(cand)):
            dvth_next = aging.advance_dvth_scalar(
                params, s.mean_dvth, adf_active, self.tau_s)
            deg_next = s.mean_degradation \
                + s.mean_f0 * (dvth_next - s.mean_dvth) / params.headroom
            emb_g = (lifetime(deg_ref, max(deg_next, 0.0)).yearly_kgco2eq
                     - lifetime(deg_ref, max(s.mean_degradation, 0.0))
                     .yearly_kgco2eq) \
                * 1000.0 * self.embodied_horizon_years
            f = max(s.mean_f0 - s.mean_degradation, 1e-6)
            op_g = (self.power_model.marginal_task_w(f, n_cores)
                    * (self.tau_s / f) / 3.6e6 * i_now)
            score = emb_g + op_g
            if score < best_score:
                best, best_score = int(i), score
        return best


@register_router("generation-aware")
class GenerationAwareRouter(ClusterRouter):
    """Generation-aware placement over mixed hardware fleets
    (GreenLLM-style hardware/workload matching, `repro.hardware`).

    Decode is latency-tolerant — per-token service dominates and a few
    percent of frequency loss is absorbed by batching — so
    `select_token` pins it on the *oldest-generation* load-feasible
    machine (ties broken toward the most-aged CPU via per-machine
    settled snapshots): old silicon soaks up the steady decode stream
    and its embodied carbon keeps amortizing, while new SKUs stay fresh
    and fast. Prefill is the latency-critical burst, so
    `select_prompt` steers it to the *newest-generation* feasible
    machine (ties broken jsq-style toward the least-loaded, then the
    lowest index).

    Size-awareness (the `FleetView.pending_*_tokens` hook): a request
    whose prompt is at least `long_prompt_tokens` — or whose decode is
    at least `long_decode_tokens` — widens the respective feasibility
    slack by `burst_extra_slack`, letting big compute-heavy prompts
    reach a new SKU (and long throughput-bound decodes reach an old
    one) even when it is not currently the least loaded.

    Reads per-machine aging through snapshots only (never
    `fleet.aging_params`), so mixed-SKU fleets with per-machine NBTI
    operating points route correctly. On the uniform default fleet all
    generations are 0 and the router degrades to load-feasible jsq
    tie-breaking.
    """

    def __init__(self, prompt_slack: int = 0, token_slack: int = 2,
                 long_prompt_tokens: float = 256.0,
                 long_decode_tokens: float = 64.0,
                 burst_extra_slack: int = 2):
        for label, v in (("prompt_slack", prompt_slack),
                         ("token_slack", token_slack),
                         ("burst_extra_slack", burst_extra_slack)):
            if v < 0:
                raise ValueError(f"{label} must be >= 0, got {v}")
        if long_prompt_tokens <= 0.0:
            raise ValueError(f"long_prompt_tokens must be > 0, got "
                             f"{long_prompt_tokens}")
        if long_decode_tokens <= 0.0:
            raise ValueError(f"long_decode_tokens must be > 0, got "
                             f"{long_decode_tokens}")
        self.prompt_slack = prompt_slack
        self.token_slack = token_slack
        self.long_prompt_tokens = long_prompt_tokens
        self.long_decode_tokens = long_decode_tokens
        self.burst_extra_slack = burst_extra_slack

    def select_prompt(self, fleet: FleetView) -> int:
        loads = fleet.prompt_depths()
        slack = self.prompt_slack
        if fleet.pending_prompt_tokens >= self.long_prompt_tokens:
            slack += self.burst_extra_slack
        cand = _feasible(loads, slack)
        if len(cand) == 1:
            return int(cand[0])
        gens = fleet.prompt_generations()[cand]
        new = cand[gens == gens.max()]
        if len(new) == 1:
            return int(new[0])
        return int(new[int(np.argmin(loads[new]))])

    def select_token(self, fleet: FleetView) -> int:
        loads = fleet.token_loads()
        slack = self.token_slack
        if fleet.pending_decode_tokens >= self.long_decode_tokens:
            slack += self.burst_extra_slack
        cand = _feasible(loads, slack)
        if len(cand) == 1:
            return int(cand[0])
        gens = fleet.token_generations()[cand]
        old = cand[gens == gens.min()]
        if len(old) == 1:
            return int(old[0])
        deg = [s.mean_degradation for s in fleet.token_aging(old)]
        return int(old[int(np.argmax(deg))])
