"""Bounded request-latency aggregation for the event engine (ROADMAP 1d).

`metrics.collect` historically materialized one latency array over every
completed request (`[rs.t_done - rs.t_arrival for rs in
cluster.completed]`) — O(requests) memory held until collection, the
exact pattern the fleet engine replaced with streaming `mw_*` window
columns. `LatencyAggregate` is the event-engine counterpart: the
cluster observes each completion as it happens and collection reads the
aggregate.

Two regimes:

  * Up to `exact_cap` completions the raw samples are buffered and
    `mean()` / `percentile()` evaluate `np.mean` / `np.percentile` over
    them — **bit-identical** to the historical per-request-list math
    (same values in the same order), which is what keeps the pinned
    goldens and the drift gate green without re-pinning.
  * Past the cap the buffer is spilled into a fixed log-spaced histogram
    plus running count/sum/min/max, and the memory stays O(bins)
    forever — week-long event-engine horizons no longer accumulate
    per-request state. Mean stays exact to running-sum precision;
    percentiles interpolate within the owning histogram bin.
"""
from __future__ import annotations

import numpy as np

#: default exact-buffer size — default configs complete ~1e4 requests,
#: so bit-exact mode comfortably covers every pinned golden
DEFAULT_EXACT_CAP = 1 << 18


class LatencyAggregate:
    """Streaming latency summary: exact up to a cap, bounded after."""

    __slots__ = ("count", "exact_cap", "_sum", "_min", "_max",
                 "_samples", "_edges", "_hist")

    def __init__(self, exact_cap: int = DEFAULT_EXACT_CAP,
                 bins: int = 512, lo_s: float = 1e-3, hi_s: float = 1e4):
        if exact_cap < 1:
            raise ValueError(f"exact_cap must be >= 1, got {exact_cap}")
        self.count = 0
        self.exact_cap = int(exact_cap)
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._samples: list[float] | None = []
        # log-spaced bin edges; samples outside [lo_s, hi_s] clamp into
        # the first/last bin (min/max stay exact regardless)
        self._edges = np.geomspace(lo_s, hi_s, bins + 1)
        self._hist: np.ndarray | None = None

    def observe(self, latency_s: float) -> None:
        self.count += 1
        self._sum += latency_s
        if latency_s < self._min:
            self._min = latency_s
        if latency_s > self._max:
            self._max = latency_s
        if self._samples is not None:
            self._samples.append(latency_s)
            if len(self._samples) > self.exact_cap:
                self._spill()
        else:
            self._hist[self._bin(latency_s)] += 1

    def _bin(self, x: float) -> int:
        i = int(np.searchsorted(self._edges, x, side="right")) - 1
        return min(max(i, 0), len(self._edges) - 2)

    def _spill(self) -> None:
        """Cap crossed: fold the exact buffer into the histogram and
        switch to bounded mode."""
        self._hist = np.zeros(len(self._edges) - 1, dtype=np.int64)
        idx = np.clip(
            np.searchsorted(self._edges, self._samples, side="right") - 1,
            0, len(self._edges) - 2)
        np.add.at(self._hist, idx, 1)
        self._samples = None

    @property
    def exact(self) -> bool:
        """True while every sample is still buffered (bit-exact mode)."""
        return self._samples is not None

    def mean(self) -> float:
        if self.count == 0:
            return float("nan")
        if self._samples is not None:
            # identical expression to the historical per-request list
            return float(np.asarray(self._samples).mean())
        return self._sum / self.count

    def percentile(self, p: float) -> float:
        if self.count == 0:
            return float("nan")
        if self._samples is not None:
            return float(np.percentile(np.asarray(self._samples), p))
        # histogram interpolation: walk the cumulative counts to the
        # owning bin, interpolate linearly inside it, clamp to observed
        # min/max so degenerate bins can't over/undershoot
        target = p / 100.0 * (self.count - 1)
        cum = np.cumsum(self._hist)
        b = int(np.searchsorted(cum, target, side="right"))
        b = min(b, len(self._hist) - 1)
        prev = cum[b - 1] if b > 0 else 0
        inbin = max(int(self._hist[b]), 1)
        frac = min(max((target - prev) / inbin, 0.0), 1.0)
        lo, hi = self._edges[b], self._edges[b + 1]
        return float(min(max(lo + frac * (hi - lo), self._min), self._max))
