"""Splitwise-style LLM inference cluster model (paper §5, §6.1).

Topology matches the paper's experimental cluster: 22 GPU machines run a
phase-splitting deployment with 5 *prompt* instances and 17 *token*
instances (iso-throughput power-optimized design from Splitwise [26]).
Every serving step lands a Table-2 CPU task on the host CPU of the machine
executing it; each machine's CPU is governed by a `CoreManager` (proposed
technique or a baseline policy).

GPU execution times use a linear H100 performance model (prefill cost per
input token; ORCA-style iteration-level batched decode), and the KV-cache
transfer between prompt and token machines crosses an InfiniBand link and
fires `flow_completion` on the receiving host — the same structure
splitwise-sim models.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Callable

import numpy as np

from repro.core import OVERSUBSCRIBED, CoreManager
from repro.sim.config import ExperimentConfig
from repro.sim.events import EventQueue
from repro.sim.fleetstate import FleetAgingSettler
from repro.sim.routing import FleetView, get_router
from repro.sim.tasks import TASK_DURATIONS_S, TaskIdAllocator
from repro.workloads import Request

# ----------------------------- GPU model ------------------------------ #
PREFILL_BASE_S = 0.030          # fixed prefill overhead (H100, 70B-class)
PREFILL_PER_TOKEN_S = 1.2e-4    # prefill seconds per input token
DECODE_ITER_BASE_S = 0.025      # one batched decode forward pass
DECODE_ITER_PER_REQ_S = 4.0e-4  # marginal batch cost per active request
MAX_DECODE_BATCH = 64
KV_BYTES_PER_TOKEN = 320e3      # 70B-class fp16 KV per token (all layers)
IB_LINK_BW_BPS = 25e9           # 200 Gb/s InfiniBand
OVERSUB_SLOWDOWN = 2.0          # time-sharing penalty for oversubscribed tasks


@dataclasses.dataclass
class RequestState:
    req: Request
    remaining: int
    t_arrival: float
    t_first_token: float = -1.0
    t_done: float = -1.0


class Machine:
    """One inference server: host CPU (CoreManager) + a GPU instance."""

    def __init__(self, machine_id: int, cfg: ExperimentConfig,
                 queue: EventQueue, task_ids: TaskIdAllocator | None = None,
                 telemetry=None):
        self.machine_id = machine_id
        self.queue = queue
        # Cluster-shared id stream (falls back to a private one so a
        # Machine can still be built standalone in tests/examples).
        self.task_ids = task_ids if task_ids is not None else TaskIdAllocator()
        # Each machine instantiates its own policy from the registry name
        # (policies carry per-server state and cannot be shared).
        self.manager = CoreManager(
            cfg.num_cores, policy=cfg.policy,
            policy_opts=cfg.policy_options,
            rng=np.random.default_rng(cfg.seed * 1000 + machine_id),
            idling_period_s=cfg.idling_period_s,
            on_promote=self._on_promote,
            res_window_s=cfg.resolved_power_window_s,
            telemetry=telemetry,
            telemetry_id=machine_id,
        )
        self.running_cpu_tasks = 0
        self.task_count_samples: list[int] = []
        # Oversubscribed tasks still in flight, keyed by task id:
        # [work_left (nominal s), rate (work/s), t_progress, gen, on_done].
        # A promotion reschedules the completion event; `gen` marks the
        # superseded event stale (the EventQueue has no cancellation).
        self._oversub_inflight: dict[int, list] = {}

    def run_cpu_task(self, name: str, on_done=None) -> None:
        """Spawn a Table-2 CPU task; completion latency reflects core
        aging (degraded frequency) and oversubscription time-sharing.

        An oversubscribed task progresses at the time-shared rate until
        the manager promotes it onto a freed core, at which point its
        remaining duration is recomputed from the promoted core's
        settled frequency (`_on_promote`)."""
        tid = self.task_ids.next_id()
        work = TASK_DURATIONS_S[name]
        now = self.queue.now
        speed = self.manager.assign(tid, now)
        rate = max(speed, 1e-6)
        dur = work / rate
        tracked = self.manager.core_of_task.get(tid) == OVERSUBSCRIBED
        if tracked:
            dur *= OVERSUB_SLOWDOWN
            self._oversub_inflight[tid] = [
                work, rate / OVERSUB_SLOWDOWN, now, 0, on_done]
        self.running_cpu_tasks += 1
        self._schedule_finish(tid, dur, 0, on_done, tracked)

    def _schedule_finish(self, tid: int, dur: float, gen: int,
                         on_done, tracked: bool) -> None:
        def _finish():
            if tracked:
                # Tracked (once-oversubscribed) tasks may have two finish
                # events in flight: a missing entry means the current-gen
                # event already completed the task, a gen mismatch means
                # a promotion superseded this event — either way, stale.
                st = self._oversub_inflight.get(tid)
                if st is None or st[3] != gen:
                    return
                del self._oversub_inflight[tid]
            self.manager.release(tid, self.queue.now)
            self.running_cpu_tasks -= 1
            if on_done is not None:
                on_done()

        self.queue.schedule_in(dur, _finish)

    def _on_promote(self, tid: int, core: int, now: float,
                    speed: float) -> None:
        """Manager moved `tid` from the oversubscription queue onto
        `core`: bank the progress made at the old time-shared rate and
        reschedule completion at the promoted core's settled speed."""
        st = self._oversub_inflight.get(tid)
        if st is None:
            return
        work_left, rate, t_progress, gen, on_done = st
        work_left = max(work_left - (now - t_progress) * rate, 0.0)
        rate = max(speed, 1e-6)
        st[:] = [work_left, rate, now, gen + 1, on_done]
        self._schedule_finish(tid, work_left / rate, gen + 1, on_done, True)


class PromptInstance:
    """Prefill-phase worker: FIFO, one prefill in flight (Splitwise)."""

    def __init__(self, machine: Machine):
        self.machine = machine
        # FIFO of admitted-but-not-started prefills; popleft() is O(1)
        # where list.pop(0) was O(n) under queueing bursts.
        self.queue: collections.deque[tuple[RequestState, Callable]] = \
            collections.deque()
        self.busy = False

    def enqueue(self, rs: RequestState, on_prefill_done) -> None:
        m = self.machine
        # Executor.submit -> submit_chain -> Instance.alloc_memory chain.
        def after_submit():
            m.run_cpu_task("submit_chain", lambda: m.run_cpu_task(
                "alloc_memory", lambda: self._admit(rs, on_prefill_done)))
        m.run_cpu_task("submit", after_submit)

    def _admit(self, rs: RequestState, on_prefill_done) -> None:
        self.queue.append((rs, on_prefill_done))
        self._maybe_start()

    def _maybe_start(self) -> None:
        if self.busy or not self.queue:
            return
        self.busy = True
        rs, cb = self.queue.popleft()
        m = self.machine
        gpu_time = PREFILL_BASE_S + PREFILL_PER_TOKEN_S * rs.req.input_tokens

        def gpu_done():
            rs.t_first_token = m.queue.now
            # finish_task + submit_flow kick off the KV-cache transfer.
            m.run_cpu_task("finish_task")
            m.run_cpu_task("submit_flow", lambda: cb(rs))
            self.busy = False
            self._maybe_start()

        m.run_cpu_task("submit_task", lambda: m.queue.schedule_in(
            gpu_time, gpu_done))


class TokenInstance:
    """Decode-phase worker with ORCA iteration-level continuous batching.

    Completion detection is O(1) per iteration: instead of decrementing
    every batched request's token counter each pass, a request joining
    the batch is pushed onto a min-heap keyed by the absolute iteration
    number it finishes at (continuous batching never evicts, so that
    number is fixed on admission). Iterations that complete nothing —
    the overwhelming majority at ~200 output tokens per request — skip
    the batch scan entirely. Completion *order* matches the old per-pass
    scan exactly: ties pop in admission order.
    """

    def __init__(self, machine: Machine):
        self.machine = machine
        self.active: list[RequestState] = []
        self.pending: collections.deque[RequestState] = collections.deque()
        self.iterating = False
        self.on_request_done = None
        self._iter_count = 0
        self._finish_heap: list[tuple[int, int, RequestState]] = []
        self._admit_seq = 0
        self._gpu_time = 0.0

    @property
    def load(self) -> int:
        return len(self.active) + len(self.pending)

    def receive_kv(self, rs: RequestState) -> None:
        """KV-cache flow arrived: fire flow_completion + alloc, then join
        the continuous batch."""
        m = self.machine

        def joined():
            self.pending.append(rs)
            self._maybe_iterate()

        m.run_cpu_task("flow_completion", lambda: m.run_cpu_task(
            "alloc_memory", joined))

    def _maybe_iterate(self) -> None:
        if self.iterating:
            return
        # admit pending up to batch limit
        while self.pending and len(self.active) < MAX_DECODE_BATCH:
            rs = self.pending.popleft()
            self.active.append(rs)
            self._admit_seq += 1
            heapq.heappush(self._finish_heap,
                           (self._iter_count + rs.remaining,
                            self._admit_seq, rs))
        if not self.active:
            return
        self.iterating = True
        self._gpu_time = (DECODE_ITER_BASE_S
                          + DECODE_ITER_PER_REQ_S * len(self.active))
        # ORCAInstance.start_iteration on the host, then the GPU pass.
        self.machine.run_cpu_task("start_iteration", self._gpu_pass)

    def _gpu_pass(self) -> None:
        self.machine.queue.schedule_in(self._gpu_time, self._iteration_done)

    def _iteration_done(self) -> None:
        m = self.machine
        self._iter_count += 1
        fh = self._finish_heap
        if fh and fh[0][0] <= self._iter_count:
            done_now = []
            while fh and fh[0][0] <= self._iter_count:
                done_now.append(heapq.heappop(fh)[2])
            done_ids = {id(rs) for rs in done_now}
            self.active = [rs for rs in self.active
                           if id(rs) not in done_ids]
            for rs in done_now:
                rs.remaining = 0
                rs.t_done = m.queue.now
                m.run_cpu_task("free_memory")
                m.run_cpu_task("finish_request", (
                    (lambda r=rs: self.on_request_done(r))
                    if self.on_request_done else None))
        self.iterating = False
        self._maybe_iterate()


class Cluster:
    """22-machine phase-splitting cluster + cluster-level scheduler."""

    def __init__(self, cfg: ExperimentConfig, telemetry=None):
        self.cfg = cfg
        self.queue = EventQueue()
        # Telemetry sink shared by every machine's CoreManager and the
        # routing/sampling paths below (None = zero-cost off; the hub is
        # owned by `run_experiment`, which exports it after the run).
        self.telemetry = telemetry if (
            telemetry is not None and getattr(telemetry, "enabled", True)
        ) else None
        # One id stream per simulation (not per process): concurrent
        # clusters can't interleave ids, while within this cluster ids
        # stay globally ordered by spawn time — the property the
        # manager's oversubscription FIFO relies on.
        self.task_ids = TaskIdAllocator()
        self.machines = [
            Machine(i, cfg, self.queue, self.task_ids,
                    telemetry=self.telemetry)
            for i in range(cfg.n_machines)
        ]
        self.prompt_instances = [PromptInstance(m)
                                 for m in self.machines[:cfg.n_prompt]]
        self.token_instances = [TokenInstance(m)
                                for m in self.machines[cfg.n_prompt:]]
        self.completed: list[RequestState] = []
        for ti in self.token_instances:
            ti.on_request_done = self._request_done
        # Cluster-level request routing (`repro.sim.routing`): the router
        # only sees a read-only FleetView; RNG-driven routers draw from a
        # cluster-owned stream so seeded runs stay reproducible.
        self.router = get_router(cfg.router, **cfg.router_options)
        self.router_rng = np.random.default_rng(cfg.seed * 1000 + 999)
        self.fleet = FleetView(self)
        if self.telemetry is not None:
            tel = self.telemetry
            self._c_routes = {k: tel.counter(f"routes_{k}")
                              for k in ("prompt", "token")}
            self._s_prompt_depth = tel.get_series("fleet/prompt_queue_depth")
            self._s_decode_load = tel.get_series("fleet/decode_load")
            self._s_cpu_tasks = tel.get_series("fleet/cpu_tasks")
        # Periodic ticks settle all machines' cores through one stacked
        # advance (numpy backend: bit-identical to per-machine settle_all).
        self.fleet_settler = FleetAgingSettler(
            [m.manager for m in self.machines])

    # ----------------------- scheduling policy ------------------------ #
    def _route(self, select, n: int, kind: str) -> int:
        idx = int(select(self.fleet))
        if not 0 <= idx < n:
            raise ValueError(f"router {self.router.name!r} returned "
                             f"{kind} index {idx}, outside [0, {n})")
        tel = self.telemetry
        if tel is not None:
            # Record the FleetView the router judged against — queue
            # depths (prompt) or decode loads (token) — so placement
            # decisions are auditable after the run.
            view = (self.fleet.prompt_depths() if kind == "prompt"
                    else self.fleet.token_loads())
            machine = idx if kind == "prompt" else self.cfg.n_prompt + idx
            self._c_routes[kind].inc()
            tel.push({"kind": "route", "t": self.queue.now,
                      "machine": machine, "phase": kind, "chosen": idx,
                      "router": self.router.name,
                      "depths": [int(d) for d in view]})
        return idx

    def submit_request(self, req: Request) -> None:
        rs = RequestState(req, remaining=req.output_tokens,
                          t_arrival=self.queue.now)
        pi = self.prompt_instances[self._route(
            self.router.select_prompt, len(self.prompt_instances), "prompt")]
        pi.enqueue(rs, self._prefill_done)

    def _prefill_done(self, rs: RequestState) -> None:
        # KV-cache flow to the router-chosen token instance over IB.
        ti = self.token_instances[self._route(
            self.router.select_token, len(self.token_instances), "token")]
        flow_s = rs.req.input_tokens * KV_BYTES_PER_TOKEN / IB_LINK_BW_BPS
        self.queue.schedule_in(flow_s, lambda: ti.receive_kv(rs))

    def _request_done(self, rs: RequestState) -> None:
        self.completed.append(rs)

    # --------------------------- main loop ----------------------------- #
    def run(self, requests: list[Request], duration_s: float,
            sample_period_s: float = 0.1) -> None:
        for req in requests:
            self.queue.schedule(req.arrival_s,
                                lambda r=req: self.submit_request(r))

        period = self.machines[0].manager.idling_period_s

        def periodic(t=[0.0]):
            # One fleet-batched settlement instead of n_machines
            # sequential settle_all chains; each manager's periodic then
            # sees fully-settled state (its own settle_all early-outs).
            self.fleet_settler.settle(self.queue.now)
            for m in self.machines:
                m.manager.periodic(self.queue.now)
            t[0] += period
            if t[0] <= duration_s:
                self.queue.schedule_in(period, periodic)

        tel = self.telemetry

        def sampler(t=[0.0]):
            for m in self.machines:
                m.task_count_samples.append(m.running_cpu_tasks)
            if tel is not None:
                now = self.queue.now
                self._s_prompt_depth.observe(
                    now, float(sum(len(p.queue) + p.busy
                                   for p in self.prompt_instances)))
                self._s_decode_load.observe(
                    now, float(sum(ti.load
                                   for ti in self.token_instances)))
                self._s_cpu_tasks.observe(
                    now, float(sum(m.running_cpu_tasks
                                   for m in self.machines)))
            t[0] += sample_period_s
            if t[0] <= duration_s:
                self.queue.schedule_in(sample_period_s, sampler)

        self.queue.schedule(period, periodic)
        self.queue.schedule(sample_period_s, sampler)
        self.queue.run_until(duration_s)
        for m in self.machines:
            m.manager.settle_all(duration_s)
