"""Splitwise-style LLM inference cluster model (paper §5, §6.1).

Topology matches the paper's experimental cluster: 22 GPU machines run a
phase-splitting deployment with 5 *prompt* instances and 17 *token*
instances (iso-throughput power-optimized design from Splitwise [26]).
Every serving step lands a Table-2 CPU task on the host CPU of the machine
executing it; each machine's CPU is governed by a `CoreManager` (proposed
technique or a baseline policy).

GPU execution times use a linear H100 performance model (prefill cost per
input token; ORCA-style iteration-level batched decode), and the KV-cache
transfer between prompt and token machines crosses an InfiniBand link and
fires `flow_completion` on the receiving host — the same structure
splitwise-sim models.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Callable

import numpy as np

from repro.core import OVERSUBSCRIBED, CoreManager
from repro.faults import FaultView, get_fault_model
from repro.sim.config import ExperimentConfig
from repro.sim.events import EventQueue
from repro.hardware.inventory import resolve_fleet
from repro.sim.fleetstate import FleetAgingSettler, GroupedAgingSettler
from repro.sim.latency import LatencyAggregate
from repro.sim.routing import FleetView, get_router
from repro.sim.tasks import TASK_DURATIONS_S, TaskIdAllocator
from repro.workloads import Request

# ----------------------------- GPU model ------------------------------ #
PREFILL_BASE_S = 0.030          # fixed prefill overhead (H100, 70B-class)
PREFILL_PER_TOKEN_S = 1.2e-4    # prefill seconds per input token
DECODE_ITER_BASE_S = 0.025      # one batched decode forward pass
DECODE_ITER_PER_REQ_S = 4.0e-4  # marginal batch cost per active request
MAX_DECODE_BATCH = 64
KV_BYTES_PER_TOKEN = 320e3      # 70B-class fp16 KV per token (all layers)
IB_LINK_BW_BPS = 25e9           # 200 Gb/s InfiniBand
OVERSUB_SLOWDOWN = 2.0          # time-sharing penalty for oversubscribed tasks


@dataclasses.dataclass
class RequestState:
    req: Request
    remaining: int
    t_arrival: float
    t_first_token: float = -1.0
    t_done: float = -1.0
    # fault-layer bookkeeping (untouched when faults are off):
    # dispatch attempts so far, whether any machine ever admitted it,
    # and whether the retry budget was exhausted.
    attempts: int = 0
    admitted: bool = False
    failed: bool = False


class Machine:
    """One inference server: host CPU (CoreManager) + a GPU instance."""

    def __init__(self, machine_id: int, cfg: ExperimentConfig,
                 queue: EventQueue, task_ids: TaskIdAllocator | None = None,
                 telemetry=None, track_inflight: bool = False, hw=None):
        self.machine_id = machine_id
        self.queue = queue
        # Heterogeneous fleets (`repro.hardware`): `hw` is this
        # machine's resolved `HardwareSKU`, or None on the uniform
        # default — which passes CoreManager exactly the historical
        # arguments (bit-exact).
        self.sku = hw
        hw_kwargs = {} if hw is None else {
            "aging_params": hw.aging_params(),
            "variation_params": hw.variation_params(),
        }
        # Cluster-shared id stream (falls back to a private one so a
        # Machine can still be built standalone in tests/examples).
        self.task_ids = task_ids if task_ids is not None else TaskIdAllocator()
        # Each machine instantiates its own policy from the registry name
        # (policies carry per-server state and cannot be shared).
        self.manager = CoreManager(
            cfg.num_cores if hw is None else hw.num_cores,
            policy=cfg.policy,
            policy_opts=cfg.policy_options,
            **hw_kwargs,
            rng=np.random.default_rng(cfg.seed * 1000 + machine_id),
            idling_period_s=cfg.idling_period_s,
            on_promote=self._on_promote,
            on_demote=self._on_demote,
            res_window_s=cfg.resolved_power_window_s,
            telemetry=telemetry,
            telemetry_id=machine_id,
        )
        self.running_cpu_tasks = 0
        self.task_count_samples: list[int] = []
        # Oversubscribed tasks still in flight, keyed by task id:
        # [work_left (nominal s), rate (work/s), t_progress, gen, on_done].
        # A promotion reschedules the completion event; `gen` marks the
        # superseded event stale (the EventQueue has no cancellation).
        self._oversub_inflight: dict[int, list] = {}
        # Fault layer: when faults are active EVERY task is tracked in
        # `_oversub_inflight` (not just oversubscribed ones) so in-flight
        # work can be rebanked on core failure / stall and cleanly killed
        # on machine crash. Off by default — the faultless hot path is
        # untouched.
        self._track_all = bool(track_inflight)
        self.up = True
        # Bumped on every crash: closures over GPU / flow completions
        # capture the epoch at schedule time and discard themselves when
        # the machine crashed in between.
        self.epoch = 0

    def run_cpu_task(self, name: str, on_done=None) -> None:
        """Spawn a Table-2 CPU task; completion latency reflects core
        aging (degraded frequency) and oversubscription time-sharing.

        An oversubscribed task progresses at the time-shared rate until
        the manager promotes it onto a freed core, at which point its
        remaining duration is recomputed from the promoted core's
        settled frequency (`_on_promote`)."""
        tid = self.task_ids.next_id()
        work = TASK_DURATIONS_S[name]
        now = self.queue.now
        speed = self.manager.assign(tid, now)
        rate = max(speed, 1e-6)
        dur = work / rate
        tracked = self.manager.core_of_task.get(tid) == OVERSUBSCRIBED
        if tracked:
            dur *= OVERSUB_SLOWDOWN
            self._oversub_inflight[tid] = [
                work, rate / OVERSUB_SLOWDOWN, now, 0, on_done]
        elif self._track_all:
            tracked = True
            self._oversub_inflight[tid] = [work, rate, now, 0, on_done]
        self.running_cpu_tasks += 1
        self._schedule_finish(tid, dur, 0, on_done, tracked)

    def _schedule_finish(self, tid: int, dur: float, gen: int,
                         on_done, tracked: bool) -> None:
        def _finish():
            if tracked:
                # Tracked (once-oversubscribed) tasks may have two finish
                # events in flight: a missing entry means the current-gen
                # event already completed the task, a gen mismatch means
                # a promotion superseded this event — either way, stale.
                st = self._oversub_inflight.get(tid)
                if st is None or st[3] != gen:
                    return
                del self._oversub_inflight[tid]
            self.manager.release(tid, self.queue.now)
            self.running_cpu_tasks -= 1
            if on_done is not None:
                on_done()

        self.queue.schedule_in(dur, _finish)

    def _on_promote(self, tid: int, core: int, now: float,
                    speed: float) -> None:
        """Manager moved `tid` from the oversubscription queue onto
        `core`: bank the progress made at the old time-shared rate and
        reschedule completion at the promoted core's settled speed."""
        st = self._oversub_inflight.get(tid)
        if st is None:
            return
        work_left, rate, t_progress, gen, on_done = st
        work_left = max(work_left - (now - t_progress) * rate, 0.0)
        rate = max(speed, 1e-6)
        st[:] = [work_left, rate, now, gen + 1, on_done]
        self._schedule_finish(tid, work_left / rate, gen + 1, on_done, True)

    def _on_demote(self, tid: int, now: float, speed: float) -> None:
        """Fault layer pushed `tid` off its (failed) core back into the
        oversubscription queue — the inverse of `_on_promote`: bank the
        progress made at the old rate and continue at the time-shared
        rate until a surviving core frees up."""
        st = self._oversub_inflight.get(tid)
        if st is None:
            return
        work_left, rate, t_progress, gen, on_done = st
        work_left = max(work_left - (now - t_progress) * rate, 0.0)
        rate = max(speed, 1e-6) / OVERSUB_SLOWDOWN
        st[:] = [work_left, rate, now, gen + 1, on_done]
        self._schedule_finish(tid, work_left / rate, gen + 1, on_done, True)

    def crash(self, now: float) -> None:
        """Power loss: every in-flight CPU task (and its pending finish
        event) dies — clearing `_oversub_inflight` marks all of them
        stale — and the manager powers the cores down. Request-level
        recovery is the cluster fault layer's job."""
        self.up = False
        self.epoch += 1
        self.manager.crash(now)
        self._oversub_inflight.clear()
        self.running_cpu_tasks = 0

    def reboot(self, now: float) -> None:
        """Power restored: surviving cores wake into a fresh working
        set; the instance starts empty (everything was re-dispatched)."""
        self.up = True
        self.manager.reboot(now)


class PromptInstance:
    """Prefill-phase worker: FIFO, one prefill in flight (Splitwise)."""

    def __init__(self, machine: Machine):
        self.machine = machine
        # FIFO of admitted-but-not-started prefills; popleft() is O(1)
        # where list.pop(0) was O(n) under queueing bursts.
        self.queue: collections.deque[tuple[RequestState, Callable]] = \
            collections.deque()
        self.busy = False

    def enqueue(self, rs: RequestState, on_prefill_done) -> None:
        m = self.machine
        # Executor.submit -> submit_chain -> Instance.alloc_memory chain.
        def after_submit():
            m.run_cpu_task("submit_chain", lambda: m.run_cpu_task(
                "alloc_memory", lambda: self._admit(rs, on_prefill_done)))
        m.run_cpu_task("submit", after_submit)

    def _admit(self, rs: RequestState, on_prefill_done) -> None:
        self.queue.append((rs, on_prefill_done))
        self._maybe_start()

    def _maybe_start(self) -> None:
        if self.busy or not self.queue:
            return
        self.busy = True
        rs, cb = self.queue.popleft()
        m = self.machine
        gpu_time = PREFILL_BASE_S + PREFILL_PER_TOKEN_S * rs.req.input_tokens
        epoch = m.epoch

        def gpu_done():
            if m.epoch != epoch:
                return  # machine crashed mid-prefill; request re-dispatched
            rs.t_first_token = m.queue.now
            # finish_task + submit_flow kick off the KV-cache transfer.
            m.run_cpu_task("finish_task")
            m.run_cpu_task("submit_flow", lambda: cb(rs))
            self.busy = False
            self._maybe_start()

        m.run_cpu_task("submit_task", lambda: m.queue.schedule_in(
            gpu_time, gpu_done))

    def reset(self) -> None:
        """Machine crashed: drop queued work (the fault layer re-dispatches
        every booked request) and clear the in-flight marker."""
        self.queue.clear()
        self.busy = False


class TokenInstance:
    """Decode-phase worker with ORCA iteration-level continuous batching.

    Completion detection is O(1) per iteration: instead of decrementing
    every batched request's token counter each pass, a request joining
    the batch is pushed onto a min-heap keyed by the absolute iteration
    number it finishes at (continuous batching never evicts, so that
    number is fixed on admission). Iterations that complete nothing —
    the overwhelming majority at ~200 output tokens per request — skip
    the batch scan entirely. Completion *order* matches the old per-pass
    scan exactly: ties pop in admission order.
    """

    def __init__(self, machine: Machine):
        self.machine = machine
        self.active: list[RequestState] = []
        self.pending: collections.deque[RequestState] = collections.deque()
        self.iterating = False
        self.on_request_done = None
        self._iter_count = 0
        self._finish_heap: list[tuple[int, int, RequestState]] = []
        self._admit_seq = 0
        self._gpu_time = 0.0

    @property
    def load(self) -> int:
        return len(self.active) + len(self.pending)

    def receive_kv(self, rs: RequestState) -> None:
        """KV-cache flow arrived: fire flow_completion + alloc, then join
        the continuous batch."""
        m = self.machine

        def joined():
            self.pending.append(rs)
            self._maybe_iterate()

        m.run_cpu_task("flow_completion", lambda: m.run_cpu_task(
            "alloc_memory", joined))

    def _maybe_iterate(self) -> None:
        if self.iterating:
            return
        # admit pending up to batch limit
        while self.pending and len(self.active) < MAX_DECODE_BATCH:
            rs = self.pending.popleft()
            self.active.append(rs)
            self._admit_seq += 1
            heapq.heappush(self._finish_heap,
                           (self._iter_count + rs.remaining,
                            self._admit_seq, rs))
        if not self.active:
            return
        self.iterating = True
        self._gpu_time = (DECODE_ITER_BASE_S
                          + DECODE_ITER_PER_REQ_S * len(self.active))
        # ORCAInstance.start_iteration on the host, then the GPU pass.
        self.machine.run_cpu_task("start_iteration", self._gpu_pass)

    def _gpu_pass(self) -> None:
        epoch = self.machine.epoch
        self.machine.queue.schedule_in(
            self._gpu_time, lambda: self._iteration_done(epoch))

    def _iteration_done(self, epoch: int) -> None:
        m = self.machine
        if epoch != m.epoch:
            return  # machine crashed mid-iteration; batch re-dispatched
        self._iter_count += 1
        fh = self._finish_heap
        if fh and fh[0][0] <= self._iter_count:
            done_now = []
            while fh and fh[0][0] <= self._iter_count:
                done_now.append(heapq.heappop(fh)[2])
            done_ids = {id(rs) for rs in done_now}
            self.active = [rs for rs in self.active
                           if id(rs) not in done_ids]
            for rs in done_now:
                rs.remaining = 0
                rs.t_done = m.queue.now
                m.run_cpu_task("free_memory")
                m.run_cpu_task("finish_request", (
                    (lambda r=rs: self.on_request_done(r))
                    if self.on_request_done else None))
        self.iterating = False
        self._maybe_iterate()

    def reset(self) -> None:
        """Machine crashed: the continuous batch and its finish schedule
        are lost (the fault layer re-dispatches every booked request)."""
        self.active = []
        self.pending.clear()
        self._finish_heap = []
        self.iterating = False


# -------------------- fault handling (retry/failover) ------------------- #
#: dispatch attempts per request before it is counted failed/rejected
MAX_RETRIES = 3
#: exponential-backoff base: attempt k retries after BASE * 2**(k-1) s
BACKOFF_BASE_S = 0.05
#: a dispatched-but-not-started prefill older than this is hedged
#: (pulled back and re-dispatched); started prefills are never stolen
HEDGE_TIMEOUT_S = 10.0


def _merge_intervals(
        spans: list[tuple[float, float]]) -> list[tuple[float, float]]:
    """Merge overlapping [lo, hi) spans (degraded-window accounting)."""
    if not spans:
        return []
    spans = sorted(spans)
    out = [list(spans[0])]
    for lo, hi in spans[1:]:
        if lo <= out[-1][1]:
            out[-1][1] = max(out[-1][1], hi)
        else:
            out.append([lo, hi])
    return [(lo, hi) for lo, hi in out]


class FaultCoordinator:
    """Cluster-level fault orchestration: injection, degradation, recovery.

    Built only when `cfg.fault_model != "none"` — with faults off the
    cluster never touches this class and the hot path is bit-identical
    to the faultless build.

    Responsibilities:
      * run each machine's `FaultModel.periodic` once per idling period
        and apply its decisions (offline cores via
        `CoreManager.fail_core`, transient stalls, crash/reboot);
      * health-aware dispatch: route around down machines, re-dispatch
        crash victims with bounded retry + exponential backoff, hedge
        prefills stuck in queue past `HEDGE_TIMEOUT_S`;
      * robustness accounting: capacity-based availability, degraded
        windows, retry/failure counters, and the conservation invariant
        completed + failed + rejected + pending == submitted.
    """

    def __init__(self, cluster: "Cluster", cfg: ExperimentConfig):
        self.cluster = cluster
        self.cfg = cfg
        n = cfg.n_machines
        # Per-machine model instances (may carry state, e.g. a pre-drawn
        # next crash time) over per-machine fault RNG streams.
        # Sequence-seeding with a salt keeps these streams disjoint from
        # the manager (seed*1000+mid) and router (seed*1000+999) streams
        # AND identical across policies — failure-count comparisons
        # between policies reflect aging state, not RNG drift.
        self.models = [get_fault_model(cfg.fault_model, **cfg.fault_options)
                       for _ in range(n)]
        self.rngs = [np.random.default_rng([cfg.seed, 0xFA, mid])
                     for mid in range(n)]
        self.views = [FaultView(m, rng, cfg.idling_period_s)
                      for m, rng in zip(cluster.machines, self.rngs)]
        # robustness counters
        self.submitted = 0
        self.retries = 0
        self.hedges = 0
        self.failed_requests = 0
        self.rejected_requests = 0
        self.core_failures = 0
        self.machine_crashes = 0
        self.stalls = 0
        #: core-seconds of serving capacity lost to failures/reboots
        self.lost_core_s = 0.0
        self._degraded: list[tuple[float, float]] = []
        # machine_id -> {id(rs): rs} of requests currently owned by that
        # machine (prefilling or decoding there); a crash re-dispatches
        # exactly these.
        self.inflight: dict[int, dict[int, RequestState]] = {
            mid: {} for mid in range(n)}
        self.rs_loc: dict[int, int] = {}
        # (machine_id, core) -> expiry time of an active transient stall
        self._stall_until: dict[tuple[int, int], float] = {}

    # ------------------------- booking ------------------------------- #
    def _book(self, rs: RequestState, machine_id: int) -> None:
        self.inflight[machine_id][id(rs)] = rs
        self.rs_loc[id(rs)] = machine_id

    def _unbook(self, rs: RequestState) -> None:
        mid = self.rs_loc.pop(id(rs), None)
        if mid is not None:
            self.inflight[mid].pop(id(rs), None)

    # ------------------------- dispatch ------------------------------ #
    def submit(self, rs: RequestState) -> None:
        self.submitted += 1
        self._dispatch_prompt(rs)

    def _dispatch_prompt(self, rs: RequestState) -> None:
        c = self.cluster
        pis = c.prompt_instances
        up = [i for i, pi in enumerate(pis) if pi.machine.up]
        if not up:
            self._retry(rs, "no-prompt-machine-up")
            return
        c.pending_request = rs.req
        idx = c._route(c.router.select_prompt, len(pis), "prompt")
        if not pis[idx].machine.up:
            # Health-aware failover: the router chose a down machine;
            # redirect to the least-loaded live prompt instance.
            depths = c.fleet.prompt_depths()
            idx = min(up, key=lambda i: depths[i])
        pi = pis[idx]
        self._book(rs, pi.machine.machine_id)
        rs.admitted = True
        pi.enqueue(rs, c._prefill_done)
        att = rs.attempts
        c.queue.schedule_in(HEDGE_TIMEOUT_S,
                            lambda: self._hedge_check(rs, att, idx))

    def _hedge_check(self, rs: RequestState, att: int, idx: int) -> None:
        """Fires HEDGE_TIMEOUT_S after a dispatch: a prefill still sitting
        in the queue (never started) is pulled back and re-dispatched
        immediately. Started prefills are never stolen, so a request is
        never served twice."""
        if (rs.t_done >= 0.0 or rs.failed or rs.attempts != att
                or rs.t_first_token >= 0.0):
            return
        pi = self.cluster.prompt_instances[idx]
        for entry in pi.queue:
            if entry[0] is rs:
                pi.queue.remove(entry)
                self._unbook(rs)
                self.hedges += 1
                self._retry(rs, "hedge-timeout", immediate=True)
                return

    def _retry(self, rs: RequestState, cause: str,
               immediate: bool = False) -> None:
        rs.attempts += 1
        if rs.attempts > MAX_RETRIES:
            rs.failed = True
            if rs.admitted:
                self.failed_requests += 1
            else:
                self.rejected_requests += 1
            return
        self.retries += 1
        # A retry restarts from the prompt phase: decode progress on a
        # crashed machine is gone with its KV cache.
        rs.remaining = rs.req.output_tokens
        rs.t_first_token = -1.0
        delay = 0.0 if immediate else BACKOFF_BASE_S * 2.0 ** (rs.attempts - 1)
        self.cluster.queue.schedule_in(
            delay, lambda: self._dispatch_prompt(rs))
        tel = self.cluster.telemetry
        if tel is not None:
            tel.push({"kind": "fault_retry", "t": self.cluster.queue.now,
                      "cause": cause, "attempt": rs.attempts})

    def prefill_done(self, rs: RequestState) -> None:
        c = self.cluster
        self._unbook(rs)
        tis = c.token_instances
        up = [i for i, ti in enumerate(tis) if ti.machine.up]
        if not up:
            self._retry(rs, "no-token-machine-up")
            return
        c.pending_request = rs.req
        idx = c._route(c.router.select_token, len(tis), "token")
        if not tis[idx].machine.up:
            loads = c.fleet.token_loads()
            idx = min(up, key=lambda i: loads[i])
        ti = tis[idx]
        self._book(rs, ti.machine.machine_id)
        flow_s = rs.req.input_tokens * KV_BYTES_PER_TOKEN / IB_LINK_BW_BPS
        c.queue.schedule_in(flow_s, lambda: self._kv_arrive(ti, rs))

    def _kv_arrive(self, ti: TokenInstance, rs: RequestState) -> None:
        mid = ti.machine.machine_id
        if self.inflight[mid].get(id(rs)) is not rs:
            return  # destination crashed in transit; already re-dispatched
        if not ti.machine.up:
            self._unbook(rs)
            self._retry(rs, "token-machine-down")
            return
        ti.receive_kv(rs)

    def request_done(self, rs: RequestState) -> None:
        self._unbook(rs)

    # ------------------------- injection ----------------------------- #
    def tick(self, now: float) -> None:
        """Once per idling period: expire stalls, then let each machine's
        fault model decide what breaks."""
        if self._stall_until:
            for key in [k for k, t in self._stall_until.items()
                        if t <= now]:
                del self._stall_until[key]
                m = self.cluster.machines[key[0]]
                if m.up:
                    m.manager.clear_core_slowdown(key[1], now)
        for mid, model in enumerate(self.models):
            dec = model.periodic(self.views[mid])
            if not dec:
                continue
            machine = self.cluster.machines[mid]
            if dec.crash:
                if machine.up:
                    self._crash(machine, now, dec.reboot_s)
                continue
            for core in dec.fail_cores:
                self._fail_core(machine, int(core), now)
            for core in dec.stall_cores:
                self._stall(machine, int(core), now,
                            dec.stall_factor, dec.stall_s)

    def _fail_core(self, machine: Machine, core: int, now: float) -> None:
        mgr = machine.manager
        if not machine.up or mgr.failed.item(core):
            return
        mgr.fail_core(core, now)
        self.core_failures += 1
        dur = self.cfg.duration_s
        self.lost_core_s += max(dur - now, 0.0)
        self._degraded.append(
            (now, min(now + self.cfg.idling_period_s, dur)))
        tel = self.cluster.telemetry
        if tel is not None:
            tel.push({"kind": "core_failure", "t": now,
                      "machine": machine.machine_id, "core": core})

    def _crash(self, machine: Machine, now: float, reboot_s: float) -> None:
        mid = machine.machine_id
        victims = list(self.inflight[mid].values())
        for rs in victims:
            self.rs_loc.pop(id(rs), None)
        self.inflight[mid].clear()
        for key in [k for k in self._stall_until if k[0] == mid]:
            del self._stall_until[key]
        machine.crash(now)
        c = self.cluster
        n_p = self.cfg.n_prompt
        if mid < n_p:
            c.prompt_instances[mid].reset()
        else:
            c.token_instances[mid - n_p].reset()
        self.machine_crashes += 1
        dur = self.cfg.duration_s
        surviving = machine.manager.num_cores \
            - int(machine.manager.failed.sum())
        self.lost_core_s += surviving * min(reboot_s, max(dur - now, 0.0))
        self._degraded.append((now, min(now + reboot_s, dur)))
        c.queue.schedule_in(reboot_s, lambda: self._reboot(machine))
        for rs in victims:
            self._retry(rs, "machine-crash")
        tel = c.telemetry
        if tel is not None:
            tel.push({"kind": "machine_crash", "t": now, "machine": mid,
                      "reboot_s": reboot_s, "victims": len(victims)})

    def _reboot(self, machine: Machine) -> None:
        now = self.cluster.queue.now
        machine.reboot(now)
        tel = self.cluster.telemetry
        if tel is not None:
            tel.push({"kind": "machine_reboot", "t": now,
                      "machine": machine.machine_id})

    def _stall(self, machine: Machine, core: int, now: float,
               factor: float, stall_s: float) -> None:
        mgr = machine.manager
        if not machine.up or mgr.failed.item(core):
            return
        mgr.set_core_slowdown(core, now, factor)
        self.stalls += 1
        key = (machine.machine_id, core)
        self._stall_until[key] = max(
            self._stall_until.get(key, 0.0), now + stall_s)
        self._degraded.append(
            (now, min(now + stall_s, self.cfg.duration_s)))

    # ------------------------- accounting ---------------------------- #
    def robustness(self, elapsed_s: float) -> dict:
        """Robustness scalars for `ExperimentResult` (keys match field
        names; `pending_requests` is derived by the caller)."""
        cfg = self.cfg
        n_cores = (cfg.n_machines * cfg.num_cores
                   if self.cluster.inventory is None
                   else self.cluster.inventory.total_cores)
        total = n_cores * max(elapsed_s, 1e-9)
        widths = [hi - lo for lo, hi in _merge_intervals(self._degraded)]
        return {
            "availability": 1.0 - min(self.lost_core_s / total, 1.0),
            "core_failures": self.core_failures,
            "machine_crashes": self.machine_crashes,
            "stalls": self.stalls,
            "retries": self.retries,
            "failed_requests": self.failed_requests,
            "rejected_requests": self.rejected_requests,
            "submitted": self.submitted,
            "p99_degraded_window_s": (
                float(np.percentile(np.asarray(widths), 99))
                if widths else 0.0),
        }


class Cluster:
    """22-machine phase-splitting cluster + cluster-level scheduler."""

    def __init__(self, cfg: ExperimentConfig, telemetry=None):
        self.cfg = cfg
        self.queue = EventQueue()
        # Telemetry sink shared by every machine's CoreManager and the
        # routing/sampling paths below (None = zero-cost off; the hub is
        # owned by `run_experiment`, which exports it after the run).
        self.telemetry = telemetry if (
            telemetry is not None and getattr(telemetry, "enabled", True)
        ) else None
        # One id stream per simulation (not per process): concurrent
        # clusters can't interleave ids, while within this cluster ids
        # stay globally ordered by spawn time — the property the
        # manager's oversubscription FIFO relies on.
        self.task_ids = TaskIdAllocator()
        faults_on = cfg.fault_model != "none"
        # Heterogeneous fleets (`repro.hardware`): None on the uniform
        # default — every machine then builds with the historical
        # homogeneous arguments, bit-exactly.
        self.inventory = resolve_fleet(cfg.fleet, cfg.fleet_options,
                                       cfg.n_machines)
        self.machines = [
            Machine(i, cfg, self.queue, self.task_ids,
                    telemetry=self.telemetry, track_inflight=faults_on,
                    hw=(None if self.inventory is None
                        else self.inventory.skus[i]))
            for i in range(cfg.n_machines)
        ]
        self.prompt_instances = [PromptInstance(m)
                                 for m in self.machines[:cfg.n_prompt]]
        self.token_instances = [TokenInstance(m)
                                for m in self.machines[cfg.n_prompt:]]
        self.completed: list[RequestState] = []
        # Streaming latency summary (ROADMAP 1d): metrics read this
        # instead of materializing a per-request latency array.
        self.completed_count = 0
        self.latency = LatencyAggregate()
        for ti in self.token_instances:
            ti.on_request_done = self._request_done
        # Cluster-level request routing (`repro.sim.routing`): the router
        # only sees a read-only FleetView; RNG-driven routers draw from a
        # cluster-owned stream so seeded runs stay reproducible.
        self.router = get_router(cfg.router, **cfg.router_options)
        self.router_rng = np.random.default_rng(cfg.seed * 1000 + 999)
        self.fleet = FleetView(self)
        if self.telemetry is not None:
            tel = self.telemetry
            self._c_routes = {k: tel.counter(f"routes_{k}")
                              for k in ("prompt", "token")}
            self._s_prompt_depth = tel.get_series("fleet/prompt_queue_depth")
            self._s_decode_load = tel.get_series("fleet/decode_load")
            self._s_cpu_tasks = tel.get_series("fleet/cpu_tasks")
        # Pending-request hook for size-aware routers: set immediately
        # before every `_route` call so `FleetView` can expose the
        # routed request's token counts (None outside routing).
        self.pending_request = None
        # Periodic ticks settle all machines' cores through one stacked
        # advance (numpy backend: bit-identical to per-machine settle_all).
        # Mixed fleets group managers by (AgingParams, num_cores) and run
        # one stacked settler per homogeneous group.
        if self.inventory is None:
            self.fleet_settler = FleetAgingSettler(
                [m.manager for m in self.machines])
        else:
            self.fleet_settler = GroupedAgingSettler(
                [m.manager for m in self.machines])
        # Fault layer: None with the default "none" model — every
        # faultless code path below checks `self.faults is not None`
        # exactly once and otherwise runs the historical bit-exact logic.
        self.faults = FaultCoordinator(self, cfg) if faults_on else None

    # ----------------------- scheduling policy ------------------------ #
    def _route(self, select, n: int, kind: str) -> int:
        idx = int(select(self.fleet))
        if not 0 <= idx < n:
            raise ValueError(f"router {self.router.name!r} returned "
                             f"{kind} index {idx}, outside [0, {n})")
        tel = self.telemetry
        if tel is not None:
            # Record the FleetView the router judged against — queue
            # depths (prompt) or decode loads (token) — so placement
            # decisions are auditable after the run.
            view = (self.fleet.prompt_depths() if kind == "prompt"
                    else self.fleet.token_loads())
            machine = idx if kind == "prompt" else self.cfg.n_prompt + idx
            self._c_routes[kind].inc()
            tel.push({"kind": "route", "t": self.queue.now,
                      "machine": machine, "phase": kind, "chosen": idx,
                      "router": self.router.name,
                      "depths": [int(d) for d in view]})
        return idx

    def submit_request(self, req: Request) -> None:
        rs = RequestState(req, remaining=req.output_tokens,
                          t_arrival=self.queue.now)
        self.pending_request = req
        if self.faults is not None:
            self.faults.submit(rs)
            return
        pi = self.prompt_instances[self._route(
            self.router.select_prompt, len(self.prompt_instances), "prompt")]
        pi.enqueue(rs, self._prefill_done)

    def _prefill_done(self, rs: RequestState) -> None:
        self.pending_request = rs.req
        if self.faults is not None:
            self.faults.prefill_done(rs)
            return
        # KV-cache flow to the router-chosen token instance over IB.
        ti = self.token_instances[self._route(
            self.router.select_token, len(self.token_instances), "token")]
        flow_s = rs.req.input_tokens * KV_BYTES_PER_TOKEN / IB_LINK_BW_BPS
        self.queue.schedule_in(flow_s, lambda: ti.receive_kv(rs))

    def _request_done(self, rs: RequestState) -> None:
        self.completed_count += 1
        self.latency.observe(rs.t_done - rs.t_arrival)
        self.completed.append(rs)
        if self.faults is not None:
            self.faults.request_done(rs)

    # --------------------------- main loop ----------------------------- #
    def run(self, requests: list[Request], duration_s: float,
            sample_period_s: float = 0.1) -> None:
        for req in requests:
            self.queue.schedule(req.arrival_s,
                                lambda r=req: self.submit_request(r))

        period = self.machines[0].manager.idling_period_s

        def periodic(t=[0.0]):
            # One fleet-batched settlement instead of n_machines
            # sequential settle_all chains; each manager's periodic then
            # sees fully-settled state (its own settle_all early-outs).
            self.fleet_settler.settle(self.queue.now)
            for m in self.machines:
                m.manager.periodic(self.queue.now)
            if self.faults is not None:
                self.faults.tick(self.queue.now)
            t[0] += period
            if t[0] <= duration_s:
                self.queue.schedule_in(period, periodic)

        tel = self.telemetry

        def sampler(t=[0.0]):
            for m in self.machines:
                m.task_count_samples.append(m.running_cpu_tasks)
            if tel is not None:
                now = self.queue.now
                self._s_prompt_depth.observe(
                    now, float(sum(len(p.queue) + p.busy
                                   for p in self.prompt_instances)))
                self._s_decode_load.observe(
                    now, float(sum(ti.load
                                   for ti in self.token_instances)))
                self._s_cpu_tasks.observe(
                    now, float(sum(m.running_cpu_tasks
                                   for m in self.machines)))
            t[0] += sample_period_s
            if t[0] <= duration_s:
                self.queue.schedule_in(sample_period_s, sampler)

        self.queue.schedule(period, periodic)
        self.queue.schedule(sample_period_s, sampler)
        self.queue.run_until(duration_s)
        for m in self.machines:
            m.manager.settle_all(duration_s)
