"""Experiment runner: replay a trace against a cluster under a policy."""
from __future__ import annotations

from repro.core import Policy
from repro.sim import metrics as metrics_mod
from repro.sim.cluster import Cluster
from repro.sim.tasks import reset_task_ids
from repro.sim.trace import TraceConfig, generate


def run_experiment(
    policy: Policy,
    num_cores: int = 40,
    rate_rps: float = 60.0,
    duration_s: float = 120.0,
    seed: int = 0,
    n_prompt: int = 5,
    n_token: int = 17,
    idling_period_s: float = 1.0,
) -> metrics_mod.ExperimentMetrics:
    reset_task_ids()
    trace = generate(TraceConfig(rate_rps=rate_rps, duration_s=duration_s,
                                 seed=seed))
    cluster = Cluster(policy, num_cores, seed=seed, n_prompt=n_prompt,
                      n_token=n_token, idling_period_s=idling_period_s)
    cluster.run(trace, duration_s)
    return metrics_mod.collect(cluster, policy.value, num_cores, rate_rps)


def run_policy_sweep(
    num_cores: int = 40,
    rate_rps: float = 60.0,
    duration_s: float = 120.0,
    seed: int = 0,
    policies=(Policy.LINUX, Policy.LEAST_AGED, Policy.PROPOSED),
) -> dict[str, metrics_mod.ExperimentMetrics]:
    return {
        p.value: run_experiment(p, num_cores=num_cores, rate_rps=rate_rps,
                                duration_s=duration_s, seed=seed)
        for p in policies
    }
