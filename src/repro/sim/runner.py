"""Experiment runner: replay a trace against a cluster under a policy.

Canonical API (PR 1): build a frozen `ExperimentConfig` and pass it to
`run_experiment` / `run_policy_sweep`. The pre-registry signature
(`run_experiment(Policy.PROPOSED, num_cores=..., ...)`) still works as a
deprecated shim.
"""
from __future__ import annotations

import warnings

from repro.core.manager import Policy
from repro.core.policies import canonical_policy_name
from repro.sim import metrics as metrics_mod
from repro.sim.cluster import Cluster
from repro.sim.config import ExperimentConfig
from repro.sim.tasks import reset_task_ids
from repro.sim.trace import TraceConfig, generate

DEFAULT_SWEEP = ("linux", "least-aged", "proposed")


def _coerce_config(cfg, legacy_kw) -> ExperimentConfig:
    if isinstance(cfg, ExperimentConfig):
        if legacy_kw:
            raise TypeError("pass experiment parameters inside the "
                            f"ExperimentConfig, not as kwargs: {legacy_kw}")
        return cfg
    # Legacy shim: first argument was a Policy enum (or name string).
    warnings.warn(
        "run_experiment(policy, **kwargs) is deprecated; pass an "
        "ExperimentConfig instead", DeprecationWarning, stacklevel=3)
    name = getattr(cfg, "value", cfg)
    return ExperimentConfig(policy=name, **legacy_kw)


def run_experiment(cfg: ExperimentConfig | Policy | str,
                   **legacy_kw) -> metrics_mod.ExperimentMetrics:
    cfg = _coerce_config(cfg, legacy_kw)
    reset_task_ids()
    trace = generate(TraceConfig(rate_rps=cfg.rate_rps,
                                 duration_s=cfg.duration_s, seed=cfg.seed))
    cluster = Cluster(cfg)
    cluster.run(trace, cfg.duration_s, sample_period_s=cfg.sample_period_s)
    return metrics_mod.collect(cluster, cfg.policy, cfg.num_cores,
                               cfg.rate_rps)


def run_policy_sweep(
    cfg: ExperimentConfig | None = None,
    policies=DEFAULT_SWEEP,
    **legacy_kw,
) -> dict[str, metrics_mod.ExperimentMetrics]:
    """Run the same experiment under each policy, keyed by registry name.

    Policies are given by string name (any registered policy works — no
    enum import needed); `cfg.policy_opts` only apply to the sweep entry
    matching `cfg.policy`.
    """
    if cfg is None:
        cfg = ExperimentConfig(**legacy_kw)
    elif legacy_kw:
        raise TypeError("pass experiment parameters inside the "
                        f"ExperimentConfig, not as kwargs: {legacy_kw}")
    out = {}
    for p in policies:
        name = canonical_policy_name(getattr(p, "value", p))
        run_cfg = cfg if name == cfg.policy else cfg.with_policy(name)
        out[run_cfg.policy] = run_experiment(run_cfg)
    return out
