"""Experiment runner: replay a workload scenario against a cluster
under a policy.

Build a frozen `ExperimentConfig` and pass it to `run_experiment`; the
workload comes from the `repro.workloads` scenario registry
(`cfg.scenario` + `cfg.scenario_opts`), the policy from the
`repro.core.policies` registry. `run_policy_sweep` runs the same
experiment across policies, and — with `scenarios=` — across full
policy x scenario grids:

    sweep = run_policy_sweep(cfg, policies=("linux", "proposed"),
                             scenarios=("conversation-poisson",
                                        "conversation-mmpp"))
    sweep[("proposed", "conversation-mmpp")].p99_latency_s
"""
from __future__ import annotations

from repro.core.policies import canonical_policy_name
from repro.sim import metrics as metrics_mod
from repro.sim.cluster import Cluster
from repro.sim.config import ExperimentConfig
from repro.workloads import canonical_scenario_name, get_scenario

DEFAULT_SWEEP = ("linux", "least-aged", "proposed")


def run_experiment(cfg: ExperimentConfig) -> metrics_mod.ExperimentMetrics:
    if not isinstance(cfg, ExperimentConfig):
        raise TypeError(
            "run_experiment takes an ExperimentConfig (the pre-registry "
            "run_experiment(policy, **kwargs) signature was removed); "
            f"got {cfg!r}")
    scenario = get_scenario(cfg.scenario, **cfg.scenario_options)
    trace = scenario.generate(rate_rps=cfg.rate_rps,
                              duration_s=cfg.duration_s, seed=cfg.seed)
    cluster = Cluster(cfg)
    cluster.run(trace, cfg.duration_s, sample_period_s=cfg.sample_period_s)
    return metrics_mod.collect(cluster, cfg.policy, cfg.num_cores,
                               cfg.rate_rps, scenario=cfg.scenario)


def run_policy_sweep(
    cfg: ExperimentConfig | None = None,
    policies=DEFAULT_SWEEP,
    scenarios=None,
) -> dict:
    """Run the same experiment under each policy (and scenario).

    Policies/scenarios are given by registry name. With `scenarios=None`
    (default) the result is keyed by policy name and the workload is
    `cfg.scenario`, preserving the single-workload API. With an iterable
    of scenario names, the result is keyed by `(policy, scenario)`
    tuples. `cfg.policy_opts` / `cfg.scenario_opts` only apply to the
    sweep entries matching `cfg.policy` / `cfg.scenario`.
    """
    if cfg is None:
        cfg = ExperimentConfig()
    if scenarios is None:
        out = {}
        for p in policies:
            run_cfg = _with_policy(cfg, p)
            out[run_cfg.policy] = run_experiment(run_cfg)
        return out
    out = {}
    for s in scenarios:
        s_name = canonical_scenario_name(s)
        s_cfg = cfg if s_name == cfg.scenario else cfg.with_scenario(s_name)
        for p in policies:
            run_cfg = _with_policy(s_cfg, p)
            out[(run_cfg.policy, s_name)] = run_experiment(run_cfg)
    return out


def _with_policy(cfg: ExperimentConfig, policy) -> ExperimentConfig:
    name = canonical_policy_name(policy)
    return cfg if name == cfg.policy else cfg.with_policy(name)
