"""Experiment runner: replay a workload scenario against a cluster
under a policy.

Build a frozen `ExperimentConfig` and pass it to `run_experiment`; the
workload comes from the `repro.workloads` scenario registry
(`cfg.scenario` + `cfg.scenario_opts`), the policy from the
`repro.core.policies` registry. `run_policy_sweep` runs the same
experiment across policies, and — with `scenarios=` — across full
policy x scenario grids:

    sweep = run_policy_sweep(cfg, policies=("linux", "proposed"),
                             scenarios=("conversation-poisson",
                                        "conversation-mmpp"))
    sweep[("proposed", "conversation-mmpp")].p99_latency_s

With `routers=` the cluster-level routing axis (`repro.sim.routing`)
joins the grid, keyed `(policy, router)` or `(policy, scenario,
router)`:

    grid = run_policy_sweep(cfg, policies=("linux", "proposed"),
                            scenarios=("conversation-poisson",
                                       "conversation-mmpp"),
                            routers=("jsq", "least-aged-cpu",
                                     "carbon-greedy"))
    grid[("proposed", "conversation-mmpp", "carbon-greedy")]

The sweep returns a `SweepResult` — a read-only mapping with the same
keys as the dict it historically returned, plus `save`/`load`/`to_rows`
so grids persist and diff across runs (see `repro.sim.results`).
"""
from __future__ import annotations

from repro.carbon import get_carbon_model
from repro.core.policies import canonical_policy_name
from repro.faults.registry import canonical_fault_model_name, get_fault_model
from repro.hardware.inventory import canonical_fleet_name, resolve_fleet
from repro.power import get_power_model
from repro.power.registry import canonical_power_model_name
from repro.sim import metrics as metrics_mod
from repro.sim.cluster import Cluster
from repro.sim.config import ExperimentConfig
from repro.sim.results import ExperimentResult, SweepResult
from repro.sim.routing import canonical_router_name
from repro.workloads import canonical_scenario_name, get_scenario

DEFAULT_SWEEP = ("linux", "least-aged", "proposed")


def run_experiment(cfg: ExperimentConfig,
                   telemetry=None) -> ExperimentResult:
    if not isinstance(cfg, ExperimentConfig):
        raise TypeError(
            "run_experiment takes an ExperimentConfig (the pre-registry "
            "run_experiment(policy, **kwargs) signature was removed); "
            f"got {cfg!r}")
    # Streaming telemetry (repro.telemetry): `cfg.telemetry=True` builds
    # a hub from `cfg.telemetry_opts`; a caller-supplied hub wins (so a
    # long-lived hub can span several runs). None = zero-cost off.
    hub = telemetry
    if hub is None and cfg.telemetry:
        from repro.telemetry import TelemetryHub
        hub = TelemetryHub.from_opts(cfg.telemetry_options)
    # Resolve every axis up front so a typo'd name fails before the
    # simulation runs, not after (policy and router resolve inside
    # Cluster.__init__ below); the resolved carbon model is handed to
    # `collect`, which would otherwise construct it a second time.
    carbon_model = get_carbon_model(cfg.carbon_model, **cfg.carbon_options)
    power_model = get_power_model(cfg.power_model, **cfg.power_options)
    scenario = get_scenario(cfg.scenario, **cfg.scenario_options)
    # Fault axis fail-fast: instantiate once to validate name + opts
    # (the cluster builds its own per-machine instances).
    get_fault_model(cfg.fault_model, **cfg.fault_options)
    # Fleet axis fail-fast: resolve the hardware inventory (None for
    # the bit-exact uniform default) so bad SKU names / row counts fail
    # here; the cluster / fleet engine re-resolve their own copy.
    resolve_fleet(cfg.fleet, cfg.fleet_options, cfg.n_machines)
    if cfg.engine == "fleet":
        # Vectorized time-stepped engine (repro.sim.fleetsim) — the
        # scale path. The event loop below stays the bit-exact
        # small-scale reference.
        from repro.sim.fleetsim import run_fleet_experiment
        return run_fleet_experiment(cfg, telemetry=hub,
                                    carbon_model=carbon_model,
                                    power_model=power_model,
                                    scenario=scenario)
    if hub is None:
        trace = scenario.generate(rate_rps=cfg.rate_rps,
                                  duration_s=cfg.duration_s, seed=cfg.seed)
        cluster = Cluster(cfg)
        cluster.run(trace, cfg.duration_s,
                    sample_period_s=cfg.sample_period_s)
        return metrics_mod.collect(cluster, cfg, carbon_model=carbon_model,
                                   power_model=power_model)
    return _run_with_telemetry(cfg, hub, carbon_model, power_model,
                               scenario)


def _run_with_telemetry(cfg, hub, carbon_model, power_model,
                        scenario) -> ExperimentResult:
    """Telemetry-on path: same simulation, plus per-phase wall-time /
    event-loop-throughput self-profiling and post-run export. Recording
    is pure observation, so the `ExperimentResult` scalars stay
    bit-identical to the hub-less path (pinned in
    tests/test_telemetry.py)."""
    import dataclasses
    import time

    def phase(name, fn):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        hub.set_gauge(f"phase/{name}_wall_s", dt)
        hub.event("phase", 0.0, phase=name, wall_s=dt)
        return out

    trace = phase("trace_gen", lambda: scenario.generate(
        rate_rps=cfg.rate_rps, duration_s=cfg.duration_s, seed=cfg.seed))
    cluster = phase("cluster_build", lambda: Cluster(cfg, telemetry=hub))
    # Surface the aging settler's *resolved* backend ("auto" may have
    # silently fallen back to numpy): visible in the event stream and as
    # a gauge in `result.telemetry_summary`. The jax backend settles in
    # float32 — fast, but not bit-exact vs the numpy reference.
    backend = cluster.fleet_settler.backend
    hub.event("engine", 0.0, engine="event", aging_backend=backend)
    hub.set_gauge("engine/aging_backend_is_jax",
                  1.0 if backend == "jax" else 0.0)
    phase("sim_run", lambda: cluster.run(
        trace, cfg.duration_s, sample_period_s=cfg.sample_period_s))
    sim_wall = hub.gauge("phase/sim_run_wall_s").value
    hub.set_gauge("events_processed", cluster.queue.processed)
    if sim_wall > 0:
        hub.set_gauge("events_per_sec", cluster.queue.processed / sim_wall)
    result = phase("collect", lambda: metrics_mod.collect(
        cluster, cfg, carbon_model=carbon_model, power_model=power_model,
        telemetry=hub))

    summary = hub.summary()
    export_dir = cfg.telemetry_options.get("export_dir")
    if export_dir:
        import os
        from repro.telemetry import export_run
        out_dir = os.path.join(
            str(export_dir), f"{cfg.policy}-{cfg.fingerprint()}")
        summary["export"] = export_run(hub, out_dir,
                                       t_end=cfg.duration_s)
    return dataclasses.replace(result, telemetry_summary=summary)


def run_policy_sweep(
    cfg: ExperimentConfig | None = None,
    policies=DEFAULT_SWEEP,
    scenarios=None,
    routers=None,
    power_models=None,
    fault_models=None,
    fleets=None,
    parallel: int | None = None,
) -> SweepResult:
    """Run the same experiment across policies (x scenarios x routers
    x power models x fault models x fleets).

    Policies/scenarios/routers/power models/fault models/fleets are
    given by registry name (fleets by fleet spec — see
    `repro.hardware`). With `scenarios=None`, `routers=None`,
    `power_models=None`, `fault_models=None` and `fleets=None`
    (default) the result is keyed by policy name, preserving the
    single-axis API. Adding `scenarios=` keys by `(policy, scenario)`;
    adding `routers=` keys by `(policy, router)`; adding
    `power_models=` appends a power-model part; adding `fault_models=`
    appends a fault-model part; adding `fleets=` appends a fleet part;
    all together key by `(policy, scenario, router, power_model,
    fault_model, fleet)`. `cfg.policy_opts` / `cfg.scenario_opts` /
    `cfg.router_opts` / `cfg.power_opts` / `cfg.fault_opts` /
    `cfg.fleet_opts` only apply to the sweep entries matching
    `cfg.policy` / `cfg.scenario` / `cfg.router` / `cfg.power_model` /
    `cfg.fault_model` / `cfg.fleet`.

    `parallel=N` fans the grid's cells across a process pool of N
    workers. Every cell is an independent simulation whose seeding is
    carried entirely by its frozen `ExperimentConfig` (each worker
    re-derives all RNG streams from `cell_cfg.seed`), so the result
    dict is identical to the serial sweep — same keys, same metrics —
    regardless of worker count or completion order (pinned by
    tests/test_perf_bitexact.py). One caveat: workers resolve registry
    names on import, so custom policies/scenarios/routers registered at
    runtime (a notebook cell, an `if __name__ == "__main__"` block) are
    only visible to workers under the `fork` start method (Linux
    default); under `spawn` (macOS/Windows default) register them in an
    imported module, or run serially.
    """
    if cfg is None:
        cfg = ExperimentConfig()
    scenario_axis = scenarios is not None
    router_axis = routers is not None
    power_axis = power_models is not None
    fault_axis = fault_models is not None
    fleet_axis = fleets is not None
    axes = (("policy",)
            + (("scenario",) if scenario_axis else ())
            + (("router",) if router_axis else ())
            + (("power_model",) if power_axis else ())
            + (("fault_model",) if fault_axis else ())
            + (("fleet",) if fleet_axis else ()))
    cells: list[tuple[object, ExperimentConfig]] = []
    for s in (scenarios if scenario_axis else (cfg.scenario,)):
        s_name = canonical_scenario_name(s)
        s_cfg = cfg if s_name == cfg.scenario else cfg.with_scenario(s_name)
        for r in (routers if router_axis else (cfg.router,)):
            r_name = canonical_router_name(r)
            r_cfg = s_cfg if r_name == s_cfg.router \
                else s_cfg.with_router(r_name)
            for w in (power_models if power_axis else (cfg.power_model,)):
                w_name = canonical_power_model_name(w)
                w_cfg = r_cfg if w_name == r_cfg.power_model \
                    else r_cfg.with_power_model(w_name)
                for fm in (fault_models if fault_axis
                           else (cfg.fault_model,)):
                    f_name = canonical_fault_model_name(fm)
                    f_cfg = w_cfg if f_name == w_cfg.fault_model \
                        else w_cfg.with_fault_model(f_name)
                    for fl in (fleets if fleet_axis else (cfg.fleet,)):
                        fl_name = canonical_fleet_name(fl)
                        fl_cfg = f_cfg if fl_name == f_cfg.fleet \
                            else f_cfg.with_fleet(fl_name)
                        for p in policies:
                            run_cfg = _with_policy(fl_cfg, p)
                            key = ((run_cfg.policy,)
                                   + ((s_name,) if scenario_axis else ())
                                   + ((r_name,) if router_axis else ())
                                   + ((w_name,) if power_axis else ())
                                   + ((f_name,) if fault_axis else ())
                                   + ((fl_name,) if fleet_axis else ()))
                            cells.append((key if len(key) > 1 else key[0],
                                          run_cfg))
    if parallel is not None and int(parallel) > 1 and len(cells) > 1:
        import concurrent.futures

        with concurrent.futures.ProcessPoolExecutor(
                max_workers=int(parallel)) as pool:
            # `map` preserves submission order, so keys zip back exactly.
            results = list(pool.map(run_experiment,
                                    [c for _, c in cells]))
        return SweepResult(zip([k for k, _ in cells], results), axes=axes)
    return SweepResult(((key, run_experiment(run_cfg))
                        for key, run_cfg in cells), axes=axes)


def _with_policy(cfg: ExperimentConfig, policy) -> ExperimentConfig:
    name = canonical_policy_name(policy)
    return cfg if name == cfg.policy else cfg.with_policy(name)
