"""Minimal deterministic event-driven simulation core (splitwise-sim style)."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable


class EventQueue:
    """Time-ordered callback queue. Ties break by insertion order, so the
    simulation is fully deterministic given a seed."""

    def __init__(self):
        self._heap: list = []
        self._counter = itertools.count()
        self.now = 0.0

    def schedule(self, time: float, fn: Callable[[], None]) -> None:
        if time < self.now - 1e-12:
            time = self.now  # never schedule into the past
        heapq.heappush(self._heap, (time, next(self._counter), fn))

    def schedule_in(self, delay: float, fn: Callable[[], None]) -> None:
        self.schedule(self.now + max(delay, 0.0), fn)

    def run_until(self, t_end: float) -> None:
        while self._heap and self._heap[0][0] <= t_end:
            time, _, fn = heapq.heappop(self._heap)
            self.now = time
            fn()
        self.now = max(self.now, t_end)

    def __len__(self) -> int:
        return len(self._heap)
