"""Minimal deterministic event-driven simulation core (splitwise-sim style)."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable


class EventQueue:
    """Time-ordered callback queue. Ties break by insertion order, so the
    simulation is fully deterministic given a seed."""

    def __init__(self):
        self._heap: list = []
        self._counter = itertools.count()
        self.now = 0.0
        self.processed = 0  # events executed (perf observability)

    def schedule(self, time: float, fn: Callable[[], None]) -> None:
        if time < self.now - 1e-12:
            time = self.now  # never schedule into the past
        heapq.heappush(self._heap, (time, next(self._counter), fn))

    def schedule_in(self, delay: float, fn: Callable[[], None]) -> None:
        # Inlined `schedule` (this is the event loop's hottest producer):
        # now + max(delay, 0) can never land in the past.
        heapq.heappush(
            self._heap,
            (self.now + (delay if delay > 0.0 else 0.0),
             next(self._counter), fn))

    def run_until(self, t_end: float) -> None:
        heap = self._heap
        pop = heapq.heappop
        n = 0
        while heap and heap[0][0] <= t_end:
            time, _, fn = pop(heap)
            self.now = time
            fn()
            n += 1
        self.processed += n
        self.now = max(self.now, t_end)

    def __len__(self) -> int:
        return len(self._heap)
