"""Structured, serializable experiment results.

`ExperimentResult` is the frozen record one `run_experiment` call
produces (replacing the mutable, numpy-laden `ExperimentMetrics`):
every field is a plain Python value, `to_dict`/`from_dict` round-trip
losslessly through JSON, and a `Provenance` block (config hash, seed,
package version) says exactly which experiment produced it.

`SweepResult` wraps a `run_policy_sweep` grid. It is a read-only
`Mapping` with the same keys the sweep always returned (policy name, or
`(policy, scenario)` / `(policy, router)` / `(policy, scenario,
router)` tuples), plus `save`/`load` for persistence and `to_rows` for
flat tables that diff across runs:

    sweep = run_policy_sweep(cfg, policies=("linux", "proposed"))
    sweep["proposed"].p99_latency_s          # mapping access, as before
    sweep.save("sweep.json")
    old = SweepResult.load("sweep.json")
    rows = sweep.to_rows()                   # flat dicts, one per cell
"""
from __future__ import annotations

import dataclasses
import json
import math
from collections.abc import Mapping
from typing import Any, Iterator

from repro.carbon.base import LifetimeEstimate
from repro.power.residency import StateResidency

#: bumped when the serialized layout changes incompatibly
RESULT_SCHEMA_VERSION = 1


def _check_schema(version) -> None:
    if version != RESULT_SCHEMA_VERSION:
        raise ValueError(f"unsupported result schema {version!r}; this "
                         f"version reads schema {RESULT_SCHEMA_VERSION}")


def _tuplify(v):
    """JSON arrays back to tuples (deep) — opts are stored as tuples
    (the repo's frozen-config convention), and the round-trip must
    restore them for dataclass equality."""
    if isinstance(v, list):
        return tuple(_tuplify(x) for x in v)
    if isinstance(v, dict):
        return {k: _tuplify(x) for k, x in v.items()}
    return v


def _package_version() -> str:
    try:
        from importlib.metadata import version
        return version("repro-aging-core-mgmt")
    except Exception:
        # running from a source checkout (PYTHONPATH=src) without an
        # installed distribution
        return "0.1.0+src"


PACKAGE_VERSION = _package_version()


@dataclasses.dataclass(frozen=True)
class Provenance:
    """Where a result came from: enough to re-run or refuse to compare.

    `config_hash` is `ExperimentConfig.fingerprint()` — two results with
    different hashes were produced by different experiments and should
    not be diffed as if they were reruns.
    """

    config_hash: str
    seed: int
    package_version: str = PACKAGE_VERSION

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Provenance":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ExperimentResult:
    """Frozen record of one cluster experiment (paper §6.1.3 metrics).

    Sequence fields are tuples (not lists/ndarrays) and the dataclass is
    frozen, so the record is JSON-serializable and can't be rebound;
    the three percentile fields remain plain dicts for ergonomic
    `result.x_percentiles[99]` access — treat them as read-only.
    `None` defaults mark optional per-machine detail that older
    serialized results may omit.
    """

    policy: str
    num_cores: int
    rate_rps: float
    scenario: str
    # paper Fig. 6: CV of per-server core-frequency distribution, and mean
    # frequency degradation, percentiled across the cluster's machines.
    freq_cv_percentiles: dict[int, float]
    mean_degradation_percentiles: dict[int, float]
    # paper Fig. 8: normalized idle cores distribution (negative = oversub)
    idle_norm_percentiles: dict[int, float]
    oversub_frac_below: float      # fraction of samples below -0.1
    # paper Fig. 2: concurrent CPU tasks per machine
    task_count_mean: float
    task_count_max: int
    # service quality (NaN when nothing completed — a starved config must
    # never rank as winning a latency comparison)
    mean_latency_s: float
    p99_latency_s: float
    completed: int
    # cluster-routing axis (see `repro.sim.routing`)
    router: str = "jsq"
    # carbon-accounting axis (see `repro.carbon`): the model (and its
    # constructor opts) that priced `per_machine_carbon` /
    # `fleet_yearly_kgco2eq` — kept so default `carbon_comparison`
    # pricing can rebuild the exact same model
    carbon_model: str = "linear-extension"
    carbon_opts: tuple[tuple[str, Any], ...] = ()
    # fleet-level aging imbalance: cross-machine CV of per-machine mean
    # frequency degradation, computed within each serving role (prompt /
    # token) and machine-count-weighted. A cluster router can only level
    # aging among peers serving the same phase — the prompt/token role
    # gap is deployment topology, not routing quality — so mixing roles
    # into one CV would swamp the quantity routing actually controls.
    fleet_degradation_cv: float = float("nan")
    # per-machine embodied-carbon estimates vs the worst-case
    # linear-aging reference at the same horizon, and their fleet total;
    # `deg_reference` is that reference degradation, kept so the fleet
    # can be re-priced under another model without re-simulating
    per_machine_carbon: tuple[LifetimeEstimate, ...] | None = None
    fleet_yearly_kgco2eq: float = float("nan")
    deg_reference: float | None = None
    # raw per-machine values for downstream carbon estimates
    per_machine_cv: tuple[float, ...] | None = None
    per_machine_degradation: tuple[float, ...] | None = None
    per_machine_idle_norm: tuple[tuple[float, ...], ...] | None = None
    per_machine_task_samples: tuple[tuple[int, ...], ...] | None = None
    # power-accounting axis (see `repro.power`): the model (and opts)
    # that priced the measured per-core state residencies into energy /
    # operational carbon. `per_machine_residency` keeps the raw
    # residencies so the fleet can be re-priced under another power
    # model without re-simulating (`fleet_energy_under`).
    power_model: str = "flat-tdp"
    power_opts: tuple[tuple[str, Any], ...] = ()
    per_machine_energy_kwh: tuple[float, ...] | None = None
    per_machine_residency: tuple[StateResidency, ...] | None = None
    fleet_energy_kwh: float = float("nan")      # over the sim horizon
    mean_machine_power_w: float = float("nan")
    # operational carbon from measured energy x the carbon model's grid
    # intensity, over the sim horizon and annualized; `..._total` adds
    # the embodied yearly figure for the full-footprint headline
    fleet_operational_kgco2eq: float = float("nan")
    fleet_yearly_operational_kgco2eq: float = float("nan")
    fleet_yearly_total_kgco2eq: float = float("nan")
    # telemetry digest (`TelemetryHub.summary()` + export paths) when the
    # run recorded telemetry; None otherwise. A JSON-safe plain dict —
    # deliberately NOT part of `scalars()`: it carries wall-time gauges
    # that legitimately differ between bit-identical reruns, so it must
    # never trip `diff_scalars` drift checks.
    telemetry_summary: dict[str, Any] | None = None
    # which simulation engine produced this result: "event" (bit-exact
    # per-machine event loop) or "fleet" (vectorized time-stepped
    # surrogate, `repro.sim.fleetsim`). Deliberately NOT part of
    # `scalars()` — engine parity checks diff the metric columns of an
    # event run against a fleet run, so the engine label itself must
    # not show up as a drift.
    engine: str = "event"
    # fault-injection axis (see `repro.faults`): which fault model ran
    # and the robustness metrics it produced. With the default "none"
    # model every field below keeps its default and `scalars()` omits
    # the whole block, so faultless scalar rows (and the pinned drift
    # gate) stay byte-identical to pre-fault results.
    fault_model: str = "none"
    fault_opts: tuple[tuple[str, Any], ...] = ()
    # hardware axis (see `repro.hardware`): which fleet composition ran
    # and each machine's SKU name. With the default "uniform" fleet the
    # fields keep their defaults and `scalars()` omits the block, so
    # uniform scalar rows (and the pinned drift-gate golden) stay
    # byte-identical to pre-hardware results.
    fleet: str = "uniform"
    fleet_opts: tuple[tuple[str, Any], ...] = ()
    per_machine_sku: tuple[str, ...] | None = None
    availability: float = 1.0      # 1 - lost core-seconds / capacity
    core_failures: int = 0
    machine_crashes: int = 0
    stalls: int = 0
    retries: int = 0
    failed_requests: int = 0       # admitted, then retry budget exhausted
    rejected_requests: int = 0     # never admitted (no live machine)
    pending_requests: int = 0      # still in flight at the horizon
    submitted: int = -1            # -1 = not tracked (faults off)
    p99_degraded_window_s: float = 0.0
    provenance: Provenance | None = None

    # ------------------------------------------------------------------ #
    # serialization
    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict[str, Any]:
        """Plain-value dict; `json.dumps`-able (NaN uses the JSON
        extension Python emits/reads by default)."""
        d = dataclasses.asdict(self)
        d["schema"] = RESULT_SCHEMA_VERSION
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentResult":
        d = dict(d)
        _check_schema(d.pop("schema", RESULT_SCHEMA_VERSION))
        for f in ("freq_cv_percentiles", "mean_degradation_percentiles",
                  "idle_norm_percentiles"):
            d[f] = {int(p): float(v) for p, v in d[f].items()}
        d["carbon_opts"] = tuple((str(k), _tuplify(v))
                                 for k, v in d.get("carbon_opts", ()))
        d["power_opts"] = tuple((str(k), _tuplify(v))
                                for k, v in d.get("power_opts", ()))
        d["fault_opts"] = tuple((str(k), _tuplify(v))
                                for k, v in d.get("fault_opts", ()))
        d["fleet_opts"] = tuple((str(k), _tuplify(v))
                                for k, v in d.get("fleet_opts", ()))
        if d.get("per_machine_sku") is not None:
            d["per_machine_sku"] = tuple(str(s)
                                         for s in d["per_machine_sku"])
        if d.get("per_machine_carbon") is not None:
            d["per_machine_carbon"] = tuple(
                LifetimeEstimate.from_dict(e)
                for e in d["per_machine_carbon"])
        for f in ("per_machine_cv", "per_machine_degradation",
                  "per_machine_energy_kwh"):
            if d.get(f) is not None:
                d[f] = tuple(float(x) for x in d[f])
        if d.get("per_machine_residency") is not None:
            d["per_machine_residency"] = tuple(
                StateResidency.from_dict(r)
                for r in d["per_machine_residency"])
        if d.get("per_machine_idle_norm") is not None:
            d["per_machine_idle_norm"] = tuple(
                tuple(float(x) for x in row)
                for row in d["per_machine_idle_norm"])
        if d.get("per_machine_task_samples") is not None:
            d["per_machine_task_samples"] = tuple(
                tuple(int(x) for x in row)
                for row in d["per_machine_task_samples"])
        if d.get("provenance") is not None:
            d["provenance"] = Provenance.from_dict(d["provenance"])
        return cls(**d)

    def to_json(self, **dumps_kw) -> str:
        return json.dumps(self.to_dict(), **dumps_kw)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentResult":
        return cls.from_dict(json.loads(text))

    # ------------------------------------------------------------------ #
    # tabulation
    # ------------------------------------------------------------------ #
    _SCALARS = ("policy", "scenario", "router", "carbon_model",
                "power_model", "num_cores",
                "rate_rps", "completed", "task_count_mean", "task_count_max",
                "oversub_frac_below", "mean_latency_s", "p99_latency_s",
                "fleet_degradation_cv", "fleet_yearly_kgco2eq",
                "fleet_energy_kwh", "mean_machine_power_w",
                "fleet_yearly_operational_kgco2eq",
                "fleet_yearly_total_kgco2eq")
    _PCT_SHORT = (("freq_cv_percentiles", "freq_cv"),
                  ("mean_degradation_percentiles", "mean_degradation"),
                  ("idle_norm_percentiles", "idle_norm"))
    # appended to `scalars()` only when a fault model actually ran —
    # faultless rows must stay byte-identical (`diff_scalars` flags any
    # new key as drift, and the pinned golden mini-grid is faultless)
    _ROBUST_SCALARS = ("fault_model", "availability", "core_failures",
                      "machine_crashes", "stalls", "retries",
                      "failed_requests", "rejected_requests",
                      "pending_requests", "submitted",
                      "p99_degraded_window_s")
    # appended only when a non-uniform fleet ran, for the same reason
    _FLEET_SCALARS = ("fleet",)

    def scalars(self) -> dict[str, Any]:
        """One flat row: identity + scalar metrics + flattened
        percentiles (`mean_degradation_p99`-style keys). Per-machine
        detail is deliberately dropped — this is the diffable view."""
        row: dict[str, Any] = {f: getattr(self, f) for f in self._SCALARS}
        for field, short in self._PCT_SHORT:
            for p, v in getattr(self, field).items():
                row[f"{short}_p{p}"] = v
        if self.fault_model != "none":
            for f in self._ROBUST_SCALARS:
                row[f] = getattr(self, f)
        if self.fleet != "uniform":
            for f in self._FLEET_SCALARS:
                row[f] = getattr(self, f)
        if self.provenance is not None:
            row["config_hash"] = self.provenance.config_hash
            row["seed"] = self.provenance.seed
        return row

    def fleet_yearly_under(self, model=None) -> float:
        """Re-price the fleet's yearly embodied total under another
        carbon model. The simulation is carbon-model-independent, so
        repricing saved degradation data is exact: `model=None` rebuilds
        the result's own model *and opts*, reproducing
        `fleet_yearly_kgco2eq` bit for bit; a registry name is built
        with default opts; pass a `CarbonModel` instance for full
        control."""
        from repro.carbon import get_carbon_model
        from repro.carbon.base import CarbonModel
        if model is None:
            model = get_carbon_model(self.carbon_model,
                                     **dict(self.carbon_opts))
        elif not isinstance(model, CarbonModel):
            model = get_carbon_model(model)
        if self.deg_reference is None or self.per_machine_degradation is None:
            raise ValueError("result lacks per-machine degradation detail "
                             "(deg_reference/per_machine_degradation)")
        return float(sum(
            model.lifetime(self.deg_reference, max(d, 0.0)).yearly_kgco2eq
            for d in self.per_machine_degradation))

    def fleet_energy_under(self, model=None) -> float:
        """Re-price the fleet's horizon energy (kWh) under another power
        model. The saved per-machine residencies are power-model-
        independent, so repricing is exact: `model=None` rebuilds the
        result's own model *and opts*, reproducing `fleet_energy_kwh`
        bit for bit; a registry name is built with default opts; pass a
        `PowerModel` instance for full control."""
        from repro.power import get_power_model
        from repro.power.base import PowerModel
        if model is None:
            model = get_power_model(self.power_model,
                                    **dict(self.power_opts))
        elif not isinstance(model, PowerModel):
            model = get_power_model(model)
        if self.per_machine_residency is None:
            raise ValueError("result lacks per-machine residency detail "
                             "(per_machine_residency)")
        return float(sum(model.energy_kwh(r)
                         for r in self.per_machine_residency))

    def same_experiment(self, other: "ExperimentResult") -> bool:
        """True when both results carry provenance for the *same*
        experiment config — the precondition for diffing them as
        reruns."""
        return (self.provenance is not None
                and other.provenance is not None
                and self.provenance.config_hash
                == other.provenance.config_hash)


def _result_key(key) -> str | tuple[str, ...]:
    """Normalize a sweep key: JSON lists come back as tuples."""
    if isinstance(key, str):
        return key
    parts = tuple(key)
    return parts if len(parts) > 1 else parts[0]


class SweepResult(Mapping):
    """A `run_policy_sweep` grid: ordered `(key -> ExperimentResult)`.

    Behaves exactly like the dict the sweep historically returned
    (`sweep["proposed"]`, `sweep[("proposed", "jsq")]`, iteration in
    insertion order, `len`, `.items()` / `.values()`), plus:

      axes     — the grid's axis names, e.g. ("policy", "router")
      to_rows  — flat diffable dicts (axis columns + scalar metrics)
      save     — persist to JSON;  load — read back losslessly
    """

    def __init__(self, cells, axes: tuple[str, ...] = ("policy",)):
        self.axes = tuple(axes)
        self._cells: dict[Any, ExperimentResult] = {}
        for key, result in (cells.items() if isinstance(cells, Mapping)
                            else cells):
            key = _result_key(key)
            arity = len(key) if isinstance(key, tuple) else 1
            if arity != len(self.axes):
                raise ValueError(
                    f"sweep key {key!r} has {arity} part(s) but the grid "
                    f"declares axes {self.axes}")
            if not isinstance(result, ExperimentResult):
                raise TypeError(f"cell {key!r} must hold an "
                                f"ExperimentResult, got {result!r}")
            self._cells[key] = result

    # -- Mapping protocol ---------------------------------------------- #
    def __getitem__(self, key) -> ExperimentResult:
        return self._cells[_result_key(key)]

    def __iter__(self) -> Iterator:
        return iter(self._cells)

    def __len__(self) -> int:
        return len(self._cells)

    def __repr__(self) -> str:
        return (f"SweepResult(axes={self.axes}, "
                f"cells={len(self._cells)})")

    # -- tabulation / persistence -------------------------------------- #
    def to_rows(self) -> list[dict[str, Any]]:
        """One flat dict per cell: axis columns first, then the cell's
        scalar metrics — ready for CSV emission or cross-run diffs."""
        rows = []
        for key, result in self._cells.items():
            parts = key if isinstance(key, tuple) else (key,)
            row = dict(zip(self.axes, parts))
            row.update(result.scalars())
            rows.append(row)
        return rows

    def to_dict(self) -> dict[str, Any]:
        return {
            "schema": RESULT_SCHEMA_VERSION,
            "package_version": PACKAGE_VERSION,
            "axes": list(self.axes),
            "cells": [
                {"key": list(key) if isinstance(key, tuple) else [key],
                 "result": result.to_dict()}
                for key, result in self._cells.items()
            ],
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SweepResult":
        _check_schema(d.get("schema", RESULT_SCHEMA_VERSION))
        axes = tuple(d["axes"])
        cells = [(_result_key(c["key"]),
                  ExperimentResult.from_dict(c["result"]))
                 for c in d["cells"]]
        return cls(cells, axes=axes)

    def save(self, path: str) -> None:
        """Write the grid to `path` as JSON (lossless: `load` restores
        every field, including per-machine detail and provenance)."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "SweepResult":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def diff_scalars(self, other: "SweepResult",
                     rel_tol: float = 0.0) -> dict[Any, dict[str, tuple]]:
        """Cells/fields whose scalar metrics differ between two sweeps —
        `{key: {field: (self_value, other_value)}}`. A cell present in
        only one sweep is itself a diff, reported under the pseudo-field
        `"_cell"` as `("present", "missing")` (or the reverse), so a
        dropped or renamed grid cell can never pass a
        `diff_scalars(old) == {}` drift check. NaN == NaN here (a
        starved cell matching a starved cell is not a diff)."""
        out: dict[Any, dict[str, tuple]] = {}
        for key in other:
            if key not in self:
                out[key] = {"_cell": ("missing", "present")}
        for key in self:
            if key not in other:
                out[key] = {"_cell": ("present", "missing")}
                continue
            a, b = self[key].scalars(), other[key].scalars()
            fields = {}
            for f, va in a.items():
                vb = b.get(f)
                if isinstance(va, float) and isinstance(vb, float):
                    if math.isnan(va) and math.isnan(vb):
                        continue
                    if va == vb or (rel_tol and vb and
                                    abs(va - vb) <= rel_tol * abs(vb)):
                        continue
                    fields[f] = (va, vb)
                elif va != vb:
                    fields[f] = (va, vb)
            if fields:
                out[key] = fields
        return out
