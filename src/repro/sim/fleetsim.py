"""Vectorized time-stepped fleet engine (`ExperimentConfig.engine="fleet"`).

The event engine (`repro.sim.cluster`) is the bit-exact small-scale
reference: every CPU task is a heap event, every core an object field.
That tops out around tens of machines x minutes. This module is the
second engine of the two-engine architecture: a mean-field / fluid
surrogate that advances the whole stacked ``(n_machines, n_cores)``
fleet state with array ops, so hundreds of machines x hours-to-weeks of
simulated time run at interactive wall times.

Model (per micro step of ``dt_s`` seconds, all quantities fluid):

* **Workload** — the scenario's request trace is binned into per-step
  arrival counts / input-token / output-token sums. Arrivals split
  evenly across prompt instances (the fluid limit of JSQ: a
  join-shortest-queue router keeps fluid queues balanced, so the
  even split *is* its mean-field fixed point).
* **Prefill** — each prompt machine carries a GPU backlog in seconds +
  requests; it drains at 1 GPU-second/second using the event engine's
  timing constants. Completed prefills flow (evenly, same JSQ limit) to
  token instances.
* **Decode** — each token machine carries a continuous batch (capped at
  ``MAX_DECODE_BATCH``) and its remaining-token mass; the iteration
  period is the event engine's ``start_iteration`` CPU time plus the
  batch-dependent GPU pass, so CPU aging genuinely stretches decode.
* **CPU** — per-request task work (the same ``TASK_DURATIONS_S``
  constants the event engine schedules as discrete events) arrives as a
  per-machine fluid inflow; busy cores follow Little's law
  (work rate / settled core speed), with overflow carried as an
  oversubscription backlog.
* **Aging** — once per idling period the accumulated busy core-seconds
  are settled through the exact NBTI recursion (the update composes
  exactly under a constant ADF, so per-period advancement introduces no
  integration error beyond regime-ordering within the period), and
  Algorithm 2's reaction function gates most-aged / wakes least-aged
  cores per machine via vectorized rank selection.

Two backends share the same functional step:

* ``backend="numpy"`` — float64, deterministic, and the reference for
  checkpoint/resume exactness (a resumed run reproduces the
  uninterrupted run's ``ExperimentResult`` scalars bit-for-bit).
* ``backend="jax"`` — the step is compiled with ``jax.lax.scan`` over
  macro periods (an inner scan covers the micro steps); the aging
  settlement routes through ``repro.kernels.aging_update`` — the
  Pallas kernel on TPU, its jnp oracle elsewhere. float32: fast, NOT
  bit-exact vs numpy (documented caveat; see ``--help`` epilogs).

``backend="auto"`` resolves to jax when importable, else numpy — the
promotion of the batched aging backend from opt-in to default at scale.

What the surrogate does NOT model: per-core task placement (stress
spreads evenly over the active set, so within-machine frequency CV
comes from process variation + gating asymmetry only), router choice
(always the JSQ fluid limit), and sub-period event ordering. Parity vs
the event engine on small configs is pinned with tolerances in
``tests/test_fleetsim.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core import aging, temperature, variation
from repro.hardware.inventory import resolve_fleet
from repro.power.residency import StateResidency
from repro.sim import metrics as metrics_mod
from repro.sim.cluster import (
    DECODE_ITER_BASE_S,
    DECODE_ITER_PER_REQ_S,
    IB_LINK_BW_BPS,
    KV_BYTES_PER_TOKEN,
    MAX_DECODE_BATCH,
    PREFILL_BASE_S,
    PREFILL_PER_TOKEN_S,
)
from repro.sim.config import ExperimentConfig
from repro.sim.results import ExperimentResult
from repro.sim.tasks import TASK_DURATIONS_S

# ---------------------------------------------------------------------- #
# Per-request CPU work (nominal core-seconds), assembled from the same
# task table the event engine schedules discretely (see Machine.*).
# ---------------------------------------------------------------------- #
# prompt side, on arrival: submit -> submit_chain -> alloc_memory ->
# submit_task (the serial admission chain).
_W_PROMPT_ARRIVAL = (TASK_DURATIONS_S["submit"]
                     + TASK_DURATIONS_S["submit_chain"]
                     + TASK_DURATIONS_S["alloc_memory"]
                     + TASK_DURATIONS_S["submit_task"])
# prompt side, on prefill completion: finish_task || submit_flow.
_W_PROMPT_FINISH = (TASK_DURATIONS_S["finish_task"]
                    + TASK_DURATIONS_S["submit_flow"])
# token side, on flow arrival: flow_completion -> alloc_memory.
_W_TOKEN_ARRIVAL = (TASK_DURATIONS_S["flow_completion"]
                    + TASK_DURATIONS_S["alloc_memory"])
# token side, per decode iteration (serial with the GPU pass).
_W_TOKEN_ITER = TASK_DURATIONS_S["start_iteration"]
# token side, on request completion: free_memory + finish_request
# (after t_done — not on the latency critical path, but CPU load).
_W_TOKEN_FINISH = (TASK_DURATIONS_S["free_memory"]
                   + TASK_DURATIONS_S["finish_request"])
# serial CPU latency before prefill admission (excludes submit_task,
# which is folded into the prefill service time like the event loop).
_LAT_CPU_PROMPT = (TASK_DURATIONS_S["submit"]
                   + TASK_DURATIONS_S["submit_chain"]
                   + TASK_DURATIONS_S["alloc_memory"])
_MEAN_TASK_S = float(np.mean(list(TASK_DURATIONS_S.values())))
_KV_S_PER_TOKEN = KV_BYTES_PER_TOKEN / IB_LINK_BW_BPS

_IDLE_BINS = 512          # linear histogram over [-1, 1] for idle_norm
_EPS = 1e-12


def _resolve_backend(requested: str) -> str:
    """'auto' promotes the batched jax aging path when available."""
    if requested == "numpy":
        return "numpy"
    if requested == "jax":
        import jax  # noqa: F401  (raises if genuinely unavailable)
        return "jax"
    if requested == "auto":
        try:
            import jax  # noqa: F401
            return "jax"
        except Exception:
            return "numpy"
    raise ValueError(f"unknown fleet backend {requested!r}: expected "
                     f"'numpy', 'jax' or 'auto'")


@dataclasses.dataclass
class _Shape:
    """Static geometry + timing constants of one fleet run."""
    n_prompt: int
    n_token: int
    num_cores: int
    dt_s: float
    steps_per_period: int     # micro steps per idling period
    n_macro: int              # macro (idling-period) steps
    mwin_s: float             # metrics-window width
    n_mwin: int
    pwin_s: float             # residency-window width
    n_pwin: int
    duration_s: float
    mean_out_tokens: float    # trace-wide mean output tokens/request
    gating: bool              # policy gates cores (Algorithm 2)?
    # per-machine CPU-wait clip fed into the window means: inf (exact
    # no-op) when faultless; duration_s under fault injection, so one
    # dead machine's unbounded backlog/capacity ratio can't poison the
    # fleet-mean latency windows
    wait_cap_s: float = float("inf")
    # per-machine core counts for heterogeneous fleets
    # (`repro.hardware`): None = uniform legacy fleet (`num_cores`
    # everywhere, zero ragged bookkeeping); otherwise a fleet-order
    # tuple and `num_cores` is the padded max — lanes beyond a
    # machine's count are excluded everywhere via a pad mask.
    core_counts: tuple | None = None

    @property
    def n_machines(self) -> int:
        return self.n_prompt + self.n_token

    @property
    def total_cores(self) -> int:
        if self.core_counts is None:
            return self.n_machines * self.num_cores
        return int(sum(self.core_counts))


def _initial_state(shape: _Shape) -> dict[str, np.ndarray]:
    """Stacked fleet state; every mutable quantity of a run lives here
    (and therefore checkpoints/restores as one array dict)."""
    M, N = shape.n_machines, shape.num_cores
    P, K = shape.n_prompt, shape.n_token
    W, PW = shape.n_mwin, shape.n_pwin
    return {
        "macro": np.zeros((), dtype=np.int64),       # completed macro steps
        "dvth": np.zeros((M, N)),
        "gated": np.zeros((M, N), dtype=bool),
        # fluid queues
        "pq_s": np.zeros(P), "pq_n": np.zeros(P), "pq_out": np.zeros(P),
        "d_batch": np.zeros(K), "d_tokens": np.zeros(K),
        "d_pend": np.zeros(K), "d_pend_tok": np.zeros(K),
        "cpu_backlog": np.zeros(M),
        "busy_s": np.zeros((M, N)),     # busy core-seconds since settle
        "u_last": np.zeros(M), "ov_last": np.zeros(M),
        # metrics windows (streaming aggregates — bounded for any horizon)
        "mw_cnt": np.zeros(W), "mw_wait": np.zeros(W),
        "mw_iter": np.zeros(W), "mw_cpuw": np.zeros(W),
        "mw_sp": np.zeros(W), "mw_st": np.zeros(W),
        "mw_comps": np.zeros(W),
        # residency windows (per machine, for the power models)
        "res_busy": np.zeros((M, PW)), "res_idle": np.zeros((M, PW)),
        "res_gated": np.zeros((M, PW)), "res_fbusy": np.zeros((M, PW)),
        # sample statistics
        "idle_hist": np.zeros(_IDLE_BINS, dtype=np.int64),
        "task_sum": np.zeros(()), "task_cnt": np.zeros(()),
        "task_max": np.zeros(()),
        "completions": np.zeros(()),
    }


# ---------------------------------------------------------------------- #
# Functional fleet step — written once against an array namespace `xp`
# (numpy or jax.numpy) so both backends run the same physics.
# ---------------------------------------------------------------------- #
def _micro_step(xp, shape: _Shape, dyn, q, arr_row):
    """One fluid micro step. `dyn` = per-period derived state
    (sp, st, sm, active counts); `q` = queue-state tuple; `arr_row` =
    (arrivals, input-token sum, output-token sum) for this step.
    Returns (q', observables)."""
    (pq_s, pq_n, pq_out, d_batch, d_tokens, d_pend, d_pend_tok,
     cpu_backlog) = q
    sp, st, sm, active = dyn          # (P,), (K,), (M,), (M,)
    P, K = shape.n_prompt, shape.n_token
    dt = shape.dt_s
    a, in_sum, out_sum = arr_row

    # Prefill wait seen by an arrival this step: GPU backlog ahead of it
    # (sampled before the arrival joins), fleet-mean across prompt
    # instances (even JSQ-limit split).
    wait_p = xp.mean(pq_s)

    # 1) arrivals -> prompt queues (even split) + prompt CPU work
    pq_n = pq_n + a / P
    pq_s = pq_s + (a * PREFILL_BASE_S + PREFILL_PER_TOKEN_S * in_sum
                   + a * TASK_DURATIONS_S["submit_task"] / sp) / P
    pq_out = pq_out + out_sum / P
    work_p = (a / P) * _W_PROMPT_ARRIVAL

    # 2) prefill drain (1 GPU-second per second)
    ds = xp.minimum(pq_s, dt)
    frac = ds / xp.maximum(pq_s, _EPS)
    done_n = pq_n * frac
    done_out = pq_out * frac
    pq_s = pq_s - ds
    pq_n = pq_n - done_n
    pq_out = pq_out - done_out
    work_p = work_p + done_n * _W_PROMPT_FINISH
    c_total = xp.sum(done_n)
    o_total = xp.sum(done_out)

    # 3) flow to token instances (even split) + decode admission
    d_pend = d_pend + c_total / K
    d_pend_tok = d_pend_tok + o_total / K
    work_t = (c_total / K) * _W_TOKEN_ARRIVAL
    room = xp.maximum(MAX_DECODE_BATCH - d_batch, 0.0)
    adm = xp.minimum(d_pend, room)
    tok_per_pend = d_pend_tok / xp.maximum(d_pend, _EPS)
    d_batch = d_batch + adm
    d_tokens = d_tokens + adm * tok_per_pend
    d_pend = d_pend - adm
    d_pend_tok = d_pend_tok - adm * tok_per_pend

    # 4) decode iterations: CPU start_iteration is serial with the GPU
    # pass, so aged (slower) CPUs stretch the iteration period — the
    # paper's aging -> service-quality coupling.
    iter_period = (_W_TOKEN_ITER / st + DECODE_ITER_BASE_S
                   + DECODE_ITER_PER_REQ_S
                   * xp.minimum(d_batch, MAX_DECODE_BATCH))
    busy_gpu = d_batch > _EPS
    iters = xp.where(busy_gpu, dt / iter_period, 0.0)
    tokens_out = xp.minimum(iters * d_batch, d_tokens)
    # completion rate = batch x token-rate / remaining mass (fluid drain
    # of the residual-token distribution; integrates to the full batch).
    comps = xp.minimum(
        d_batch * tokens_out / xp.maximum(d_tokens, _EPS), d_batch)
    d_tokens = xp.maximum(d_tokens - tokens_out, 0.0)
    drained = d_tokens <= _EPS
    comps = xp.where(drained, d_batch, comps)
    d_batch = xp.where(drained, 0.0, xp.maximum(d_batch - comps, 0.0))
    work_t = work_t + _W_TOKEN_ITER * iters + comps * _W_TOKEN_FINISH
    comps_total = xp.sum(comps)

    # 5) CPU layer (Little's law): nominal work executes at the settled
    # mean core speed; overflow carries as oversubscription backlog.
    work = xp.concatenate([work_p, work_t])
    todo = cpu_backlog + work
    cap = active * dt * sm
    done = xp.minimum(todo, cap)
    cpu_backlog = todo - done
    u = done / (dt * sm)                       # busy cores (fractional)
    ov = cpu_backlog / _MEAN_TASK_S            # oversubscribed tasks
    cpu_wait = xp.mean(xp.minimum(
        cpu_backlog / xp.maximum(active * sm, _EPS), shape.wait_cap_s))

    q2 = (pq_s, pq_n, pq_out, d_batch, d_tokens, d_pend, d_pend_tok,
          cpu_backlog)
    obs = {
        "u": u, "ov": ov, "done": done,
        "wait_p": wait_p,
        "iter_mean": xp.mean(iter_period),
        "cpu_wait": cpu_wait,
        "comps": comps_total,
        "sp_mean": xp.mean(sp), "st_mean": xp.mean(st),
    }
    return q2, obs


def _settle_aging(shape: _Shape, dvth, gated, busy_s, advance):
    """Settle one idling period of aging: every non-gated core spends
    its accumulated busy core-seconds at active-allocated stress and the
    remainder of the period at active-unallocated stress; gated cores
    are frozen (ADF = 0). Exact per regime — the NBTI recursion composes
    under a constant ADF."""
    period = shape.steps_per_period * shape.dt_s
    tau_busy = np.minimum(busy_s, period) if isinstance(busy_s, np.ndarray) \
        else busy_s
    tau_idle = period - tau_busy
    dvth = advance(dvth, gated, tau_busy,
                   temperature.TEMP_ACTIVE_ALLOCATED_C)
    dvth = advance(dvth, gated, tau_idle,
                   temperature.TEMP_ACTIVE_UNALLOCATED_C)
    return dvth


def _gate_correction(xp, shape: _Shape, active_n, u, ov, g_now, carbon,
                     n_vec=None):
    """Vectorized Algorithm 2 reaction (`idling.core_correction`), with
    the optional carbon-aware temporal reshaping. `n_vec` (ragged
    fleets) supplies per-machine core counts; None keeps the uniform
    scalar `shape.num_cores`."""
    N = shape.num_cores if n_vec is None else n_vec
    tasks = xp.minimum(N * 1.0, u + ov)
    e = (active_n - tasks) / N
    f = xp.where(e >= 0.0, xp.tan(0.785 * e), xp.arctan(1.55 * e))
    corr = xp.trunc(N * f)
    if carbon is not None:
        g_mean, dirty_frac, defer_frac, guard, gain = carbon
        dirty = g_now > dirty_frac * g_mean
        amplified = xp.trunc(corr * gain)
        deferred = corr + xp.trunc(-corr * defer_frac)
        corr = xp.where(
            dirty & (corr > 0), amplified,
            xp.where(dirty & (corr < 0) & (ov <= guard), deferred, corr))
    return corr


def _apply_gating(xp, corr, gated, busy_n, dvth, failed=None):
    """Vectorized `idling.apply_correction`: gate `corr` most-aged
    spare active cores (+) or wake `-corr` least-aged gated cores (-)
    per machine, by rank selection along the core axis. `failed`
    (fault layer) excludes permanently-offlined cores from both sides —
    they are never active and must never be woken; `None` leaves the
    selection identical to the pre-fault behavior."""
    active = ~gated if failed is None else ~gated & ~failed
    wakeable = gated if failed is None else gated & ~failed
    eligible = xp.sum(active, axis=1) - busy_n
    k_gate = xp.clip(corr, 0.0, xp.maximum(eligible, 0.0))
    key = xp.where(active, dvth, -np.inf)
    rank_g = xp.argsort(xp.argsort(-key, axis=1), axis=1)
    gate_new = rank_g < k_gate[:, None]
    k_wake = xp.clip(-corr, 0.0, xp.sum(wakeable, axis=1))
    keyw = xp.where(wakeable, dvth, np.inf)
    rank_w = xp.argsort(xp.argsort(keyw, axis=1), axis=1)
    wake = rank_w < k_wake[:, None]
    return (gated | gate_new) & ~wake


def _redistribute_queues(xp, q, onset, up, P):
    """Crash onset: move a down machine's fluid queue mass to the live
    machines of its serving group — the fluid analog of the event
    engine's re-dispatch — and return (q', re-dispatched request mass).
    `onset` is the per-machine crash-onset mask for this macro period,
    `up` the per-machine up-fraction column."""
    (pq_s, pq_n, pq_out, d_batch, d_tokens, d_pend, d_pend_tok,
     cpu_backlog) = q
    live = (up > 0.5) & ~onset

    def move(col, on, upm):
        lost = xp.where(on, col, 0.0)
        tot = xp.sum(lost)
        n_up = xp.sum(upm)
        share = xp.where(upm, tot / xp.maximum(n_up, 1), 0.0)
        # nowhere to go (whole group down): keep the mass in place
        return xp.where(n_up > 0, col - lost + share, col)

    on_p, on_t = onset[:P], onset[P:]
    up_p, up_t = live[:P], live[P:]
    retried = (xp.sum(xp.where(on_p, pq_n, 0.0))
               + xp.sum(xp.where(on_t, d_batch + d_pend, 0.0)))
    q2 = (move(pq_s, on_p, up_p), move(pq_n, on_p, up_p),
          move(pq_out, on_p, up_p), move(d_batch, on_t, up_t),
          move(d_tokens, on_t, up_t), move(d_pend, on_t, up_t),
          move(d_pend_tok, on_t, up_t), move(cpu_backlog, onset, live))
    return q2, retried


class _FleetFaults:
    """Vectorized fault layer for the fleet engine (`repro.faults`).

    The event engine applies fault decisions per machine per tick; the
    fleet surrogate applies the same three built-in models as capacity
    columns and masks:

      machine-crash    — the crash/reboot timeline is *precomputed* from
                         the same per-machine RNG streams
                         (`default_rng([seed, 0xFA, mid])`, Exp(mttf)
                         inter-arrivals): per-macro up-fraction columns
                         scale each machine's CPU capacity, and queue
                         mass is redistributed to live machines at each
                         crash onset (fluid re-dispatch).
      transient-stall  — onsets replayed from the same streams (two
                         draws per machine per period, like the event
                         model) into per-macro capacity multipliers:
                         one core at `slowdown` x speed for `stall_s`.
      guardband        — dynamic (depends on the evolving aging state):
                         each core draws an Exp(1) failure threshold up
                         front; per macro the cumulative hazard
                         `hazard_per_s * period * max(over, 0)`
                         integrates inside the scan and a core fails
                         when it crosses its threshold (inverse-CDF
                         sampling of the first failure under the
                         time-varying hazard — same hazard law as the
                         event model, without per-tick uniforms).
                         Failed cores leave the active set permanently
                         and freeze (like DEEP_IDLE parking).

    What stays approximate: GPU queues of a down machine keep draining
    (capacity loss is modeled in the CPU layer only), and failures land
    at macro boundaries. Engine parity under faults is therefore NOT
    pinned — fault experiments at fleet scale are surrogate estimates,
    the event engine is the reference.
    """

    def __init__(self, cfg: ExperimentConfig, shape: _Shape):
        from repro.faults import get_fault_model
        model = get_fault_model(cfg.fault_model, **cfg.fault_options)
        if model.name not in ("guardband", "machine-crash",
                              "transient-stall"):
            raise ValueError(
                f"fleet engine cannot vectorize fault model "
                f"{model.name!r}; run it under engine='event'")
        M, N = shape.n_machines, shape.num_cores
        # per-machine core counts (ragged fleets; uniform otherwise)
        counts = ([N] * M if shape.core_counts is None
                  else list(shape.core_counts))
        self.period = shape.steps_per_period * shape.dt_s
        self.kind = model.name
        # neutral columns; the matching branch below fills its own
        self.up_frac = np.ones((shape.n_macro, M))
        self.onset = np.zeros((shape.n_macro, M), dtype=bool)
        self.cap_mult = np.ones((shape.n_macro, M))
        self.guard = None
        self.thresh = None
        self.n_crashes = 0
        self.n_stalls = 0
        self.static_lost_core_s = 0.0
        self.windows: list[tuple[float, float]] = []
        dur = shape.duration_s
        rngs = [np.random.default_rng([cfg.seed, 0xFA, mid])
                for mid in range(M)]
        if self.kind == "guardband":
            self.guard = (model.margin, model.hazard_per_s)
            if shape.core_counts is None:
                self.max_failed_n = float(int(model.max_failed_frac * N))
                self.thresh = np.stack([r.exponential(1.0, size=N)
                                        for r in rngs])
            else:
                # per-machine failure budgets; padded lanes get an
                # infinite threshold so they can never fail
                self.max_failed_n = np.array(
                    [float(int(model.max_failed_frac * n))
                     for n in counts])
                self.thresh = np.full((M, N), np.inf)
                for mid, (r, n) in enumerate(zip(rngs, counts)):
                    self.thresh[mid, :n] = r.exponential(1.0, size=n)
        elif self.kind == "machine-crash":
            for mid, rng in enumerate(rngs):
                t = float(rng.exponential(model.mttf_s))
                while t < dur:
                    self.n_crashes += 1
                    down_until = t + model.reboot_s
                    k0 = min(int(t / self.period), shape.n_macro - 1)
                    self.onset[k0, mid] = True
                    k1 = min(int(min(down_until, dur) / self.period),
                             shape.n_macro - 1)
                    for k in range(k0, k1 + 1):
                        lo = max(t, k * self.period)
                        hi = min(down_until, (k + 1) * self.period, dur)
                        if hi > lo:
                            self.up_frac[k, mid] -= (hi - lo) / self.period
                    self.static_lost_core_s += counts[mid] \
                        * (min(down_until, dur) - t)
                    self.windows.append((t, min(down_until, dur)))
                    t = down_until + float(rng.exponential(model.mttf_s))
        else:   # transient-stall
            p = -np.expm1(-model.rate_per_s * self.period)
            for mid, rng in enumerate(rngs):
                slow_loss = (1.0 - model.slowdown) / counts[mid]
                for k in range(shape.n_macro):
                    u = float(rng.random())
                    rng.integers(N)      # core id (capacity-aggregated)
                    if u >= p:
                        continue
                    self.n_stalls += 1
                    t0 = (k + 1) * self.period
                    t1 = min(t0 + model.stall_s, dur)
                    k1 = min(int(t1 / self.period), shape.n_macro - 1)
                    for kk in range(k + 1, k1 + 1):
                        lo = max(t0, kk * self.period)
                        hi = min(t1, (kk + 1) * self.period)
                        if hi > lo:
                            self.cap_mult[kk, mid] -= \
                                (hi - lo) / self.period * slow_loss
                    if t1 > t0:
                        self.windows.append((t0, t1))

    def robustness(self, state, completed: int, submitted: int) -> dict:
        """Fleet-side robustness scalars (same keys the event engine's
        `FaultCoordinator.robustness` produces)."""
        from repro.sim.cluster import _merge_intervals
        sh_lost = float(state.get("lost_core_s", 0.0))
        lost = sh_lost + self.static_lost_core_s
        core_failures = (int(state["failed"].sum())
                         if self.guard is not None else 0)
        widths = [hi - lo for lo, hi in _merge_intervals(self.windows)]
        if self.guard is not None and core_failures:
            # failures land at macro boundaries; each degrades the
            # machine for ~one re-sizing period
            widths.append(self.period)
        return {
            "core_failures": core_failures,
            "machine_crashes": self.n_crashes,
            "stalls": self.n_stalls,
            "retries": int(round(float(state.get("retried", 0.0)))),
            "failed_requests": 0,
            "rejected_requests": 0,
            "submitted": submitted,
            "pending_requests": max(submitted - completed, 0),
            "p99_degraded_window_s": (
                float(np.percentile(np.asarray(widths), 99))
                if widths else 0.0),
            "_lost_core_s": lost,
        }


def _derived(xp, shape: _Shape, f0, dvth, gated, headroom):
    """Per-period derived quantities: settled per-core speeds and the
    per-machine active-core mean speed used by the fluid layers."""
    f = f0 * (1.0 - dvth / headroom)
    active = ~gated
    active_n = xp.sum(active, axis=1)
    sm = xp.sum(xp.where(active, f, 0.0), axis=1) / xp.maximum(
        active_n, 1.0)
    sp = sm[:shape.n_prompt]
    st = sm[shape.n_prompt:]
    return f, sp, st, sm, active_n


# ---------------------------------------------------------------------- #
# Engine
# ---------------------------------------------------------------------- #
class FleetEngine:
    """Time-stepped vectorized fleet simulator (see module docstring).

    ``engine_opts`` (via ``ExperimentConfig.engine_opts``):

    * ``dt_s`` (default 0.25) — fluid micro-step width, seconds.
    * ``backend`` — "numpy" | "jax" | "auto" (default "auto").
    * ``use_kernel`` — route the jax aging settle through the Pallas
      kernel (default: only on TPU; the jnp oracle elsewhere).
    * ``checkpoint_dir`` — directory for periodic fleet checkpoints
      (written through ``repro.checkpoint.store``).
    * ``checkpoint_every_s`` (default 600) — simulated seconds between
      checkpoints.
    * ``resume`` (default True) — resume from the latest checkpoint in
      ``checkpoint_dir`` whose config fingerprint matches.
    """

    def __init__(self, cfg: ExperimentConfig, telemetry=None):
        opts = cfg.engine_options
        unknown = set(opts) - {"dt_s", "backend", "use_kernel",
                               "checkpoint_dir", "checkpoint_every_s",
                               "resume"}
        if unknown:
            raise ValueError(f"unknown engine_opts {sorted(unknown)}")
        self.cfg = cfg
        self.telemetry = telemetry
        self.backend = _resolve_backend(str(opts.get("backend", "auto")))
        self.checkpoint_dir = opts.get("checkpoint_dir")
        self.checkpoint_every_s = float(opts.get("checkpoint_every_s",
                                                 600.0))
        self.resume = bool(opts.get("resume", True))
        self._use_kernel = opts.get("use_kernel")

        dt = float(opts.get("dt_s", 0.25))
        if dt <= 0:
            raise ValueError(f"dt_s must be > 0, got {dt}")
        dt = min(dt, cfg.idling_period_s)
        spp = max(1, round(cfg.idling_period_s / dt))
        dt = cfg.idling_period_s / spp          # align to the period
        n_macro = max(1, int(round(cfg.duration_s / cfg.idling_period_s)))
        mwin = max(dt, cfg.duration_s / 512.0)
        pwin = cfg.resolved_power_window_s
        # Heterogeneous fleet (`repro.hardware`): None for the uniform
        # default, in which case every branch below runs the legacy
        # bit-exact path with zero ragged bookkeeping. Mixed fleets pad
        # the core axis to the widest SKU and mask the extra lanes.
        self.inventory = resolve_fleet(cfg.fleet, cfg.fleet_options,
                                       cfg.n_machines)
        inv = self.inventory
        if inv is None:
            self.params = aging.DEFAULT_PARAMS
            num_cores, core_counts = cfg.num_cores, None
        else:
            # one shared NBTI operating point (raises for mixed Vdd/Vth
            # fleets — those need the per-machine event engine)
            self.params = inv.shared_dynamics_params()
            num_cores, core_counts = inv.max_cores, tuple(inv.num_cores)
        self.shape = _Shape(
            n_prompt=cfg.n_prompt, n_token=cfg.n_token,
            num_cores=num_cores, dt_s=dt, steps_per_period=spp,
            n_macro=n_macro,
            mwin_s=mwin, n_mwin=int(np.ceil(cfg.duration_s / mwin)) + 1,
            pwin_s=pwin, n_pwin=int(np.ceil(cfg.duration_s / pwin)) + 1,
            duration_s=cfg.duration_s,
            mean_out_tokens=0.0,        # set from the trace in run()
            gating=cfg.policy == "proposed",
            core_counts=core_counts,
        )
        # Same per-machine initial-frequency draw as the event engine's
        # CoreManager (seeded rng per machine), so both engines simulate
        # literally the same silicon — on mixed fleets each machine
        # draws its own SKU's variation parameters and core count.
        if inv is None:
            vp = variation.VariationParams(f_nominal=self.params.f_nominal)
            self.f0 = np.stack([
                variation.sample_initial_frequencies(
                    vp, cfg.num_cores,
                    np.random.default_rng(cfg.seed * 1000 + i))
                for i in range(self.shape.n_machines)])
            self._pad = None
            self._n_vec = None
        else:
            self.f0 = np.ones((self.shape.n_machines, num_cores))
            for i, n in enumerate(core_counts):
                self.f0[i, :n] = variation.sample_initial_frequencies(
                    inv.variation_params[i], n,
                    np.random.default_rng(cfg.seed * 1000 + i))
            self._pad = (np.arange(num_cores)[None, :]
                         >= np.asarray(core_counts)[:, None])
            self._n_vec = np.asarray(core_counts, dtype=np.float64)
        self._carbon_gate = self._resolve_carbon_gate(cfg)
        self.state = _initial_state(self.shape)
        # Fault layer (None with the default "none" model — the state
        # dict, scan signature and physics stay exactly the pre-fault
        # ones, so faultless runs are bit-identical on both backends).
        self._faults = (_FleetFaults(cfg, self.shape)
                        if cfg.fault_model != "none" else None)
        if self._faults is not None:
            self.shape.wait_cap_s = cfg.duration_s
            self.state["lost_core_s"] = np.zeros(())
            self.state["retried"] = np.zeros(())
            if self._faults.guard is not None:
                self.state["failed"] = np.zeros(
                    (self.shape.n_machines, self.shape.num_cores),
                    dtype=bool)
                self.state["cum_haz"] = np.zeros(
                    (self.shape.n_machines, self.shape.num_cores))
        self.resumed_from: int | None = None

    @staticmethod
    def _resolve_carbon_gate(cfg: ExperimentConfig):
        """(intensity_fn, params) for carbon-aware proposed configs."""
        popts = cfg.policy_options
        if cfg.policy != "proposed" or not popts.get("carbon_aware"):
            return None
        from repro.carbon.intensity import get_intensity
        intensity = get_intensity(popts.get("intensity", "diurnal"),
                                  **dict(popts.get("intensity_opts") or {}))
        return (intensity,
                (intensity.mean_g_per_kwh(),
                 float(popts.get("dirty_frac", 1.05)),
                 float(popts.get("defer_frac", 0.5)),
                 float(popts.get("guard_tasks", 2)),
                 float(popts.get("gate_gain", 2.0))))

    # ------------------------------------------------------------------ #
    # trace binning
    # ------------------------------------------------------------------ #
    def _bin_trace(self, requests) -> np.ndarray:
        """(T_micro, 3) per-step [arrival count, input-token sum,
        output-token sum] from the scenario's request trace."""
        sh = self.shape
        n_steps = sh.n_macro * sh.steps_per_period
        out = np.zeros((n_steps, 3))
        if not requests:
            return out
        t_arr = np.fromiter((r.arrival_s for r in requests), float,
                            count=len(requests))
        n_in = np.fromiter((r.input_tokens for r in requests), float,
                           count=len(requests))
        n_out = np.fromiter((r.output_tokens for r in requests), float,
                            count=len(requests))
        idx = np.clip((t_arr / sh.dt_s).astype(np.int64), 0, n_steps - 1)
        out[:, 0] = np.bincount(idx, minlength=n_steps)
        out[:, 1] = np.bincount(idx, weights=n_in, minlength=n_steps)
        out[:, 2] = np.bincount(idx, weights=n_out, minlength=n_steps)
        return out

    # ------------------------------------------------------------------ #
    # run
    # ------------------------------------------------------------------ #
    def run(self, requests) -> None:
        sh = self.shape
        sh.mean_out_tokens = (float(np.mean([r.output_tokens
                                             for r in requests]))
                              if requests else 1.0)
        arr = self._bin_trace(requests)
        self._requests = requests
        start = 0
        if self.checkpoint_dir and self.resume:
            start = self._try_resume()
        if self.backend == "jax":
            self._run_jax(arr, start)
        else:
            self._run_numpy(arr, start)

    # -- checkpoint/resume --------------------------------------------- #
    def _checkpoint(self, macro: int) -> None:
        from repro.checkpoint import store
        state = {k: np.asarray(v) for k, v in self.state.items()}
        state["macro"] = np.asarray(macro, dtype=np.int64)
        store.save(self.checkpoint_dir, macro, state,
                   extra={"config": self.cfg.fingerprint(),
                          "engine": "fleet", "backend": self.backend,
                          "macro": macro})

    def _try_resume(self) -> int:
        from repro.checkpoint import store
        if store.latest_step(self.checkpoint_dir) is None:
            return 0
        template = {k: np.asarray(v) for k, v in self.state.items()}
        # step=None lets the store digest-verify the newest checkpoint
        # and fall back (with a warning) to the newest earlier step that
        # verifies, so one torn write doesn't strand the whole run.
        restored = store.restore(self.checkpoint_dir, template)
        # copy: restored arrays can be read-only views of the npz buffer
        state = {k: np.array(v) for k, v in restored.items()}
        step = int(state["macro"])      # save() labels steps by macro
        meta = store.meta(self.checkpoint_dir, step)
        if meta.get("config") != self.cfg.fingerprint():
            raise ValueError(
                f"checkpoint at {self.checkpoint_dir!r} step {step} was "
                f"written by config {meta.get('config')!r}, not "
                f"{self.cfg.fingerprint()!r}: refusing to resume a "
                f"different experiment")
        self.state = state
        self.resumed_from = step
        return int(self.state["macro"])

    # -- numpy driver --------------------------------------------------- #
    def _advance_numpy(self, dvth, gated, tau, temp_c):
        a = aging.adf(self.params, temp_c, 1.0)
        tau = np.where(gated, 0.0, np.broadcast_to(tau, dvth.shape))
        return aging.advance_dvth(self.params, dvth, a, tau)

    def _run_numpy(self, arr: np.ndarray, start_macro: int) -> None:
        sh, st = self.shape, self.state
        xp = np
        P = sh.n_prompt
        spp = sh.steps_per_period
        next_ckpt = self._next_ckpt(start_macro)
        g_fn = self._carbon_gate[0].g_per_kwh if self._carbon_gate else None
        fx = self._faults
        pad = self._pad
        # per-machine core counts: the scalar num_cores on uniform
        # fleets (identical arithmetic to the pre-hardware engine), a
        # (M,) vector on ragged ones
        n_vec = sh.num_cores if pad is None else self._n_vec
        for k in range(start_macro, sh.n_macro):
            gated_eff = st["gated"] if pad is None else st["gated"] | pad
            if fx is not None:
                if "failed" in st:
                    gated_eff = gated_eff | st["failed"]
                if fx.onset[k].any():
                    q0 = (st["pq_s"], st["pq_n"], st["pq_out"],
                          st["d_batch"], st["d_tokens"], st["d_pend"],
                          st["d_pend_tok"], st["cpu_backlog"])
                    q0, retried = _redistribute_queues(
                        xp, q0, fx.onset[k], fx.up_frac[k], P)
                    (st["pq_s"], st["pq_n"], st["pq_out"], st["d_batch"],
                     st["d_tokens"], st["d_pend"], st["d_pend_tok"],
                     st["cpu_backlog"]) = q0
                    st["retried"] = st["retried"] + retried
            f, sp, spd_t, sm, active_n = _derived(
                xp, sh, self.f0, st["dvth"], gated_eff,
                self.params.headroom)
            if fx is not None:
                # capacity columns: stalls scale speed, crashes scale
                # the live core count
                sm = sm * fx.cap_mult[k]
                active_n = active_n * fx.up_frac[k]
                # a machine with no live cores has zero capacity (via
                # active_n) but must keep a finite nominal speed for the
                # 1/speed bookkeeping terms
                f_all = f.mean(axis=1) if pad is None \
                    else (f * ~pad).sum(axis=1) / n_vec
                sm = xp.where(active_n > 0, sm, f_all)
                sp, spd_t = sm[:P], sm[P:]
            dyn = (sp, spd_t, sm, active_n)
            q = (st["pq_s"], st["pq_n"], st["pq_out"], st["d_batch"],
                 st["d_tokens"], st["d_pend"], st["d_pend_tok"],
                 st["cpu_backlog"])
            for j in range(spp):
                step = k * spp + j
                t = step * sh.dt_s
                q, obs = _micro_step(xp, sh, dyn, q, arr[step])
                u, ov, done = obs["u"], obs["ov"], obs["done"]
                # streaming window aggregates (in place: bounded memory)
                w = min(int(t / sh.mwin_s), sh.n_mwin - 1)
                st["mw_cnt"][w] += 1.0
                st["mw_wait"][w] += obs["wait_p"]
                st["mw_iter"][w] += obs["iter_mean"]
                st["mw_cpuw"][w] += obs["cpu_wait"]
                st["mw_sp"][w] += obs["sp_mean"]
                st["mw_st"][w] += obs["st_mean"]
                st["mw_comps"][w] += obs["comps"]
                pw = min(int(t / sh.pwin_s), sh.n_pwin - 1)
                busy_cs = done / sm
                st["res_busy"][:, pw] += busy_cs
                st["res_idle"][:, pw] += active_n * sh.dt_s - busy_cs
                st["res_gated"][:, pw] += (n_vec - active_n) * sh.dt_s
                st["res_fbusy"][:, pw] += done
                tasks = u + ov
                st["task_sum"] += tasks.sum()
                st["task_cnt"] += tasks.size
                st["task_max"] = np.maximum(st["task_max"], tasks.max())
                st["completions"] += obs["comps"]
                # spread busy time evenly over this period's active set
                st["busy_s"] += np.where(
                    gated_eff, 0.0,
                    (busy_cs / np.maximum(active_n, 1.0))[:, None])
            (st["pq_s"], st["pq_n"], st["pq_out"], st["d_batch"],
             st["d_tokens"], st["d_pend"], st["d_pend_tok"],
             st["cpu_backlog"]) = q
            st["u_last"], st["ov_last"] = u, ov

            # macro boundary: settle aging, sample, gate (same order as
            # the event engine's periodic tick).
            st["dvth"] = _settle_aging(sh, st["dvth"], gated_eff,
                                       st["busy_s"], self._advance_numpy)
            st["busy_s"][:] = 0.0
            if fx is not None and fx.guard is not None:
                margin, hazard = fx.guard
                over = (st["dvth"] / self.params.headroom
                        - margin) / margin
                haz = hazard * fx.period * np.maximum(over, 0.0)
                st["cum_haz"] = st["cum_haz"] + np.where(
                    st["failed"], 0.0, haz)
                cand = (st["cum_haz"] >= fx.thresh) & ~st["failed"]
                allowed = np.maximum(
                    fx.max_failed_n - st["failed"].sum(axis=1), 0.0)
                key = np.where(cand, st["cum_haz"] - fx.thresh, -np.inf)
                rank = np.argsort(np.argsort(-key, axis=1), axis=1)
                st["failed"] = st["failed"] | (
                    cand & (rank < allowed[:, None]))
                st["lost_core_s"] = (st["lost_core_s"]
                                     + st["failed"].sum() * fx.period)
            idle_norm = (active_n - u - ov) / n_vec
            bins = np.clip(((idle_norm + 1.0) * 0.5
                            * (_IDLE_BINS - 1)).astype(np.int64),
                           0, _IDLE_BINS - 1)
            st["idle_hist"] += np.bincount(bins, minlength=_IDLE_BINS)
            if sh.gating:
                t_now = (k + 1) * spp * sh.dt_s
                g_now = g_fn(t_now) if g_fn else 0.0
                carbon = self._carbon_gate[1] if self._carbon_gate else None
                corr = _gate_correction(xp, sh, active_n, u, ov, g_now,
                                        carbon, n_vec=self._n_vec)
                # padded lanes behave like permanently failed cores:
                # never gateable, never wakeable
                fail_eff = st.get("failed")
                if pad is not None:
                    fail_eff = pad if fail_eff is None \
                        else fail_eff | pad
                st["gated"] = _apply_gating(xp, corr, st["gated"],
                                            np.ceil(np.minimum(u,
                                                               active_n)),
                                            st["dvth"],
                                            failed=fail_eff)
            st["macro"] = np.asarray(k + 1, dtype=np.int64)
            if self.checkpoint_dir and k + 1 >= next_ckpt \
                    and k + 1 < sh.n_macro:
                self._checkpoint(k + 1)
                next_ckpt = self._next_ckpt(k + 1)

    def _next_ckpt(self, macro: int) -> int:
        per = max(1, int(round(self.checkpoint_every_s
                               / self.cfg.idling_period_s)))
        return (macro // per + 1) * per

    # -- jax driver ----------------------------------------------------- #
    def _run_jax(self, arr: np.ndarray, start_macro: int) -> None:
        import jax
        import jax.numpy as jnp
        from repro.kernels.aging_update.ops import advance_fleet

        sh = self.shape
        params = self.params
        use_kernel = (self._use_kernel if self._use_kernel is not None
                      else jax.default_backend() == "tpu")
        f0 = jnp.asarray(self.f0, jnp.float32)
        spp = sh.steps_per_period
        carbon = self._carbon_gate[1] if self._carbon_gate else None
        # ragged-fleet constants (static Python branches below — the
        # uniform trace is byte-identical to the pre-hardware engine)
        pad = None if self._pad is None else jnp.asarray(self._pad)
        n_vec = (sh.num_cores if pad is None
                 else jnp.asarray(self._n_vec, jnp.float32))
        # Fault columns (constants of the run; the guardband threshold
        # crossing is the only dynamic part and lives in the carry).
        fx = self._faults
        guard_on = fx is not None and fx.guard is not None
        thresh_j = jnp.asarray(fx.thresh, jnp.float32) if guard_on else None
        if self._carbon_gate:
            t_macro = (np.arange(sh.n_macro) + 1) * spp * sh.dt_s
            g_arr = np.array([self._carbon_gate[0].g_per_kwh(t)
                              for t in t_macro], dtype=np.float32)
        else:
            g_arr = np.zeros(sh.n_macro, dtype=np.float32)

        def advance(dvth, gated, tau, temp_c):
            flat = dvth.reshape(-1)
            stress = jnp.where(gated, 0.0, 1.0).reshape(-1)
            tau_f = jnp.broadcast_to(tau, dvth.shape).reshape(-1)
            temp = jnp.full_like(flat, temp_c)
            out = advance_fleet(flat, temp, stress, tau_f, params,
                                use_kernel=use_kernel)
            return out.reshape(dvth.shape)

        def micro_body(carry, xs):
            q, acc, dyn, gated = carry
            arr_row, t = xs
            q, obs = _micro_step(jnp, sh, dyn, q, arr_row)
            sp, st_, sm, active_n = dyn
            u, ov, done = obs["u"], obs["ov"], obs["done"]
            w = jnp.minimum((t / sh.mwin_s).astype(jnp.int32),
                            sh.n_mwin - 1)
            pw = jnp.minimum((t / sh.pwin_s).astype(jnp.int32),
                             sh.n_pwin - 1)
            busy_cs = done / sm
            tasks = u + ov
            acc = dict(acc)
            acc["mw"] = acc["mw"].at[:, w].add(jnp.stack([
                1.0, obs["wait_p"], obs["iter_mean"], obs["cpu_wait"],
                obs["sp_mean"], obs["st_mean"], obs["comps"]]))
            acc["res"] = acc["res"].at[:, :, pw].add(jnp.stack([
                busy_cs, active_n * sh.dt_s - busy_cs,
                (n_vec - active_n) * sh.dt_s, done], axis=0))
            acc["task_sum"] = acc["task_sum"] + tasks.sum()
            acc["task_cnt"] = acc["task_cnt"] + tasks.size
            acc["task_max"] = jnp.maximum(acc["task_max"], tasks.max())
            acc["completions"] = acc["completions"] + obs["comps"]
            acc["busy_s"] = acc["busy_s"] + jnp.where(
                gated, 0.0, (busy_cs / jnp.maximum(active_n, 1.0))[:, None])
            return (q, acc, dyn, gated), (u, ov)

        def macro_body(carry, xs):
            st = carry
            if fx is not None:
                arr_rows, ts, g_now, up_row, onset_row, mult_row = xs
            else:
                arr_rows, ts, g_now = xs
            gated_eff = (st["gated"] | st["failed"]) if guard_on \
                else st["gated"]
            if pad is not None:
                gated_eff = gated_eff | pad
            f = f0 * (1.0 - st["dvth"] / params.headroom)
            active = ~gated_eff
            active_n = jnp.sum(active, axis=1).astype(jnp.float32)
            sm = (jnp.sum(jnp.where(active, f, 0.0), axis=1)
                  / jnp.maximum(active_n, 1.0))
            if fx is not None:
                sm = sm * mult_row
                active_n = active_n * up_row
                f_all = jnp.mean(f, axis=1) if pad is None \
                    else jnp.sum(f * ~pad, axis=1) / n_vec
                sm = jnp.where(active_n > 0, sm, f_all)
            dyn = (sm[:sh.n_prompt], sm[sh.n_prompt:], sm, active_n)
            q = (st["pq_s"], st["pq_n"], st["pq_out"], st["d_batch"],
                 st["d_tokens"], st["d_pend"], st["d_pend_tok"],
                 st["cpu_backlog"])
            if fx is not None:
                q, retried = _redistribute_queues(jnp, q, onset_row,
                                                  up_row, sh.n_prompt)
            acc = {k2: st[k2] for k2 in
                   ("mw", "res", "task_sum", "task_cnt", "task_max",
                    "completions", "busy_s")}
            (q, acc, _, _), (us, ovs) = jax.lax.scan(
                micro_body, (q, acc, dyn, gated_eff), (arr_rows, ts))
            u, ov = us[-1], ovs[-1]
            dvth = _settle_aging(sh, st["dvth"], gated_eff,
                                 acc["busy_s"], advance)
            failed = st.get("failed")
            if guard_on:
                margin, hazard = fx.guard
                over = (dvth / params.headroom - margin) / margin
                haz = hazard * fx.period * jnp.maximum(over, 0.0)
                cum = st["cum_haz"] + jnp.where(failed, 0.0, haz)
                cand = (cum >= thresh_j) & ~failed
                allowed = jnp.maximum(
                    fx.max_failed_n
                    - jnp.sum(failed, axis=1).astype(jnp.float32), 0.0)
                key = jnp.where(cand, cum - thresh_j, -jnp.inf)
                rank = jnp.argsort(jnp.argsort(-key, axis=1), axis=1)
                failed = failed | (cand & (rank < allowed[:, None]))
            idle_norm = (active_n - u - ov) / n_vec
            bins = jnp.clip(((idle_norm + 1.0) * 0.5
                             * (_IDLE_BINS - 1)).astype(jnp.int32),
                            0, _IDLE_BINS - 1)
            idle_hist = st["idle_hist"].at[bins].add(1)
            gated = st["gated"]
            if sh.gating:
                corr = _gate_correction(jnp, sh, active_n, u, ov, g_now,
                                        carbon,
                                        n_vec=None if pad is None
                                        else n_vec)
                fail_eff = failed
                if pad is not None:
                    fail_eff = pad if fail_eff is None \
                        else fail_eff | pad
                gated = _apply_gating(
                    jnp, corr, gated,
                    jnp.ceil(jnp.minimum(u, active_n)), dvth,
                    failed=fail_eff)
            st = dict(st)
            st.update(acc)
            (st["pq_s"], st["pq_n"], st["pq_out"], st["d_batch"],
             st["d_tokens"], st["d_pend"], st["d_pend_tok"],
             st["cpu_backlog"]) = q
            st["busy_s"] = jnp.zeros_like(acc["busy_s"])
            st["dvth"] = dvth
            st["gated"] = gated
            st["idle_hist"] = idle_hist
            st["u_last"], st["ov_last"] = u, ov
            if fx is not None:
                st["retried"] = st["retried"] + retried
                if guard_on:
                    st["failed"] = failed
                    st["cum_haz"] = cum
                    st["lost_core_s"] = (
                        st["lost_core_s"]
                        + jnp.sum(failed).astype(jnp.float32) * fx.period)
            return st, None

        # pack numpy state -> f32 jax pytree (mw/res stacked for cheap
        # scatter adds inside the scan)
        s = self.state
        jst = {k: jnp.asarray(v, jnp.float32)
               for k, v in s.items()
               if k not in ("macro", "idle_hist", "gated", "failed",
                            "mw_cnt", "mw_wait", "mw_iter", "mw_cpuw",
                            "mw_sp", "mw_st", "mw_comps", "res_busy",
                            "res_idle", "res_gated", "res_fbusy")}
        jst["gated"] = jnp.asarray(s["gated"])
        if guard_on:
            jst["failed"] = jnp.asarray(s["failed"])
        jst["idle_hist"] = jnp.asarray(s["idle_hist"], jnp.int32)
        jst["mw"] = jnp.asarray(np.stack([
            s["mw_cnt"], s["mw_wait"], s["mw_iter"], s["mw_cpuw"],
            s["mw_sp"], s["mw_st"], s["mw_comps"]]), jnp.float32)
        jst["res"] = jnp.asarray(np.stack([
            s["res_busy"], s["res_idle"], s["res_gated"],
            s["res_fbusy"]]), jnp.float32)

        n_steps = sh.n_macro * spp
        ts = (np.arange(n_steps) * sh.dt_s).astype(np.float32)
        arr_m = jnp.asarray(arr.reshape(sh.n_macro, spp, 3), jnp.float32)
        ts_m = jnp.asarray(ts.reshape(sh.n_macro, spp))
        g_m = jnp.asarray(g_arr)

        scan = jax.jit(lambda st0, xs: jax.lax.scan(macro_body, st0, xs))
        per = max(1, int(round(self.checkpoint_every_s
                               / self.cfg.idling_period_s)))
        k = start_macro
        while k < sh.n_macro:
            k2 = min(k + per, sh.n_macro) if self.checkpoint_dir \
                else sh.n_macro
            xs = (arr_m[k:k2], ts_m[k:k2], g_m[k:k2])
            if fx is not None:
                xs = xs + (jnp.asarray(fx.up_frac[k:k2], jnp.float32),
                           jnp.asarray(fx.onset[k:k2]),
                           jnp.asarray(fx.cap_mult[k:k2], jnp.float32))
            jst, _ = scan(jst, xs)
            k = k2
            self._unpack_jax(jst, k)
            if self.checkpoint_dir and k < sh.n_macro:
                self._checkpoint(k)

    def _unpack_jax(self, jst, macro: int) -> None:
        s = self.state
        for key in ("dvth", "pq_s", "pq_n", "pq_out", "d_batch",
                    "d_tokens", "d_pend", "d_pend_tok", "cpu_backlog",
                    "busy_s", "u_last", "ov_last", "task_sum",
                    "task_cnt", "task_max", "completions"):
            s[key] = np.asarray(jst[key], dtype=np.float64)
        s["gated"] = np.asarray(jst["gated"])
        s["idle_hist"] = np.asarray(jst["idle_hist"], dtype=np.int64)
        mw = np.asarray(jst["mw"], dtype=np.float64)
        (s["mw_cnt"], s["mw_wait"], s["mw_iter"], s["mw_cpuw"],
         s["mw_sp"], s["mw_st"], s["mw_comps"]) = mw
        res = np.asarray(jst["res"], dtype=np.float64)
        (s["res_busy"], s["res_idle"], s["res_gated"],
         s["res_fbusy"]) = res
        if self._faults is not None:
            for key in ("lost_core_s", "retried", "cum_haz"):
                if key in jst:
                    s[key] = np.asarray(jst[key], dtype=np.float64)
            if "failed" in jst:
                s["failed"] = np.asarray(jst["failed"])
        s["macro"] = np.asarray(macro, dtype=np.int64)

    # ------------------------------------------------------------------ #
    # collection
    # ------------------------------------------------------------------ #
    def _window_means(self):
        st = self.state
        cnt = np.maximum(st["mw_cnt"], 1.0)
        means = {k: st[k] / cnt
                 for k in ("mw_wait", "mw_iter", "mw_cpuw", "mw_sp",
                           "mw_st")}
        # empty windows fall back to the run-wide mean
        have = st["mw_cnt"] > 0
        for k, v in means.items():
            overall = float(v[have].mean()) if have.any() else \
                (1.0 if k in ("mw_sp", "mw_st") else 0.0)
            means[k] = np.where(have, v, overall)
        return means

    def _latency_postpass(self):
        """Per-request latency estimates from the windowed aggregates —
        a transient vectorized pass over the trace (no per-request state
        is held by the engine)."""
        sh = self.shape
        requests = self._requests
        if not requests:
            return float("nan"), float("nan"), 0
        mw = self._window_means()
        t_arr = np.fromiter((r.arrival_s for r in requests), float,
                            count=len(requests))
        n_in = np.fromiter((r.input_tokens for r in requests), float,
                           count=len(requests))
        n_out = np.fromiter((r.output_tokens for r in requests), float,
                            count=len(requests))
        w = np.clip((t_arr / sh.mwin_s).astype(np.int64), 0,
                    sh.n_mwin - 1)
        sp = mw["mw_sp"][w]
        wait = mw["mw_wait"][w] + mw["mw_cpuw"][w]
        prefill = (TASK_DURATIONS_S["submit_task"] / sp
                   + PREFILL_BASE_S + PREFILL_PER_TOKEN_S * n_in)
        w2 = np.clip(((t_arr + wait + prefill) / sh.mwin_s)
                     .astype(np.int64), 0, sh.n_mwin - 1)
        itp = mw["mw_iter"][w2]
        lat = (_LAT_CPU_PROMPT / sp + wait + prefill
               + n_in * _KV_S_PER_TOKEN
               + _W_TOKEN_ARRIVAL / mw["mw_st"][w2]
               + 0.5 * itp + n_out * itp)
        done = t_arr + lat <= sh.duration_s
        if not done.any():
            return float("nan"), float("nan"), 0
        lat_done = lat[done]
        return (float(lat_done.mean()),
                float(np.percentile(lat_done, 99)),
                int(done.sum()))

    def _idle_percentiles(self):
        hist = self.state["idle_hist"].astype(np.float64)
        total = hist.sum()
        if total <= 0:
            zeros = {p: 0.0 for p in metrics_mod.PERCENTILES}
            return zeros, 0.0
        edges = np.linspace(-1.0, 1.0, _IDLE_BINS + 1)
        cdf = np.cumsum(hist) / total
        pcts = {}
        for p in metrics_mod.PERCENTILES:
            i = int(np.searchsorted(cdf, p / 100.0))
            i = min(i, _IDLE_BINS - 1)
            c0 = cdf[i - 1] if i > 0 else 0.0
            span = cdf[i] - c0
            frac = ((p / 100.0 - c0) / span) if span > 0 else 0.5
            pcts[p] = float(edges[i] + frac * (edges[i + 1] - edges[i]))
        below = float(hist[:int((1.0 - 0.1) * 0.5
                                * (_IDLE_BINS - 1))].sum() / total)
        return pcts, below

    def residencies(self) -> tuple[StateResidency, ...]:
        sh, st = self.shape, self.state
        out = []
        for m in range(sh.n_machines):
            out.append(StateResidency(
                num_cores=(sh.num_cores if sh.core_counts is None
                           else sh.core_counts[m]),
                duration_s=sh.duration_s,
                busy_core_s=float(st["res_busy"][m].sum()),
                idle_core_s=float(st["res_idle"][m].sum()),
                gated_core_s=float(st["res_gated"][m].sum()),
                freq_busy_core_s=float(st["res_fbusy"][m].sum()),
                window_s=sh.pwin_s,
                window_busy_s=tuple(st["res_busy"][m]),
                window_idle_s=tuple(st["res_idle"][m]),
                window_gated_s=tuple(st["res_gated"][m]),
            ))
        return tuple(out)

    def collect(self, carbon_model=None, power_model=None,
                telemetry=None) -> ExperimentResult:
        sh, st = self.shape, self.state
        f = self.f0 * (1.0 - st["dvth"] / self.params.headroom)
        if self._pad is None:
            cvs = f.std(axis=1) / f.mean(axis=1)
            degs = (self.f0 - f).mean(axis=1)
        else:
            # masked per-machine stats: padded lanes carry no silicon
            w = ~self._pad
            n = self._n_vec
            fm = (f * w).sum(axis=1) / n
            var = (((f - fm[:, None]) ** 2) * w).sum(axis=1) / n
            cvs = np.sqrt(var) / fm
            degs = ((self.f0 - f) * w).sum(axis=1) / n
        idle_pcts, below = self._idle_percentiles()
        mean_lat, p99_lat, completed = self._latency_postpass()
        task_cnt = max(float(st["task_cnt"]), 1.0)
        robustness = None
        if self._faults is not None:
            robustness = self._faults.robustness(
                st, completed, len(self._requests))
            lost = robustness.pop("_lost_core_s")
            robustness["availability"] = 1.0 - min(
                lost / (sh.total_cores * sh.duration_s), 1.0)
        result = metrics_mod.price_and_build(
            self.cfg,
            cvs=cvs, degs=degs,
            idle_norm_percentiles=idle_pcts,
            oversub_frac_below=below,
            task_count_mean=float(st["task_sum"]) / task_cnt,
            task_count_max=int(round(float(st["task_max"]))),
            mean_latency_s=mean_lat, p99_latency_s=p99_lat,
            completed=completed,
            aging_params=self.params,
            elapsed=sh.duration_s,
            residencies=self.residencies(),
            engine="fleet",
            robustness=robustness,
            carbon_model=carbon_model, power_model=power_model,
            telemetry=telemetry,
            fleet_inventory=self.inventory,
        )
        if telemetry is not None:
            self._emit_telemetry(telemetry)
        return result

    def _emit_telemetry(self, hub) -> None:
        """Windowed fleet aggregates into the hub's streaming series —
        ring-buffered, so any horizon stays bounded."""
        sh, st = self.shape, self.state
        mw = self._window_means()
        have = np.flatnonzero(st["mw_cnt"] > 0)
        tl = hub.timeline("fleet/windows", maxlen=max(len(have), 1))
        for i in have:
            t = float(i * sh.mwin_s)
            tl.record(t, (float(mw["mw_wait"][i]),
                          float(mw["mw_iter"][i]),
                          float(mw["mw_cpuw"][i]),
                          float(st["mw_comps"][i])))
        hub.set_gauge("fleet/completions", float(st["completions"]))
        hub.set_gauge("fleet/gated_cores_final",
                      float(self.state["gated"].sum()))


# ---------------------------------------------------------------------- #
# runner entry point
# ---------------------------------------------------------------------- #
def run_fleet_experiment(cfg: ExperimentConfig, *, telemetry=None,
                         carbon_model=None, power_model=None,
                         scenario=None,
                         requests=None) -> ExperimentResult:
    """Generate the trace, run the fleet engine, collect the result.
    Mirrors `run_experiment`'s event path; `requests` short-circuits
    trace generation when the caller already has it."""
    if scenario is None:
        from repro.workloads import get_scenario
        scenario = get_scenario(cfg.scenario, **cfg.scenario_options)
    if requests is None:
        requests = scenario.generate(rate_rps=cfg.rate_rps,
                                     duration_s=cfg.duration_s,
                                     seed=cfg.seed)
    engine = FleetEngine(cfg, telemetry=telemetry)
    if telemetry is not None:
        telemetry.event("engine", 0.0, engine="fleet",
                        backend=engine.backend)
    engine.run(requests)
    return engine.collect(carbon_model=carbon_model,
                          power_model=power_model, telemetry=telemetry)


__all__: list[str] = ["FleetEngine", "run_fleet_experiment"]
