"""Fleet-batched NBTI aging settlement.

The cluster's periodic tick used to settle each machine's cores through
its own `CoreManager.settle_all` — 22 sequential numpy dispatch chains
per second of simulated time. `FleetAgingSettler` stacks every
machine's per-core state into one `(n_machines, n_cores)` batch and
advances all of it through a single `advance_dvth` call, then scatters
the settled shifts back into the managers.

Backends:

  numpy  — default; bit-identical to calling `settle_all` per machine
           (elementwise float64 math over a stacked array; pinned by
           tests/test_fleetstate.py), so the serial simulation stays
           golden-exact.
  jax    — routes the stacked batch through the fleet-scale Pallas
           kernel (`repro.kernels.aging_update`, float32; interpret
           mode off-TPU). NOT bit-exact with the float64 numpy path —
           for analytics sweeps and kernel-backed scale runs, not for
           golden-pinned experiments.
  auto   — jax when importable, numpy otherwise.

Managers must be homogeneous (same `AgingParams`, same core count) —
exactly what a `Cluster` builds.
"""
from __future__ import annotations

import numpy as np

from repro.core import aging, temperature

_BACKENDS = ("numpy", "jax", "auto")


def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
    except Exception:
        return False
    return True


class FleetAgingSettler:
    """Settles a fleet of `CoreManager`s to a common timestamp in one
    batched dVth advance (the paper's hot loop, fleet-vectorized)."""

    def __init__(self, managers, backend: str = "numpy"):
        if backend not in _BACKENDS:
            raise ValueError(f"unknown fleetstate backend {backend!r}; "
                             f"expected one of {_BACKENDS}")
        managers = list(managers)
        if not managers:
            raise ValueError("FleetAgingSettler needs at least one manager")
        params = managers[0].params
        n = managers[0].num_cores
        for m in managers[1:]:
            if m.params != params or m.num_cores != n:
                raise ValueError(
                    "FleetAgingSettler requires homogeneous managers "
                    "(same AgingParams and num_cores)")
        self.managers = managers
        self.params = params
        self.num_cores = n
        if backend == "auto":
            backend = "jax" if _jax_available() else "numpy"
        self.backend = backend

    # ------------------------------------------------------------------ #
    def _gather(self, now: float):
        """Stack per-machine state into (M, N) regime arrays (regimes
        derived through the same `temperature.regime_arrays` helper the
        per-machine settle path uses, so the two can never drift)."""
        ms = self.managers
        dvth = np.stack([m.dvth for m in ms])
        tau = now - np.stack([m.last_update for m in ms])
        cs = np.stack([m.c_state for m in ms])
        alloc = np.stack([m.task_of_core for m in ms]) >= 0
        temps, stress = temperature.regime_arrays(cs, alloc)
        return dvth, temps, stress, np.maximum(tau, 0.0)

    def _scatter(self, new_dvth: np.ndarray, now: float) -> None:
        for k, m in enumerate(self.managers):
            m.dvth[:] = new_dvth[k]
            np.maximum(m.last_update, now, out=m.last_update)
            if now > m.now:
                m.now = now

    # ------------------------------------------------------------------ #
    def settle(self, now: float) -> None:
        """Advance every machine's every core to `now` under its current
        regime. Equivalent to `for m in managers: m.settle_all(now)`
        (bit-identical on the numpy backend), one batched call."""
        dvth, temps, stress, tau = self._gather(now)
        if not (tau > 0.0).any():
            for m in self.managers:
                if now > m.now:
                    m.now = now
            return
        if self.backend == "jax":
            new = self._advance_jax(dvth, temps, stress, tau)
        else:
            adf_vals = aging.adf(self.params, temps, stress)
            new = aging.advance_dvth(self.params, dvth, adf_vals, tau)
        self._scatter(new, now)

    def _advance_jax(self, dvth, temps, stress, tau) -> np.ndarray:
        """Flatten the (M, N) batch through the Pallas fleet kernel
        (float32; the kernel pads to its 128-lane block size)."""
        from repro.kernels.aging_update.ops import advance_fleet

        shape = dvth.shape
        out = advance_fleet(dvth.ravel(), temps.ravel(), stress.ravel(),
                            tau.ravel(), self.params)
        return np.asarray(out, dtype=np.float64).reshape(shape)


class GroupedAgingSettler:
    """Heterogeneous-fleet settler: groups managers by `(AgingParams,
    num_cores)` and runs one `FleetAgingSettler` per homogeneous group.

    Mixed fleets (`repro.hardware`) build machines with per-SKU core
    counts and aging parameters, so one stacked batch no longer fits;
    each group still advances through a single batched call, and every
    group is bit-identical to its machines settling individually.
    """

    def __init__(self, managers, backend: str = "numpy"):
        managers = list(managers)
        if not managers:
            raise ValueError("GroupedAgingSettler needs at least one "
                             "manager")
        groups: dict[tuple, list] = {}
        for m in managers:
            groups.setdefault((m.params, m.num_cores), []).append(m)
        self.settlers = [FleetAgingSettler(g, backend=backend)
                         for g in groups.values()]
        self.managers = managers
        # all groups resolve "auto" identically; surface the first
        self.backend = self.settlers[0].backend

    def settle(self, now: float) -> None:
        for s in self.settlers:
            s.settle(now)


def settle_fleet(managers, now: float, backend: str = "numpy") -> None:
    """One-shot convenience wrapper around `FleetAgingSettler`."""
    FleetAgingSettler(managers, backend=backend).settle(now)
