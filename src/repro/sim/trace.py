"""Azure-production-like LLM inference trace synthesis (paper §6.1.2).

The paper replays Microsoft's published Azure LLM inference traces, which
characterize each request by (arrival time, input tokens, output tokens).
Those traces are not shipped offline, so we synthesize statistically
matching traces using the published Splitwise [26] characterization of the
Azure *conversation* workload: heavy-tailed token counts with
median input ~1020 / mean ~1155, and mean output ~211 tokens, Poisson
arrivals at a configurable cluster request rate. Deterministic per seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    arrival_s: float
    input_tokens: int
    output_tokens: int


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    rate_rps: float = 60.0          # cluster-wide request rate
    duration_s: float = 120.0
    # lognormal fits to the Splitwise Azure-conversation characterization
    input_logmean: float = 6.93     # median ~1020 tokens
    input_logstd: float = 0.85
    input_max: int = 8192
    output_logmean: float = 4.92    # mean ~210 tokens
    output_logstd: float = 0.95
    output_max: int = 2048
    seed: int = 0


def generate(cfg: TraceConfig) -> list[Request]:
    rng = np.random.default_rng(cfg.seed)
    requests: list[Request] = []
    t = 0.0
    rid = 0
    while True:
        t += rng.exponential(1.0 / cfg.rate_rps)
        if t >= cfg.duration_s:
            break
        n_in = int(np.clip(rng.lognormal(cfg.input_logmean, cfg.input_logstd),
                           8, cfg.input_max))
        n_out = int(np.clip(rng.lognormal(cfg.output_logmean, cfg.output_logstd),
                            1, cfg.output_max))
        requests.append(Request(rid, t, n_in, n_out))
        rid += 1
    return requests


def trace_stats(requests: list[Request]) -> dict:
    n_in = np.array([r.input_tokens for r in requests])
    n_out = np.array([r.output_tokens for r in requests])
    return {
        "n_requests": len(requests),
        "input_median": float(np.median(n_in)),
        "input_mean": float(n_in.mean()),
        "output_mean": float(n_out.mean()),
        "output_median": float(np.median(n_out)),
    }
