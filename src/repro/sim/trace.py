"""Deprecated shim over `repro.workloads` (PR 2).

The single synthetic Azure-conversation generator that used to live here
is now the `conversation-poisson` scenario in the pluggable
`repro.workloads` subsystem, which adds diurnal/bursty/flash-crowd
arrival processes, code/long-context/blended token mixes, and Azure-CSV
trace ingestion & replay. New code should do:

    from repro.workloads import get_scenario
    trace = get_scenario("conversation-poisson").generate(
        rate_rps=60.0, duration_s=120.0, seed=0)

`TraceConfig` / `generate` keep working (bit-exactly — same RNG draw
sequence) by resolving to that scenario, and will be removed once
nothing imports them.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.workloads import Request, request_stats
from repro.workloads.arrivals import PoissonArrivals
from repro.workloads.mixes import LognormalMix
from repro.workloads.scenario import Scenario

__all__ = ["Request", "TraceConfig", "generate", "trace_stats"]


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Deprecated: parameters of the old built-in conversation trace.

    Equivalent to `ExperimentConfig(scenario="conversation-poisson")`
    with a custom `LognormalMix` when the token fits are overridden.
    """

    rate_rps: float = 60.0          # cluster-wide request rate
    duration_s: float = 120.0
    # lognormal fits to the Splitwise Azure-conversation characterization
    input_logmean: float = 6.93     # median ~1020 tokens
    input_logstd: float = 0.85
    input_max: int = 8192
    output_logmean: float = 4.92    # mean ~210 tokens
    output_logstd: float = 0.95
    output_max: int = 2048
    seed: int = 0

    def as_scenario(self) -> Scenario:
        """The workloads-subsystem scenario this config resolves to."""
        mix = LognormalMix(
            input_logmean=self.input_logmean,
            input_logstd=self.input_logstd,
            output_logmean=self.output_logmean,
            output_logstd=self.output_logstd,
            input_max=self.input_max, output_max=self.output_max)
        return Scenario("conversation-poisson", mix,
                        lambda rate, dur: PoissonArrivals(rate))


def generate(cfg: TraceConfig) -> list[Request]:
    warnings.warn(
        "sim.trace.generate(TraceConfig) is deprecated; use "
        "repro.workloads.get_scenario('conversation-poisson').generate()",
        DeprecationWarning, stacklevel=2)
    return cfg.as_scenario().generate(rate_rps=cfg.rate_rps,
                                      duration_s=cfg.duration_s,
                                      seed=cfg.seed)


_LEGACY_STAT_KEYS = ("n_requests", "input_median", "input_mean",
                     "output_mean", "output_median")


def trace_stats(requests: list[Request]) -> dict:
    """Deprecated alias of `repro.workloads.request_stats` (which also
    handles empty streams without NaN). Returns the legacy key set."""
    warnings.warn(
        "sim.trace.trace_stats is deprecated; use "
        "repro.workloads.request_stats", DeprecationWarning, stacklevel=2)
    stats = request_stats(requests)
    return {k: stats[k] for k in _LEGACY_STAT_KEYS}
