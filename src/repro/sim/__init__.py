"""Event-driven LLM inference cluster simulator (extended splitwise-sim).

Workloads come from the pluggable `repro.workloads` scenario registry
(`Request` is re-exported here for convenience); carbon accounting from
the pluggable `repro.carbon` model registry. Results are frozen,
serializable `ExperimentResult`s; sweeps return a `SweepResult` with
`save`/`load`/`to_rows`. The deprecated `TraceConfig` / `generate` /
`trace_stats` shims were removed — use
`repro.workloads.get_scenario(...)` / `request_stats`.
"""
from repro.sim.cluster import Cluster, Machine, PromptInstance, TokenInstance
from repro.sim.config import ExperimentConfig
from repro.sim.events import EventQueue
from repro.sim.fleetstate import FleetAgingSettler, settle_fleet
from repro.sim.metrics import PERCENTILES, carbon_comparison, collect
from repro.sim.results import (ExperimentResult, Provenance, SweepResult)
from repro.sim.routing import (ClusterRouter, FleetView, MachineAging,
                               available_routers, canonical_router_name,
                               get_router, register_router)
from repro.sim.runner import (DEFAULT_SWEEP, run_experiment,
                              run_policy_sweep)
from repro.sim.tasks import CPUTask, TASK_DURATIONS_S, TaskIdAllocator
from repro.workloads import Request

#: historical alias — `ExperimentMetrics` became the frozen,
#: serializable `ExperimentResult` (same field names).
ExperimentMetrics = ExperimentResult

__all__ = [
    "Cluster", "Machine", "PromptInstance", "TokenInstance", "EventQueue",
    "ExperimentConfig", "ExperimentMetrics", "ExperimentResult",
    "Provenance", "SweepResult", "FleetAgingSettler", "settle_fleet",
    "PERCENTILES", "carbon_comparison", "collect",
    "ClusterRouter", "FleetView", "MachineAging", "available_routers",
    "canonical_router_name", "get_router", "register_router",
    "DEFAULT_SWEEP", "run_experiment", "run_policy_sweep", "CPUTask",
    "TASK_DURATIONS_S", "TaskIdAllocator", "Request",
]
