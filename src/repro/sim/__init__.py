"""Event-driven LLM inference cluster simulator (extended splitwise-sim).

Workloads come from the pluggable `repro.workloads` scenario registry;
`Request` is re-exported here for convenience, and `TraceConfig` /
`generate` / `trace_stats` survive as deprecated shims over it.
"""
from repro.sim.cluster import Cluster, Machine, PromptInstance, TokenInstance
from repro.sim.config import ExperimentConfig
from repro.sim.events import EventQueue
from repro.sim.fleetstate import FleetAgingSettler, settle_fleet
from repro.sim.metrics import ExperimentMetrics, carbon_comparison, collect
from repro.sim.routing import (ClusterRouter, FleetView, MachineAging,
                               available_routers, canonical_router_name,
                               get_router, register_router)
from repro.sim.runner import (DEFAULT_SWEEP, run_experiment,
                              run_policy_sweep)
from repro.sim.tasks import CPUTask, TASK_DURATIONS_S, TaskIdAllocator
from repro.sim.trace import Request, TraceConfig, generate, trace_stats

__all__ = [
    "Cluster", "Machine", "PromptInstance", "TokenInstance", "EventQueue",
    "ExperimentConfig", "ExperimentMetrics", "FleetAgingSettler",
    "settle_fleet", "carbon_comparison", "collect",
    "ClusterRouter", "FleetView", "MachineAging", "available_routers",
    "canonical_router_name", "get_router", "register_router",
    "DEFAULT_SWEEP", "run_experiment", "run_policy_sweep", "CPUTask",
    "TASK_DURATIONS_S", "TaskIdAllocator", "Request", "TraceConfig",
    "generate", "trace_stats",
]
