"""Serving engine (continuous batching + aging-aware host CPU)."""
