"""Continuous-batching inference engine with aging-aware host-CPU core
management — the paper's technique as a first-class serving feature.

The engine owns a fixed pool of batch slots backed by one device-resident
KV cache (per-slot positions), performs ORCA-style iteration-level
scheduling, and routes every host-side operation through a `CoreManager`
(Table-2 task taxonomy): request submission -> `submit`, slot allocation
-> `alloc_memory`, each batched decode iteration -> `start_iteration`,
completion -> `finish_request`/`free_memory`. The manager's Selective
Core Idling runs on a wall-clock period, so an idle engine deep-idles its
host cores (age-halting) and a bursty one wakes them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoreManager, CorePolicy
from repro.models import Model
from repro.sim.tasks import TaskIdAllocator


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: list[int]
    max_new_tokens: int
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class InferenceEngine:
    def __init__(self, model: Model, params, max_batch: int = 8,
                 max_len: int = 256,
                 policy: CorePolicy | str = "proposed",
                 num_host_cores: int = 16,
                 eos_id: int | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 greedy: bool = True, temperature: float = 1.0,
                 sample_seed: int = 0, telemetry=None):
        cfg = model.cfg
        if cfg.family in ("hybrid", "audio") or cfg.is_encdec:
            raise NotImplementedError(
                "engine batching supports decoder-only families "
                "(dense/moe/vlm/ssm); use Model.decode_step directly")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.temperature = temperature
        self._sample_key = jax.random.key(sample_seed)
        self.clock = clock
        self._t0 = clock()
        # Live serving shares the simulator's telemetry surface: the same
        # hub type, the same probes, exported via `prometheus_text()` —
        # first step toward running the simulator as a digital twin.
        self.telemetry = telemetry
        self.core_manager = CoreManager(num_host_cores, policy=policy,
                                        rng=np.random.default_rng(0),
                                        telemetry=telemetry)
        self._task_ids = TaskIdAllocator()   # per-engine CPU-task id stream
        self._last_idle_check = 0.0

        self.slots: list[Request | None] = [None] * max_batch
        self.pending: list[Request] = []
        self.cache = self._empty_cache()
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.active_mask = np.zeros(max_batch, bool)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn, static_argnums=(2,))
        self._next_id = 0

    # ------------------------- device functions ------------------------ #
    def _empty_cache(self):
        cfg = self.model.cfg
        b, s = self.max_batch, self.max_len

        def fn(p, t):
            _, cache = self.model.prefill(p, t, None, max_len=s)
            return cache
        abstract = jax.eval_shape(
            fn, self.model.abstract_params(),
            jax.ShapeDtypeStruct((b, 1), jnp.int32))
        cache = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), abstract)
        cache["pos"] = jnp.zeros((b,), jnp.int32)
        return cache

    def _prefill_fn(self, params, tokens, max_len):
        return self.model.prefill(params, tokens, None, max_len=max_len)

    def _decode_fn(self, params, cache, tokens, active):
        logits, new_cache = self.model.decode_step(params, cache, tokens)
        # inactive slots must not advance their position
        new_cache["pos"] = jnp.where(active, new_cache["pos"], cache["pos"])
        return logits, new_cache

    # ----------------------------- host API ---------------------------- #
    def _now(self) -> float:
        return self.clock() - self._t0

    def _cpu_task(self, name: str) -> None:
        """Account one Table-2 host task against the core manager."""
        task = self._task_ids.new(name)
        t = self._now()
        self.core_manager.assign(task.task_id, t)
        self.core_manager.release(task.task_id, t + task.duration_s)

    def _periodic(self) -> None:
        t = self._now()
        if t - self._last_idle_check >= self.core_manager.idling_period_s:
            self.core_manager.periodic(t)
            self._last_idle_check = t

    def submit(self, prompt: list[int], max_new_tokens: int = 32) -> int:
        self._cpu_task("submit")
        req = Request(self._next_id, list(prompt), max_new_tokens)
        self._next_id += 1
        self.pending.append(req)
        return req.req_id

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            self._cpu_task("alloc_memory")
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pcache = self._prefill(self.params, toks, self.max_len)
            # splice the single-row prefill cache into slot i
            def splice(big, small):
                if small.ndim == 0:
                    return big
                return big.at[:, i].set(small[:, 0])
            new_cache = {}
            for key in self.cache:
                if key == "pos":
                    new_cache[key] = self.cache[key].at[i].set(len(req.prompt))
                else:
                    new_cache[key] = jax.tree.map(
                        splice, self.cache[key], pcache[key])
            self.cache = new_cache
            first = self._select_token(logits[:, -1])
            req.output.append(int(first[0]))
            self.tokens = self.tokens.at[i, 0].set(first[0])
            self.slots[i] = req
            self.active_mask[i] = True

    def _select_token(self, logits_row: jax.Array) -> jax.Array:
        v = self.model.cfg.vocab_size
        logits_row = logits_row[..., :v]
        if self.greedy:
            return jnp.argmax(logits_row, -1).astype(jnp.int32)
        self._sample_key, sub = jax.random.split(self._sample_key)
        return jax.random.categorical(
            sub, logits_row / self.temperature, -1).astype(jnp.int32)

    def step(self) -> list[tuple[int, int]]:
        """One engine iteration: admit pending, batched decode, retire
        finished. Returns [(req_id, new_token), ...]."""
        self._periodic()
        self._admit()
        if not self.active_mask.any():
            return []
        self._cpu_task("start_iteration")
        active = jnp.asarray(self.active_mask)
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens, active)
        new_tokens = self._select_token(logits[:, 0])
        out = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(new_tokens[i])
            req.output.append(tok)
            out.append((req.req_id, tok))
            self.tokens = self.tokens.at[i, 0].set(tok)
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if len(req.output) >= req.max_new_tokens or hit_eos:
                req.done = True
                self._cpu_task("finish_request")
                self._cpu_task("free_memory")
                self.slots[i] = None
                self.active_mask[i] = False
        return out

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.pending and not self.active_mask.any():
                return
            self.step()
        raise RuntimeError("engine did not drain")

    # -------------------------- observability -------------------------- #
    def host_cpu_report(self) -> dict:
        m = self.core_manager
        return {
            "policy": m.policy_name,
            "frequencies": m.frequencies(self._now()).tolist(),
            "cv": m.frequency_cv(),
            "mean_degradation": m.mean_frequency_degradation(),
            "active_cores": int((m.c_state == 0).sum()),
            "assigns": m.metrics.assigns,
        }

    def prometheus_text(self) -> str:
        """Prometheus-style text snapshot of the engine's host CPU —
        the telemetry hub's probes (when one is attached) plus live
        aging gauges, one metrics surface shared with the simulator's
        exports (`repro.telemetry.prometheus_text`). Serve it with
        `repro.telemetry.start_metrics_server(engine.prometheus_text)`.
        """
        from repro.telemetry import TelemetryHub, prometheus_text
        hub = self.telemetry if self.telemetry is not None \
            else TelemetryHub()
        m = self.core_manager
        extra = {
            "host_freq_cv": m.frequency_cv(self._now()),
            "host_mean_degradation": m.mean_frequency_degradation(),
            "host_active_cores": float((m.c_state == 0).sum()),
            "host_assigns": float(m.metrics.assigns),
            "host_oversub_assigns": float(m.metrics.oversub_assigns),
        }
        return prometheus_text(hub, extra_gauges=extra)
