"""Mixtral 8x22B [arXiv:2401.04088]: 8 experts top-2, native SWA 4096."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,     # native (Mixtral inherits Mistral SWA)
    rope_theta=1000000.0,
    citation="arXiv:2401.04088",
)

LONG_CONTEXT = FULL  # native SWA already bounds the decode working set

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    head_dim=32, d_ff=256, num_experts=4, experts_per_token=2,
    sliding_window=64, vocab_size=1000, vocab_pad_mult=128)
