"""Architecture registry: one module per assigned architecture.

Each module defines FULL (the exact assigned config, cited) and SMOKE
(reduced same-family variant: <=2 layers, d_model<=512, <=4 experts) for
CPU smoke tests. Select via get_config(name) / get_smoke_config(name).
"""
from __future__ import annotations

import importlib

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = [
    "granite_moe_3b_a800m",
    "internvl2_2b",
    "mamba2_2p7b",
    "seamless_m4t_large_v2",
    "minicpm3_4b",
    "mixtral_8x22b",
    "zamba2_7b",
    "granite_3_8b",
    "llama3_8b",
    "phi3_medium_14b",
]

# public --arch ids (dashes) -> module names
ALIASES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "internvl2-2b": "internvl2_2b",
    "mamba2-2.7b": "mamba2_2p7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "minicpm3-4b": "minicpm3_4b",
    "mixtral-8x22b": "mixtral_8x22b",
    "zamba2-7b": "zamba2_7b",
    "granite-3-8b": "granite_3_8b",
    "llama3-8b": "llama3_8b",
    "phi3-medium-14b": "phi3_medium_14b",
}


def _module(name: str):
    mod_name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).FULL


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_arch_names() -> list[str]:
    return list(ALIASES.keys())
