"""Zamba2-7B [arXiv:2411.15242]: 81 Mamba2 layers + one SHARED attention
block applied every 6 SSM layers (13 applications + 3 tail SSM layers)."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,          # d_model / num_heads
    d_ff=14336,            # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,       # d_inner = 7168 -> 112 SSD heads
    ssm_expand=2,
    hybrid_period=6,
    citation="arXiv:2411.15242",
)

LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)

SMOKE = dataclasses.replace(
    FULL, num_layers=5, d_model=256, num_heads=4, num_kv_heads=4,
    head_dim=64, d_ff=512, ssm_state=16, ssm_head_dim=32,
    hybrid_period=2, vocab_size=1000, vocab_pad_mult=128)
