"""Phi-3 Medium 14B [arXiv:2404.14219]: dense, RoPE, SwiGLU, GQA kv=10."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=10000.0,
    citation="arXiv:2404.14219",
)

LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=320, num_heads=10, num_kv_heads=2,
    head_dim=32, d_ff=640, vocab_size=1000, vocab_pad_mult=128)
