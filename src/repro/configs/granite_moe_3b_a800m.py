"""IBM Granite-MoE 3B-A800M [hf:ibm-granite/granite-3.0-3b-a800m-base;
pool cites granite-3.0-1b-a400m]: 40 experts, top-8, per-expert d_ff=512.

Note: the pool line lists both "MoE 40e top-8" and "32 experts top-8";
the explicit config fields (40 experts) take precedence — 40e matches the
3b-a800m model card, 32e is the 1b-a400m card the bracket cites.
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base (40e/top-8 per 3b-a800m card)",
)

LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    head_dim=32, d_ff=128, num_experts=4, experts_per_token=2,
    vocab_size=1000, vocab_pad_mult=128)
