"""Llama-3 8B [arXiv:2407.21783]: dense decoder, GQA, 128k vocab."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    citation="arXiv:2407.21783",
)

# long_500k runs only in the sliding-window variant (see DESIGN.md).
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    head_dim=32, d_ff=512, vocab_size=1000, vocab_pad_mult=128)
