"""SeamlessM4T-Large v2 [arXiv:2308.11596]: encoder-decoder, multimodal.
The speech frontend (mel + conv codec) is a STUB: input_specs provides
frame embeddings; we implement the 24L encoder + 24L decoder transformer.
For decode shapes the encoder memory is bounded at 4096 frames (speech
segments are chunked in streaming serving) — see DESIGN.md."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
    frontend_tokens=1024,   # frames for train_4k (seq//4)
    citation="arXiv:2308.11596",
)

LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, encoder_layers=2, d_model=256, num_heads=8,
    num_kv_heads=4, head_dim=32, d_ff=512, frontend_tokens=32,
    vocab_size=1000, vocab_pad_mult=128)
