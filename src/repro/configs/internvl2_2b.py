"""InternVL2-2B [arXiv:2404.16821]: InternViT (STUB frontend) + InternLM2
language backbone. input_specs provides pre-projected patch embeddings."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    frontend_tokens=256,    # 256 patch embeddings per image (ViT stub)
    citation="arXiv:2404.16821",
)

LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    head_dim=32, d_ff=512, frontend_tokens=16, vocab_size=1000,
    vocab_pad_mult=128)
