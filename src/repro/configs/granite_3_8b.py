"""IBM Granite-3 8B [hf:ibm-granite/granite-3.0-2b-base family]: dense GQA."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10000.0,
    citation="hf:ibm-granite/granite-3.0-2b-base",
)

LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
    head_dim=32, d_ff=512, vocab_size=1000, vocab_pad_mult=128)
