"""Mamba2-2.7B [arXiv:2405.21060]: attention-free SSD, state N=128."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    ssm_state=128,
    ssm_head_dim=64,       # d_inner = 5120 -> 80 SSD heads
    ssm_expand=2,
    ssm_conv_width=4,
    citation="arXiv:2405.21060",
)

LONG_CONTEXT = FULL  # O(1) state: long_500k runs natively

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=256, ssm_state=32, ssm_head_dim=32,
    vocab_size=1000, vocab_pad_mult=128)
