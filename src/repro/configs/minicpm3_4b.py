"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: dense with Multi-head Latent
Attention (MLA). kv=40 in the pool table reflects MLA's full per-head K/V
after latent expansion; the cache itself stores the compressed latent."""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    citation="hf:openbmb/MiniCPM3-4B",
)

LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)

SMOKE = dataclasses.replace(
    FULL, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, vocab_size=1000, vocab_pad_mult=128)
