"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Per head h with state (P, N):   (P = head dim, N = ssm state dim)

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t  (outer)  B_t
    y_t = h_t @ C_t + D * x_t

Training/prefill use the chunked SSD algorithm: an intra-chunk quadratic
("attention-like") term plus an inter-chunk recurrence over chunk states
(lax.scan), which is the TPU-friendly formulation (dense MXU matmuls per
chunk, O(L) total).  `ssd_reference` is the naive sequential scan oracle.

Projections are kept *separate* (z, x, B, C, dt) rather than one packed
in_proj — mathematically identical to the reference implementation and
cleaner to shard (x/z on d_inner over the model axis). Documented in
DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm


def segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < tau <= i} a[..., tau].

    a: (..., Q) -> (..., Q, Q), lower-triangular valid (i >= j), -inf above.
    """
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)                     # (..., Q)
    diff = cum[..., :, None] - cum[..., None, :]     # s_i - s_j
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff + a[..., None, :] * 0.0, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b: jax.Array, c: jax.Array, chunk: int,
                h0: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  (B, L, H, P)   inputs (post-conv, post-activation)
    dt: (B, L, H)      positive step sizes (softplus applied by caller)
    a_log: (H,)        A = -exp(a_log)
    b:  (B, L, N)      input gate (single group, broadcast over heads)
    c:  (B, L, N)      output gate
    h0: (B, H, P, N)   initial state (None = zeros)

    Returns (y (B,L,H,P), h_final (B,H,P,N)).
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    if l % chunk:
        # zero-dt padding is exact: alpha = exp(0) = 1, update term = 0,
        # so padded steps neither move the state nor contribute output.
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        y, h_final = ssd_chunked(x, dt, a_log, b, c, chunk, h0)
        return y[:, :l], h_final
    nc = l // chunk
    f32 = jnp.float32

    A = -jnp.exp(a_log.astype(f32))                          # (H,)
    dt = dt.astype(f32)
    xr = x.reshape(bsz, nc, chunk, h, p)
    br = b.reshape(bsz, nc, chunk, n).astype(f32)
    cr = c.reshape(bsz, nc, chunk, n).astype(f32)
    dtr = dt.reshape(bsz, nc, chunk, h)
    a = dtr * A                                              # (B,nc,Q,H) <= 0
    a_hq = jnp.moveaxis(a, -1, -2)                           # (B,nc,H,Q)
    cum = jnp.cumsum(a_hq, axis=-1)                          # s_t

    # ---- intra-chunk (diagonal) term ---------------------------------- #
    L = jnp.exp(segsum(a_hq))                                # (B,nc,H,Q,Q)
    scores = jnp.einsum("bzqn,bzkn->bzqk", cr, br)           # (B,nc,Q,Q)
    g = scores[:, :, None] * L                               # (B,nc,H,Q,Q)
    xdt = xr.astype(f32) * dtr[..., None]                    # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bzhqk,bzkhp->bzqhp", g, xdt)

    # ---- chunk states -------------------------------------------------- #
    t = jnp.exp(cum[..., -1:] - cum)                         # (B,nc,H,Q)
    s_c = jnp.einsum("bzhq,bzqn,bzqhp->bzhpn", t, br, xdt)   # (B,nc,H,P,N)
    decay_chunk = jnp.exp(cum[..., -1])                      # (B,nc,H)

    # ---- inter-chunk recurrence (scan over chunks) --------------------- #
    h_init = (jnp.zeros((bsz, h, p, n), f32) if h0 is None
              else h0.astype(f32))

    def step(carry, inp):
        s_chunk, dec = inp                                   # (B,H,P,N),(B,H)
        new = dec[..., None, None] * carry + s_chunk
        return new, carry                                    # emit h_prev

    s_cs = jnp.moveaxis(s_c, 1, 0)                           # (nc,B,H,P,N)
    decs = jnp.moveaxis(decay_chunk, 1, 0)                   # (nc,B,H)
    h_final, h_prevs = jax.lax.scan(step, h_init, (s_cs, decs))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                    # (B,nc,H,P,N)

    # ---- off-diagonal (state-passing) term ------------------------------ #
    y_off = jnp.einsum("bzqn,bzhq,bzhpn->bzqhp", cr, jnp.exp(cum), h_prevs)

    y = (y_diag + y_off).reshape(bsz, l, h, p).astype(x.dtype)
    return y, h_final.astype(f32)


def ssd_reference(x, dt, a_log, b, c, h0=None):
    """Naive sequential recurrence oracle (fp32). Same signature/shapes."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))
    state = (jnp.zeros((bsz, h, p, n), f32) if h0 is None
             else h0.astype(f32))

    def step(carry, inp):
        xt, dtt, bt, ct = inp                      # (B,H,P),(B,H),(B,N),(B,N)
        alpha = jnp.exp(dtt * A)                   # (B,H)
        upd = (dtt[..., None, None] * xt[..., None]
               * bt[:, None, None, :])             # (B,H,P,N)
        new = alpha[..., None, None] * carry + upd
        yt = jnp.einsum("bhpn,bn->bhp", new, ct)
        return new, yt

    xs = (jnp.moveaxis(x.astype(f32), 1, 0), jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(b.astype(f32), 1, 0), jnp.moveaxis(c.astype(f32), 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_decode_step(state, x, dt, a_log, b, c):
    """One-token recurrent update.

    state (B,H,P,N); x (B,H,P); dt (B,H); b/c (B,N).
    Returns (y (B,H,P), new_state)."""
    f32 = jnp.float32
    A = -jnp.exp(a_log.astype(f32))
    alpha = jnp.exp(dt.astype(f32) * A)
    upd = dt.astype(f32)[..., None, None] * x.astype(f32)[..., None] \
        * b.astype(f32)[:, None, None, :]
    new = alpha[..., None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhpn,bn->bhp", new, c.astype(f32))
    return y.astype(x.dtype), new


# --------------------------------------------------------------------- #
# full Mamba2 block (projections + causal conv + SSD + gated norm)
# --------------------------------------------------------------------- #
def _causal_conv(seq: jax.Array, kernel: jax.Array,
                 prepend: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d. seq (B,L,C), kernel (W,C).
    prepend: (B,W-1,C) history (decode) or None (zero left-pad)."""
    w = kernel.shape[0]
    if prepend is None:
        prepend = jnp.zeros((seq.shape[0], w - 1, seq.shape[2]), seq.dtype)
    full = jnp.concatenate([prepend.astype(seq.dtype), seq], axis=1)
    out = jax.lax.conv_general_dilated(
        full, kernel[:, None, :].astype(seq.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=seq.shape[2])
    return out


def mamba2_projections(h: jax.Array, lp: dict, cfg: ModelConfig):
    """Shared pre-SSD computation. h: (B,L,D) -> (z, xbc, dt)."""
    z = jnp.einsum("bld,de->ble", h, lp["w_z"])            # (B,L,di)
    xin = jnp.einsum("bld,de->ble", h, lp["w_x"])          # (B,L,di)
    bg = jnp.einsum("bld,dn->bln", h, lp["w_b"])           # (B,L,G*N)
    cg = jnp.einsum("bld,dn->bln", h, lp["w_c"])           # (B,L,G*N)
    dt = jnp.einsum("bld,dh->blh", h, lp["w_dt"])          # (B,L,H)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    xbc = jnp.concatenate([xin, bg, cg], axis=-1)
    return z, xbc, dt


def mamba2_block(h: jax.Array, lp: dict, cfg: ModelConfig,
                 use_ref: bool = False) -> jax.Array:
    """Full-sequence Mamba2 block (train/prefill). h: (B,L,D)."""
    bsz, l, _ = h.shape
    di, n = cfg.d_inner, cfg.ssm_state
    nh, p = cfg.ssm_heads, cfg.ssm_head_dim
    resid = h
    hn = rms_norm(h, lp["ln"], cfg.norm_eps)
    z, xbc, dt = mamba2_projections(hn, lp, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, lp["conv"]))
    xin, bg, cg = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xin.reshape(bsz, l, nh, p)
    ssd = ssd_reference if use_ref else ssd_chunked
    kw = {} if use_ref else {"chunk": min(cfg.ssm_chunk, l)}
    y, _ = ssd(xh, dt, lp["a_log"], bg, cg, **kw)
    y = (y + lp["d_skip"][None, None, :, None] * xh).astype(xh.dtype)
    y = y.reshape(bsz, l, di)
    y = rms_norm(y * jax.nn.silu(z), lp["gate_ln"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, lp["w_out"])
    return resid + out


def mamba2_block_decode(h: jax.Array, lp: dict, cache: dict,
                        cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One-token Mamba2 block. h: (B,1,D); cache {conv (B,W-1,C),
    state (B,H,P,N)}."""
    bsz = h.shape[0]
    di, n = cfg.d_inner, cfg.ssm_state
    nh, p = cfg.ssm_heads, cfg.ssm_head_dim
    resid = h
    hn = rms_norm(h, lp["ln"], cfg.norm_eps)
    z, xbc, dt = mamba2_projections(hn, lp, cfg)           # L = 1
    conv_hist = cache["conv"]
    out = jax.nn.silu(_causal_conv(xbc, lp["conv"], prepend=conv_hist))
    new_conv = jnp.concatenate([conv_hist, xbc.astype(conv_hist.dtype)],
                               axis=1)[:, 1:]
    xin, bg, cg = jnp.split(out[:, 0], [di, di + n], axis=-1)
    xh = xin.reshape(bsz, nh, p)
    y, new_state = ssd_decode_step(cache["state"], xh, dt[:, 0],
                                   lp["a_log"], bg, cg)
    y = (y + lp["d_skip"][None, :, None] * xh).astype(xh.dtype)
    y = y.reshape(bsz, 1, di)
    y = rms_norm(y * jax.nn.silu(z), lp["gate_ln"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, lp["w_out"])
    return resid + out, {"conv": new_conv, "state": new_state}
