"""Attention cores: GQA (full / sliding-window / causal), decode-with-cache,
and cross-attention. Pure-jnp formulations that GSPMD can partition; the
Pallas TPU kernels in repro/kernels implement the same math for the
compute hot spots and are validated against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, H, D) by group broadcast."""
    b, s, hkv, d = k.shape
    if hkv == num_heads:
        return k
    rep = num_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def causal_mask(q_len: int, kv_len: int, window: int = 0,
                q_offset: int = 0) -> jax.Array:
    """(q_len, kv_len) boolean mask: True = attend.

    q position i (global i+q_offset) attends kv position j iff
    j <= i+q_offset and (window == 0 or j > i+q_offset-window).
    """
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    m = kj <= qi
    if window:
        m &= kj > qi - window
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              mask: jax.Array | None = None,
              scale: float | None = None) -> jax.Array:
    """Batched multi-head attention.

    q: (B, Sq, H, D), k/v: (B, Skv, Hkv, D) with H % Hkv == 0.
    mask: broadcastable to (B, H, Sq, Skv), True = attend.
    """
    h = q.shape[2]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def self_attention(q, k, v, *, causal: bool = True, window: int = 0,
                   q_offset: int = 0, scale: float | None = None,
                   chunk: int = 0):
    """Self-attention over a full sequence (train / prefill path).
    chunk > 0 selects the online-softmax blocked formulation (§Perf)."""
    if chunk and chunk < k.shape[1]:
        return chunked_self_attention(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, scale=scale,
                                      chunk=chunk)
    mask = None
    if causal:
        mask = causal_mask(q.shape[1], k.shape[1], window, q_offset)
        mask = mask[None, None]
    return attention(q, k, v, mask, scale)


def chunked_self_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                           scale=None, chunk=1024):
    """Flash-style attention in pure JAX: lax.scan over KV chunks with a
    running (m, l, acc) online softmax, so the (Sq, Skv) score matrix is
    never materialized — the XLA-compilable twin of the Pallas
    flash_attention kernel (memory-term optimization for prefill_32k,
    see EXPERIMENTS.md §Perf). Differentiable; exact (same fp32 math).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    if skv % chunk:
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    hkv = k.shape[2]
    dv = v.shape[-1]          # may differ from qk dim (MLA: 96 vs 64)
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    scale = scale if scale is not None else d ** -0.5
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, dv).transpose(1, 0, 2, 3, 4)
    q32 = q.astype(jnp.float32)
    q_pos = jnp.arange(sq) + q_offset

    def step(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q32,
                       kb.astype(jnp.float32)) * scale
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] <= (q_pos[:, None] if causal
                                  else jnp.full((sq, 1), skv))
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < skv)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    del hkv
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, window: int = 0,
                     scale: float | None = None) -> jax.Array:
    """Single-token attention against a KV cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S_max, Hkv, D); pos: () or (B,)
    int32 — number of valid cache entries *including* the current token
    (the caller writes the new k/v at index pos-1 before calling).
    A vector pos supports continuous batching (per-slot lengths).
    """
    s_max = k_cache.shape[1]
    idx = jnp.arange(s_max)[None, None, None, :]          # (1,1,1,S)
    p = pos if pos.ndim == 0 else pos[:, None, None, None]
    valid = idx < p
    if window:
        valid &= idx >= p - window
    return attention(q, k_cache, v_cache, valid, scale)


def cache_update(k_cache: jax.Array, v_cache: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, pos: jax.Array):
    """Write one token's k/v at index `pos` (scalar, or (B,) per-slot for
    continuous batching). cache (B, S, Hkv, D), new (B, 1, Hkv, D)."""
    k_new = k_new.astype(k_cache.dtype)
    v_new = v_new.astype(v_cache.dtype)
    if pos.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos,
                                                      axis=1)
    else:
        b = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[b, pos].set(k_new[:, 0])
        v_cache = v_cache.at[b, pos].set(v_new[:, 0])
    return k_cache, v_cache


def cross_attention(q: jax.Array, k_mem: jax.Array, v_mem: jax.Array,
                    scale: float | None = None) -> jax.Array:
    """Encoder-decoder cross attention (no mask: full encoder memory)."""
    return attention(q, k_mem, v_mem, None, scale)


def decode_attention_length_sharded(q, k_cache, v_cache, pos, window=0,
                                    scale=None):
    """Flash-decoding-style decode attention that STAYS in the cache's
    length-sharded layout (S -> model axis) instead of letting GSPMD
    reshard the multi-GB cache to head sharding every layer (§Perf).

    Scores/probs are explicitly constrained to S->model; the softmax
    statistics and the output contraction reduce over the sharded axis,
    so the only collectives are tiny (B,H)-stat and (B,H,D)-output
    all-reduces. Falls back to plain decode_attention without a mesh.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return decode_attention(q, k_cache, v_cache, pos, window, scale)
    P = jax.sharding.PartitionSpec
    b, _, h, d = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    groups = h // hkv
    bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = 1
    for a in bax:
        bsz *= mesh.shape[a]
    b_ax = bax if (bax and b % bsz == 0) else None
    s_ax = "model" if s_max % mesh.shape["model"] == 0 else None
    scale = scale if scale is not None else d ** -0.5

    # keep q replicated across model (it is one token; recompute is free)
    qg = jax.lax.with_sharding_constraint(
        q[:, 0].reshape(b, hkv, groups, d), P(b_ax, None, None, None))
    kc = jax.lax.with_sharding_constraint(
        k_cache, P(b_ax, s_ax, None, None))
    vc = jax.lax.with_sharding_constraint(
        v_cache, P(b_ax, s_ax, None, None))

    scores = jnp.einsum("begd,bsed->begs", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) * scale
    scores = jax.lax.with_sharding_constraint(
        scores, P(b_ax, None, None, s_ax))
    idx = jnp.arange(s_max)[None, None, None, :]
    p = pos if pos.ndim == 0 else pos[:, None, None, None]
    valid = idx < p
    if window:
        valid &= idx >= p - window
    scores = jnp.where(valid, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)       # reduce over S shard
    probs = jnp.exp(scores - m)
    l = jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("begs,bsed->begd", probs, vc.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)
    out = jax.lax.with_sharding_constraint(out, P(b_ax, None, None, None))
    return out.reshape(b, 1, h, d).astype(q.dtype)
