"""Common neural layers: RMSNorm, RoPE, SwiGLU, embeddings. Pure JAX."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings, shape (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, H, D) with D even; positions: broadcastable to (..., S).
    Uses the half-split convention (rotate_half), matching Llama.
    """
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)                  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (...,S,D/2)
    cos = jnp.cos(angles)[..., None, :]                    # (...,S,1,D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """Token embedding lookup against a (padded_vocab, d_model) table."""
    return jnp.take(table, tokens, axis=0)


def unembed(h: jax.Array, table: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Project to (padded) vocabulary logits; padded ids masked to -inf."""
    logits = jnp.einsum("...d,vd->...v", h, table)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.finfo(logits.dtype).min, logits)
    return logits


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross entropy. logits (B,S,V), labels (B,S)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
