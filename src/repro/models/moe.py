"""Mixture-of-Experts FFN with top-k routing (GShard-style capacity).

Implementation is the *sorted-capacity* formulation: per batch row, token
slots are sorted by expert id, each expert processes a fixed-capacity
contiguous buffer, and results scatter back weighted by the router gate.
All shapes are static (jit-friendly); tokens beyond capacity are dropped
(capacity_factor 1.25, like GShard/Switch). Sorting stays local to the
batch row, so under batch->data sharding the dispatch never crosses data
shards; expert weights are sharded on d_ff over the model axis (tensor-
parallel experts), which keeps every expert-count (40, 8) legal on a
16-way axis — see DESIGN.md §Distribution design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def router_probs(x: jax.Array, w_router: jax.Array) -> jax.Array:
    """(B,S,D) x (D,E) -> (B,S,E) softmax router probabilities (fp32)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def load_balance_loss(probs: jax.Array, expert_idx: jax.Array,
                      num_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e."""
    # fraction of slots dispatched to each expert
    counts = jnp.sum(jax.nn.one_hot(expert_idx, num_experts), axis=(1, 2))
    f = counts / jnp.maximum(jnp.sum(counts, -1, keepdims=True), 1.0)  # (B,E)
    p = jnp.mean(probs, axis=1)                                        # (B,E)
    return num_experts * jnp.mean(jnp.sum(f * p, axis=-1))


def moe_ffn(x: jax.Array, params: dict, cfg: ModelConfig
            ) -> tuple[jax.Array, jax.Array]:
    """Top-k MoE SwiGLU FFN. x: (B,S,D) -> (y (B,S,D), aux_loss ())."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    probs = router_probs(x, params["router"])                   # (B,S,E)
    gate, expert_idx = jax.lax.top_k(probs, k)                  # (B,S,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    aux = load_balance_loss(probs, expert_idx, e)

    n_slots = s * k
    capacity = max(1, min(
        -(-int(n_slots * cfg.moe_capacity_factor) // e),  # ceil division
        n_slots))

    # --- per-row sorted dispatch ------------------------------------- #
    e_flat = expert_idx.reshape(b, n_slots)                     # (B, S*k)
    gate_flat = gate.reshape(b, n_slots)
    tok_of_slot = jnp.repeat(jnp.arange(s), k)[None, :]         # (1, S*k)
    order = jnp.argsort(e_flat, axis=-1, stable=True)           # (B, S*k)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
    gate_sorted = jnp.take_along_axis(gate_flat, order, axis=-1)
    tok_sorted = jnp.take_along_axis(
        jnp.broadcast_to(tok_of_slot, (b, n_slots)), order, axis=-1)

    # position of each slot within its expert's buffer
    first = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left"))(e_sorted)
    pos = jnp.arange(n_slots)[None, :] - first                  # (B, S*k)
    keep = pos < capacity
    dest = jnp.where(keep, e_sorted * capacity + pos, e * capacity)

    # gather token activations into expert buffers (B, E*C+1, D)
    x_slot = jnp.take_along_axis(x, tok_sorted[..., None], axis=1)
    buf = jnp.zeros((b, e * capacity + 1, d), x.dtype)
    buf = buf.at[jnp.arange(b)[:, None], dest].add(
        jnp.where(keep[..., None], x_slot, 0))
    buf = buf[:, : e * capacity].reshape(b, e, capacity, d)

    # --- expert computation (SwiGLU, experts sharded on d_ff) --------- #
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    y_buf = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, params["w_down"])

    # --- combine back ------------------------------------------------- #
    y_buf = y_buf.reshape(b, e * capacity, d)
    y_buf = jnp.concatenate([y_buf, jnp.zeros((b, 1, d), y_buf.dtype)], 1)
    y_slot = jnp.take_along_axis(y_buf, dest[..., None], axis=1)  # (B,S*k,D)
    y_slot = y_slot * (gate_sorted * keep)[..., None].astype(y_buf.dtype)
    y = jnp.zeros((b, s, d), x.dtype)
    y = y.at[jnp.arange(b)[:, None], tok_sorted].add(y_slot)
    return y, aux


def moe_ffn_sharded(x: jax.Array, params: dict, cfg: ModelConfig
                    ) -> tuple[jax.Array, jax.Array]:
    """SPMD-safe MoE: the sorted-capacity dispatch runs inside shard_map
    so sorts/gathers/scatters stay device-local (GSPMD otherwise lifts the
    data-dependent scatter to a full batch all-gather — measured 14x FLOP
    replication on mixtral train_4k, see EXPERIMENTS.md §Dry-run).

    Batch stays sharded over (pod, data); expert weights are sharded on
    d_ff over `model`; the w_down contraction finishes with a psum over
    `model` — the same collective a dense row-parallel MLP needs.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return moe_ffn(x, params, cfg)
    b = x.shape[0]
    f = cfg.d_ff
    bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    axsz = 1
    for a in bax:
        axsz *= mesh.shape[a]
    if not bax or b % axsz:
        bax = ()
    f_ok = f % mesh.shape["model"] == 0
    f_ax = "model" if f_ok else None
    P = jax.sharding.PartitionSpec
    bspec = P(bax if bax else None, None, None)

    def local_fn(x_l, router, wg, wu, wd):
        y, aux = moe_ffn(x_l, {"router": router, "w_gate": wg, "w_up": wu,
                               "w_down": wd}, cfg)
        if f_ax:
            y = jax.lax.psum(y, f_ax)
        if bax:
            aux = jax.lax.pmean(aux, bax)
        return y, aux

    y, aux = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(bspec, P(None, None), P(None, None, f_ax),
                  P(None, None, f_ax), P(None, f_ax, None)),
        out_specs=(bspec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y, aux


def moe_ffn_dense_reference(x: jax.Array, params: dict, cfg: ModelConfig
                            ) -> tuple[jax.Array, jax.Array]:
    """Oracle: compute EVERY expert densely and combine by gates (no
    capacity drops). Used by tests; O(E/k) more FLOPs than moe_ffn."""
    e, k = cfg.num_experts, cfg.experts_per_token
    probs = router_probs(x, params["router"])
    gate, expert_idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    aux = load_balance_loss(probs, expert_idx, e)
    # (B,S,E) combine weights (zero for non-selected experts)
    combine = jnp.zeros_like(probs)
    combine = jnp.take_along_axis(
        combine, expert_idx, axis=-1)  # dummy to keep shapes obvious
    combine = jnp.sum(jax.nn.one_hot(expert_idx, e) * gate[..., None], axis=2)
    g = jnp.einsum("bsd,edf->bsef", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    y_all = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u, params["w_down"])
    y = jnp.einsum("bsed,bse->bsd", y_all, combine.astype(x.dtype))
    return y, aux
