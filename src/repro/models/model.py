"""Config-driven model zoo: init / train-loss / prefill / decode for all
ten assigned architectures (dense GQA, MLA, MoE+SWA, Mamba2 SSD, Zamba2
hybrid, Seamless enc-dec audio, InternVL2 VLM).

Conventions:
  * params are plain nested dicts; per-layer params are stacked on a
    leading `num_layers` axis and iterated with lax.scan (compact HLO for
    the 80 dry-run compiles).
  * caches are dicts of stacked arrays with a scalar `pos` (valid tokens).
  * modality frontends (ViT / audio codec) are STUBS per the assignment:
    callers pass precomputed `embeds` of shape (B, frontend_tokens, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, moe
from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, cross_entropy_loss, embed,
                                 rms_norm, swiglu, unembed)

INIT_STD = 0.02
MOE_AUX_COEF = 0.01


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ===================================================================== #
# initialization
# ===================================================================== #
def _dense(key, shape, dtype, std=INIT_STD):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def _init_attn(key, cfg: ModelConfig, dt) -> dict:
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    if cfg.attn_type == "mla":
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        ks = jax.random.split(key, 6)
        return {
            "q_down": _dense(ks[0], (d, cfg.q_lora_rank), dt),
            "q_ln": jnp.ones((cfg.q_lora_rank,), dt),
            "q_up": _dense(ks[1], (cfg.q_lora_rank, h * qk_dim), dt),
            "kv_down": _dense(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), dt),
            "kv_ln": jnp.ones((cfg.kv_lora_rank,), dt),
            "k_up": _dense(ks[3], (cfg.kv_lora_rank, h * cfg.qk_nope_dim), dt),
            "v_up": _dense(ks[4], (cfg.kv_lora_rank, h * cfg.v_head_dim), dt),
            "wo": _dense(ks[5], (h * cfg.v_head_dim, d), dt),
        }
    return {
        "wq": _dense(ks[0], (d, h * hd), dt),
        "wk": _dense(ks[1], (d, hkv * hd), dt),
        "wv": _dense(ks[2], (d, hkv * hd), dt),
        "wo": _dense(ks[3], (h * hd, d), dt),
    }


def _init_mlp(key, cfg: ModelConfig, dt) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    if cfg.num_experts:
        e = cfg.num_experts
        return {
            "router": _dense(ks[0], (d, e), jnp.float32),
            "w_gate": _dense(ks[1], (e, d, f), dt),
            "w_up": _dense(ks[2], (e, d, f), dt),
            "w_down": _dense(ks[3], (e, f, d), dt),
        }
    return {
        "w_gate": _dense(ks[0], (d, f), dt),
        "w_up": _dense(ks[1], (d, f), dt),
        "w_down": _dense(ks[2], (f, d), dt),
    }


def _init_block(key, cfg: ModelConfig, dt, cross: bool = False) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": _init_attn(k1, cfg, dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "mlp": _init_mlp(k2, cfg, dt),
    }
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), dt)
        p["xattn"] = _init_attn(k3, cfg, dt)
    return p


def _init_mamba(key, cfg: ModelConfig, dt) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv_width
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.ones((d,), dt),
        "w_z": _dense(ks[0], (d, di), dt),
        "w_x": _dense(ks[1], (d, di), dt),
        "w_b": _dense(ks[2], (d, n), dt),
        "w_c": _dense(ks[3], (d, n), dt),
        "w_dt": _dense(ks[4], (d, h), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "conv": _dense(ks[5], (w, di + 2 * n), dt, std=0.2),
        "a_log": jnp.zeros((h,), jnp.float32),      # A = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "gate_ln": jnp.ones((di,), dt),
        "w_out": _dense(ks[6], (di, d), dt),
    }


class Model:
    """Family-dispatched functional model. All methods are jit-safe."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------ init ----------------------------- #
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        k_emb, k_un, k_layers, k_extra = jax.random.split(key, 4)
        params: dict = {
            "embed": _dense(k_emb, (cfg.padded_vocab, cfg.d_model), dt),
            "unembed": _dense(k_un, (cfg.padded_vocab, cfg.d_model), dt),
            "final_ln": jnp.ones((cfg.d_model,), dt),
        }
        lk = jax.random.split(k_layers, cfg.num_layers)
        if cfg.family == "ssm":
            params["layers"] = jax.vmap(
                lambda k: _init_mamba(k, cfg, dt))(lk)
        elif cfg.family == "hybrid":
            params["layers"] = jax.vmap(
                lambda k: _init_mamba(k, cfg, dt))(lk)
            params["shared"] = _init_block(k_extra, cfg, dt)
        elif cfg.is_encdec:
            params["layers"] = jax.vmap(
                lambda k: _init_block(k, cfg, dt, cross=True))(lk)
            ek = jax.random.split(k_extra, cfg.encoder_layers)
            params["enc_layers"] = jax.vmap(
                lambda k: _init_block(k, cfg, dt))(ek)
            params["enc_final_ln"] = jnp.ones((cfg.d_model,), dt)
        else:  # dense / moe / vlm
            params["layers"] = jax.vmap(
                lambda k: _init_block(k, cfg, dt))(lk)
        return params

    def abstract_params(self, seed: int = 0):
        return jax.eval_shape(self.init, jax.random.key(seed))

    # ====================== attention sub-blocks ====================== #
    def _gqa_qkv(self, h, ap, positions):
        cfg = self.cfg
        b, s, _ = h.shape
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,de->bse", h, ap["wq"]).reshape(
            b, s, cfg.num_heads, hd)
        k = jnp.einsum("bsd,de->bse", h, ap["wk"]).reshape(
            b, s, cfg.num_kv_heads, hd)
        v = jnp.einsum("bsd,de->bse", h, ap["wv"]).reshape(
            b, s, cfg.num_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _mla_q(self, h, ap, positions):
        cfg = self.cfg
        b, s, _ = h.shape
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        ql = rms_norm(jnp.einsum("bsd,dr->bsr", h, ap["q_down"]),
                      ap["q_ln"], cfg.norm_eps)
        q = jnp.einsum("bsr,re->bse", ql, ap["q_up"]).reshape(
            b, s, cfg.num_heads, qk_dim)
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        return jnp.concatenate([q_nope, q_rope], axis=-1)

    def _mla_latent(self, h, ap, positions):
        """Compressed KV latent: (B,S,kv_lora + rope). Rope pre-applied."""
        cfg = self.cfg
        lat = jnp.einsum("bsd,dr->bsr", h, ap["kv_down"])
        c_kv, k_rope = jnp.split(lat, [cfg.kv_lora_rank], axis=-1)
        c_kv = rms_norm(c_kv, ap["kv_ln"], cfg.norm_eps)
        k_rope = apply_rope(k_rope[:, :, None, :], positions,
                            cfg.rope_theta)[:, :, 0]
        return jnp.concatenate([c_kv, k_rope], axis=-1)

    def _mla_kv_from_latent(self, latent, ap):
        """Expand cached latent to per-head K (nope+rope) and V."""
        cfg = self.cfg
        b, s, _ = latent.shape
        c_kv, k_rope = jnp.split(latent, [cfg.kv_lora_rank], axis=-1)
        k_nope = jnp.einsum("bsr,re->bse", c_kv, ap["k_up"]).reshape(
            b, s, cfg.num_heads, cfg.qk_nope_dim)
        v = jnp.einsum("bsr,re->bse", c_kv, ap["v_up"]).reshape(
            b, s, cfg.num_heads, cfg.v_head_dim)
        k_rope = jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, cfg.num_heads, cfg.qk_rope_dim))
        k = jnp.concatenate([k_nope, k_rope], axis=-1)
        return k, v

    # ======================= full-sequence blocks ===================== #
    def _attn_full(self, h, lp, positions, causal=True, q_offset=0):
        cfg = self.cfg
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        if cfg.attn_type == "mla":
            q = self._mla_q(hn, lp["attn"], positions)
            latent = self._mla_latent(hn, lp["attn"], positions)
            k, v = self._mla_kv_from_latent(latent, lp["attn"])
            scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
            o = attn.self_attention(q, k, v, causal=causal,
                                    window=cfg.sliding_window,
                                    q_offset=q_offset, scale=scale,
                                    chunk=cfg.attn_chunk)
            o = o.reshape(*o.shape[:2], -1)
            return h + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"]), latent
        q, k, v = self._gqa_qkv(hn, lp["attn"], positions)
        o = attn.self_attention(q, k, v, causal=causal,
                                window=cfg.sliding_window, q_offset=q_offset,
                                chunk=cfg.attn_chunk)
        o = o.reshape(*o.shape[:2], -1)
        return h + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"]), (k, v)

    def _mlp(self, h, lp):
        cfg = self.cfg
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.num_experts:
            y, aux = moe.moe_ffn_sharded(hn, lp["mlp"], cfg)
            return h + y, aux
        return h + swiglu(hn, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                          lp["mlp"]["w_down"]), 0.0

    def _maybe_seq_parallel(self, h):
        """Megatron-SP (§Perf): pin the residual stream to a
        sequence-sharded layout at layer boundaries so remat-saved
        activations are S/16 per device; GSPMD converts the TP
        all-reduces into reduce-scatter + all-gather pairs."""
        cfg = self.cfg
        if not cfg.seq_parallel:
            return h
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return h
        if h.shape[1] % mesh.shape["model"]:
            return h
        bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        bsz = 1
        for a in bax:
            bsz *= mesh.shape[a]
        b_spec = bax if (bax and h.shape[0] % bsz == 0) else None
        return jax.lax.with_sharding_constraint(
            h, jax.sharding.PartitionSpec(b_spec, "model", None))

    def _kv_heads_shardable(self) -> bool:
        """True when the KV-head count divides the model axis — then the
        baseline head-sharded decode attention is already reshard-free and
        the length-sharded path would only waste replicated-q compute."""
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return True
        return self.cfg.num_kv_heads % mesh.shape["model"] == 0

    def _pin_cache(self, arr, kind="kv"):
        """§Perf: pin decode caches to their canonical sharding after the
        token write — GSPMD otherwise flaps between the update's and the
        attention einsum's preferred layouts and falls back to
        'involuntary full rematerialization' (cache replication)."""
        cfg = self.cfg
        if not cfg.pin_cache_sharding:
            return arr
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return arr
        P = jax.sharding.PartitionSpec

        def fits(dim, ax):
            if ax is None:
                return False
            size = 1
            for a in ((ax,) if isinstance(ax, str) else ax):
                if a not in mesh.axis_names:
                    return False
                size *= mesh.shape[a]
            return dim % size == 0

        bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if kind == "kv":          # (B, S, H, D)
            b, ss, hh, _ = arr.shape
            b_ax = bax if fits(b, bax) else None
            h_ax = "model" if fits(hh, "model") else None
            s_ax = None if h_ax else ("model" if fits(ss, "model") else None)
            spec = P(b_ax, s_ax, h_ax, None)
        else:                     # latent (B, S, R)
            b, ss, _ = arr.shape
            b_ax = bax if fits(b, bax) else None
            spec = P(b_ax, "model" if fits(ss, "model") else None, None)
        return jax.lax.with_sharding_constraint(arr, spec)

    def _block_full(self, h, lp, positions, causal=True):
        h, kv = self._attn_full(h, lp, positions, causal=causal)
        h, aux = self._mlp(h, lp)
        return h, kv, aux

    # ========================= train forward ========================== #
    def loss(self, params: dict, batch: dict) -> jax.Array:
        """Mean next-token CE (+ MoE aux). batch keys:
        tokens (B,S_text) int32, and for vlm/audio `embeds`
        (B, frontend_tokens, D)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.is_encdec:
            logits, aux = self._encdec_forward(params, tokens,
                                               batch["embeds"])
            mask = jnp.ones(tokens.shape, jnp.float32)
            return (cross_entropy_loss(logits[:, :-1], tokens[:, 1:],
                                       mask[:, 1:])
                    + MOE_AUX_COEF * aux)
        h = embed(tokens, params["embed"])
        n_front = 0
        if cfg.frontend != "none" and "embeds" in batch:
            h = jnp.concatenate([batch["embeds"].astype(h.dtype), h], axis=1)
            n_front = batch["embeds"].shape[1]
        s_total = h.shape[1]
        positions = jnp.arange(s_total)[None, :]
        h, aux = self._stack_forward(params, h, positions)
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = unembed(h, params["unembed"], cfg)
        labels_full = jnp.pad(tokens, ((0, 0), (n_front, 0)))
        mask = (jnp.arange(s_total)[None, :] >= n_front).astype(jnp.float32)
        mask = jnp.broadcast_to(mask, labels_full.shape)
        return (cross_entropy_loss(logits[:, :-1], labels_full[:, 1:],
                                   mask[:, 1:]) + MOE_AUX_COEF * aux)

    def _stack_forward(self, params, h, positions):
        cfg = self.cfg
        if cfg.family == "ssm":
            def body(carry, lp):
                return mamba2.mamba2_block(carry, lp, cfg), None
            if cfg.remat:
                body = jax.checkpoint(body)
            h, _ = jax.lax.scan(body, h, params["layers"],
                                unroll=cfg.scan_unroll)
            return h, 0.0
        if cfg.family == "hybrid":
            return self._hybrid_forward(params, h, positions), 0.0

        def body(carry, lp):
            hh, aux = carry
            hh = self._maybe_seq_parallel(hh)
            hh, _, a = self._block_full(hh, lp, positions)
            return (hh, aux + a), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (h, aux), _ = jax.lax.scan(body, (h, 0.0), params["layers"],
                                   unroll=cfg.scan_unroll)
        return h, aux

    def _hybrid_forward(self, params, h, positions):
        """Zamba2: scan groups of `hybrid_period` Mamba2 layers, applying
        the single SHARED attention block between groups."""
        cfg = self.cfg
        g, per, rem = (cfg.num_hybrid_groups, cfg.hybrid_period,
                       cfg.hybrid_remainder)
        stacked = params["layers"]
        grouped = jax.tree.map(
            lambda x: x[: g * per].reshape(g, per, *x.shape[1:]), stacked)
        tail = jax.tree.map(lambda x: x[g * per:], stacked)
        shared = params["shared"]

        def group_body(carry, glp):
            def inner(c, lp):
                return mamba2.mamba2_block(c, lp, cfg), None
            if cfg.remat:
                inner = jax.checkpoint(inner)
            c, _ = jax.lax.scan(inner, carry, glp,
                                unroll=cfg.scan_unroll)
            c, _, _ = self._block_full(c, shared, positions)
            return c, None

        if cfg.remat:
            group_body = jax.checkpoint(group_body)
        h, _ = jax.lax.scan(group_body, h, grouped,
                            unroll=cfg.scan_unroll)
        if rem:
            def inner(c, lp):
                return mamba2.mamba2_block(c, lp, cfg), None
            if cfg.remat:
                inner = jax.checkpoint(inner)
            h, _ = jax.lax.scan(inner, h, tail, unroll=cfg.scan_unroll)
        return h

    def _encdec_forward(self, params, tokens, embeds):
        """Seamless-style: bidirectional encoder over frame embeddings,
        causal decoder with cross-attention. Returns (logits, aux)."""
        cfg = self.cfg
        enc_pos = jnp.arange(embeds.shape[1])[None, :]
        henc = embeds.astype(_dtype(cfg))

        def enc_body(c, lp):
            c, _, _ = self._block_full(c, lp, enc_pos, causal=False)
            return c, None
        if cfg.remat:
            enc_body = jax.checkpoint(enc_body)
        henc, _ = jax.lax.scan(enc_body, henc, params["enc_layers"],
                               unroll=cfg.scan_unroll)
        memory = rms_norm(henc, params["enc_final_ln"], cfg.norm_eps)

        h = embed(tokens, params["embed"])
        dec_pos = jnp.arange(tokens.shape[1])[None, :]

        def dec_body(carry, lp):
            hh, aux = carry
            hh, _, a = self._dec_block_full(hh, lp, dec_pos, memory)
            return (hh, aux + a), None
        if cfg.remat:
            dec_body = jax.checkpoint(dec_body)
        (h, aux), _ = jax.lax.scan(dec_body, (h, 0.0), params["layers"],
                                   unroll=cfg.scan_unroll)
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        return unembed(h, params["unembed"], cfg), aux

    def _dec_block_full(self, h, lp, positions, memory):
        cfg = self.cfg
        h, kv = self._attn_full(h, lp, positions)
        hn = rms_norm(h, lp["ln_x"], cfg.norm_eps)
        q, km, vm = self._gqa_qkv_mem(hn, lp["xattn"], memory, positions)
        o = attn.cross_attention(q, km, vm)
        o = o.reshape(*o.shape[:2], -1)
        h = h + jnp.einsum("bse,ed->bsd", o, lp["xattn"]["wo"])
        h, aux = self._mlp(h, lp)
        return h, kv, aux

    def _gqa_qkv_mem(self, h, ap, memory, positions):
        """Cross-attention projections: q from decoder, k/v from memory.
        No rope on cross-attention (memory has its own geometry)."""
        cfg = self.cfg
        b, s, _ = h.shape
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,de->bse", h, ap["wq"]).reshape(
            b, s, cfg.num_heads, hd)
        sm = memory.shape[1]
        k = jnp.einsum("bsd,de->bse", memory, ap["wk"]).reshape(
            b, sm, cfg.num_kv_heads, hd)
        v = jnp.einsum("bsd,de->bse", memory, ap["wv"]).reshape(
            b, sm, cfg.num_kv_heads, hd)
        return q, k, v

    # ========================= serving: prefill ======================= #
    def prefill(self, params: dict, tokens: jax.Array,
                embeds: jax.Array | None = None,
                max_len: int | None = None) -> tuple[jax.Array, dict]:
        """Process the full prompt, return (last-token logits, cache).

        Cache arrays are allocated at `max_len` (default: prompt length).
        """
        cfg = self.cfg
        if cfg.family == "ssm":
            return self._prefill_ssm(params, tokens, max_len)
        if cfg.family == "hybrid":
            return self._prefill_hybrid(params, tokens, max_len)
        if cfg.is_encdec:
            return self._prefill_encdec(params, tokens, embeds, max_len)

        h = embed(tokens, params["embed"])
        if cfg.frontend != "none" and embeds is not None:
            h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
        b, s, _ = h.shape
        max_len = max_len or s
        positions = jnp.arange(s)[None, :]

        def body(carry, lp):
            hh, aux = carry
            hh, kv, a = self._block_full(hh, lp, positions)
            return (hh, aux + a), kv

        (h, _), kvs = jax.lax.scan(body, (h, 0.0), params["layers"], unroll=cfg.scan_unroll)
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = unembed(h[:, -1:], params["unembed"], cfg)

        pad = max_len - s
        if cfg.attn_type == "mla":
            latent = jnp.pad(kvs, ((0, 0), (0, 0), (0, pad), (0, 0)))
            cache = {"latent": latent, "pos": jnp.int32(s)}
        else:
            k, v = kvs
            if cfg.swa_ring and cfg.sliding_window:
                k = self._to_ring(k, s)
                v = self._to_ring(v, s)
            else:
                k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache = {"k": k, "v": v, "pos": jnp.int32(s)}
        return logits, cache

    def _to_ring(self, k, s):
        """§Perf: convert stacked full-length K/V (L,B,S,H,D) into a
        sliding-window ring buffer (L,B,W,H,D): slot j holds the most
        recent position p with p % W == j (RoPE was applied at write time
        with true positions, so only the mask logic changes)."""
        w = self.cfg.sliding_window
        if s >= w:
            last = k[:, :, s - w:]
            return jnp.roll(last, shift=(s - w) % w, axis=2)
        return jnp.pad(k, ((0, 0), (0, 0), (0, w - s), (0, 0), (0, 0)))

    def _prefill_ssm(self, params, tokens, max_len=None):
        cfg = self.cfg
        h = embed(tokens, params["embed"])

        def body(carry, lp):
            hh = carry
            # run block but also emit final ssm/conv state
            out, cache = self._mamba_block_with_state(hh, lp)
            return out, cache

        h, caches = jax.lax.scan(body, h, params["layers"], unroll=cfg.scan_unroll)
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = unembed(h[:, -1:], params["unembed"], cfg)
        caches["pos"] = jnp.int32(tokens.shape[1])
        return logits, caches

    def _mamba_block_with_state(self, h, lp):
        """mamba2_block variant that returns the decode cache."""
        cfg = self.cfg
        bsz, l, _ = h.shape
        di, n = cfg.d_inner, cfg.ssm_state
        nh, p = cfg.ssm_heads, cfg.ssm_head_dim
        resid = h
        hn = rms_norm(h, lp["ln"], cfg.norm_eps)
        z, xbc, dt = mamba2.mamba2_projections(hn, lp, cfg)
        conv_state = xbc[:, -(cfg.ssm_conv_width - 1):]
        xbc_act = jax.nn.silu(mamba2._causal_conv(xbc, lp["conv"]))
        xin, bg, cg = jnp.split(xbc_act, [di, di + n], axis=-1)
        xh = xin.reshape(bsz, l, nh, p)
        y, h_final = mamba2.ssd_chunked(xh, dt, lp["a_log"], bg, cg,
                                        chunk=min(cfg.ssm_chunk, l))
        y = (y + lp["d_skip"][None, None, :, None] * xh).astype(xh.dtype)
        y = y.reshape(bsz, l, di)
        y = rms_norm(y * jax.nn.silu(z), lp["gate_ln"], cfg.norm_eps)
        out = resid + jnp.einsum("ble,ed->bld", y, lp["w_out"])
        return out, {"conv": conv_state.astype(_dtype(cfg)),
                     "state": h_final}

    def _prefill_hybrid(self, params, tokens, max_len=None):
        cfg = self.cfg
        g, per, rem = (cfg.num_hybrid_groups, cfg.hybrid_period,
                       cfg.hybrid_remainder)
        s = tokens.shape[1]
        max_len = max_len or s
        h = embed(tokens, params["embed"])
        positions = jnp.arange(s)[None, :]
        stacked = params["layers"]
        grouped = jax.tree.map(
            lambda x: x[: g * per].reshape(g, per, *x.shape[1:]), stacked)
        tail = jax.tree.map(lambda x: x[g * per:], stacked)
        shared = params["shared"]
        pad = max_len - s

        def group_body(carry, glp):
            def inner(c, lp):
                return self._mamba_block_with_state(c, lp)
            c, ssm_cache = jax.lax.scan(inner, carry, glp, unroll=cfg.scan_unroll)
            c, (k, v), _ = self._block_full(c, shared, positions)
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return c, (ssm_cache, {"k": k, "v": v})

        h, (ssm_caches, attn_caches) = jax.lax.scan(group_body, h, grouped, unroll=cfg.scan_unroll)
        tail_cache = None
        if rem:
            def inner(c, lp):
                return self._mamba_block_with_state(c, lp)
            h, tail_cache = jax.lax.scan(inner, h, tail, unroll=cfg.scan_unroll)
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = unembed(h[:, -1:], params["unembed"], cfg)
        cache = {"ssm": ssm_caches, "attn": attn_caches,
                 "tail": tail_cache, "pos": jnp.int32(s)}
        return logits, cache

    def _prefill_encdec(self, params, tokens, embeds, max_len=None):
        """Encode memory once; prefill decoder self+cross caches."""
        cfg = self.cfg
        enc_pos = jnp.arange(embeds.shape[1])[None, :]
        henc = embeds.astype(_dtype(cfg))

        def enc_body(c, lp):
            c, _, _ = self._block_full(c, lp, enc_pos, causal=False)
            return c, None
        henc, _ = jax.lax.scan(enc_body, henc, params["enc_layers"], unroll=cfg.scan_unroll)
        memory = rms_norm(henc, params["enc_final_ln"], cfg.norm_eps)

        s = tokens.shape[1]
        max_len = max_len or s
        pad = max_len - s
        h = embed(tokens, params["embed"])
        dec_pos = jnp.arange(s)[None, :]

        def dec_body(carry, lp):
            hh, aux = carry
            hn = rms_norm(hh, lp["ln1"], cfg.norm_eps)
            q, k, v = self._gqa_qkv(hn, lp["attn"], dec_pos)
            o = attn.self_attention(q, k, v, causal=True)
            o = o.reshape(*o.shape[:2], -1)
            hh = hh + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
            hn = rms_norm(hh, lp["ln_x"], cfg.norm_eps)
            qx, km, vm = self._gqa_qkv_mem(hn, lp["xattn"], memory, dec_pos)
            ox = attn.cross_attention(qx, km, vm)
            ox = ox.reshape(*ox.shape[:2], -1)
            hh = hh + jnp.einsum("bse,ed->bsd", ox, lp["xattn"]["wo"])
            hh, a = self._mlp(hh, lp)
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return (hh, aux + a), (kp, vp, km, vm)

        (h, _), (ks, vs, kms, vms) = jax.lax.scan(dec_body, (h, 0.0),
                                                  params["layers"], unroll=cfg.scan_unroll)
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = unembed(h[:, -1:], params["unembed"], cfg)
        cache = {"k": ks, "v": vs, "xk": kms, "xv": vms,
                 "pos": jnp.int32(s)}
        return logits, cache

    # ========================= serving: decode ======================== #
    def decode_step(self, params: dict, cache: dict,
                    token: jax.Array) -> tuple[jax.Array, dict]:
        """One decode step. token: (B, 1) int32. Returns (logits, cache)."""
        cfg = self.cfg
        cache = dict(cache)
        cache["pos"] = jnp.asarray(cache["pos"], jnp.int32)
        if cfg.family == "ssm":
            return self._decode_ssm(params, cache, token)
        if cfg.family == "hybrid":
            return self._decode_hybrid(params, cache, token)
        if cfg.is_encdec:
            return self._decode_encdec(params, cache, token)

        pos = cache["pos"]
        h = embed(token, params["embed"])
        positions = (jnp.full((1, 1), pos, jnp.int32) if pos.ndim == 0
                     else pos[:, None])

        if cfg.attn_type == "mla":
            def body(carry, xs):
                lp, lat = xs
                hh = carry
                hh, lat = self._mla_decode_block(hh, lp, lat, pos, positions)
                return hh, lat
            h, latents = jax.lax.scan(body, h,
                                      (params["layers"], cache["latent"]), unroll=cfg.scan_unroll)
            new_cache = {"latent": latents, "pos": pos + 1}
        else:
            def body(carry, xs):
                lp, ck, cv = xs
                hh = carry
                hh, nk, nv = self._gqa_decode_block(hh, lp, ck, cv, pos,
                                                    positions)
                return hh, (nk, nv)
            h, (nks, nvs) = jax.lax.scan(
                body, h, (params["layers"], cache["k"], cache["v"]), unroll=cfg.scan_unroll)
            new_cache = {"k": nks, "v": nvs, "pos": pos + 1}
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        return unembed(h, params["unembed"], cfg), new_cache

    def _gqa_decode_block(self, h, lp, ck, cv, pos, positions):
        cfg = self.cfg
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = self._gqa_qkv(hn, lp["attn"], positions)
        ring = cfg.swa_ring and cfg.sliding_window
        if ring:
            w = cfg.sliding_window
            ck, cv = attn.cache_update(ck, cv, k, v, pos % w)
            pos_eff = jnp.minimum(pos + 1, w)
            window = 0   # the ring holds exactly the window
        else:
            ck, cv = attn.cache_update(ck, cv, k, v, pos)
            pos_eff = pos + 1
            window = cfg.sliding_window
        if cfg.pin_cache_sharding and not self._kv_heads_shardable():
            ck, cv = self._pin_cache(ck), self._pin_cache(cv)
            o = attn.decode_attention_length_sharded(
                q, ck, cv, pos_eff, window=window)
        else:
            o = attn.decode_attention(q, ck, cv, pos_eff, window=window)
        o = o.reshape(*o.shape[:2], -1)
        h = h + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
        h, _ = self._mlp(h, lp)
        return h, ck, cv

    def _mla_decode_block(self, h, lp, latent_cache, pos, positions):
        """MLA decode: append this token's latent, expand K/V from the
        latent cache (naive materialization — see §Perf for the absorbed
        variant)."""
        cfg = self.cfg
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        q = self._mla_q(hn, lp["attn"], positions)
        lat_new = self._mla_latent(hn, lp["attn"], positions)
        lat_new = lat_new.astype(latent_cache.dtype)
        if pos.ndim == 0:
            latent_cache = jax.lax.dynamic_update_slice_in_dim(
                latent_cache, lat_new, pos, axis=1)
        else:
            latent_cache = latent_cache.at[
                jnp.arange(latent_cache.shape[0]), pos].set(lat_new[:, 0])
        latent_cache = self._pin_cache(latent_cache, kind="latent")
        scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
        if cfg.mla_absorb:
            o = self._mla_absorbed_attention(q, latent_cache, lp["attn"],
                                             pos, scale)
        else:
            k, v = self._mla_kv_from_latent(latent_cache, lp["attn"])
            o = attn.decode_attention(q, k, v, pos + 1, scale=scale)
        o = o.reshape(*o.shape[:2], -1)
        h = h + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
        h, _ = self._mlp(h, lp)
        return h, latent_cache

    def _mla_absorbed_attention(self, q, latent_cache, ap, pos, scale):
        """Absorbed-matmul MLA decode (§Perf): fold k_up into the query
        and v_up into the output so attention runs directly against the
        compressed latent cache — never materializing per-head K/V of
        shape (B, S, H, d). FLOPs per token drop from
        O(S·kv_lora·H·(nope+v)) to O(S·H·(kv_lora+rope)), and the
        (B,S,H,64)x2 temporaries disappear."""
        cfg = self.cfg
        b, s_max, _ = latent_cache.shape
        hn_heads = cfg.num_heads
        q_nope, q_rope = jnp.split(q[:, 0], [cfg.qk_nope_dim], axis=-1)
        k_up = ap["k_up"].reshape(cfg.kv_lora_rank, hn_heads,
                                  cfg.qk_nope_dim)
        # q_eff[b,h,r] = sum_d q_nope[b,h,d] * k_up[r,h,d]
        q_eff = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                           k_up.astype(jnp.float32))
        c_kv, k_rope = jnp.split(latent_cache, [cfg.kv_lora_rank], axis=-1)
        scores = (jnp.einsum("bhr,bsr->bhs", q_eff,
                             c_kv.astype(jnp.float32))
                  + jnp.einsum("bhd,bsd->bhs",
                               q_rope.astype(jnp.float32),
                               k_rope.astype(jnp.float32))) * scale
        idx = jnp.arange(s_max)[None, None, :]
        p = pos if pos.ndim == 0 else pos[:, None, None]
        scores = jnp.where(idx < p + 1, scores, attn.NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhs,bsr->bhr", probs,
                             c_kv.astype(jnp.float32))
        v_up = ap["v_up"].reshape(cfg.kv_lora_rank, hn_heads,
                                  cfg.v_head_dim)
        o = jnp.einsum("bhr,rhd->bhd", out_lat,
                       v_up.astype(jnp.float32))
        return o[:, None].astype(q.dtype)

    def _decode_ssm(self, params, cache, token):
        cfg = self.cfg
        h = embed(token, params["embed"])

        def body(carry, xs):
            lp, conv, state = xs
            hh = carry
            hh, nc = mamba2.mamba2_block_decode(
                hh, lp, {"conv": conv, "state": state}, cfg)
            return hh, (nc["conv"], nc["state"])
        h, (convs, states) = jax.lax.scan(
            body, h, (params["layers"], cache["conv"], cache["state"]), unroll=cfg.scan_unroll)
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = unembed(h, params["unembed"], cfg)
        return logits, {"conv": convs, "state": states,
                        "pos": cache["pos"] + 1}

    def _decode_hybrid(self, params, cache, token):
        cfg = self.cfg
        g, per, rem = (cfg.num_hybrid_groups, cfg.hybrid_period,
                       cfg.hybrid_remainder)
        pos = cache["pos"]
        positions = (jnp.full((1, 1), pos, jnp.int32) if pos.ndim == 0
                     else pos[:, None])
        h = embed(token, params["embed"])
        stacked = params["layers"]
        grouped = jax.tree.map(
            lambda x: x[: g * per].reshape(g, per, *x.shape[1:]), stacked)
        tail = jax.tree.map(lambda x: x[g * per:], stacked)
        shared = params["shared"]

        def group_body(carry, xs):
            glp, ssm_c, attn_c = xs
            c = carry

            def inner(cc, ys):
                lp, conv, state = ys
                cc, nc = mamba2.mamba2_block_decode(
                    cc, lp, {"conv": conv, "state": state}, cfg)
                return cc, (nc["conv"], nc["state"])
            c, (convs, states) = jax.lax.scan(
                inner, c, (glp, ssm_c["conv"], ssm_c["state"]), unroll=cfg.scan_unroll)
            c, nk, nv = self._gqa_decode_block(c, shared, attn_c["k"],
                                               attn_c["v"], pos, positions)
            return c, ({"conv": convs, "state": states},
                       {"k": nk, "v": nv})

        h, (new_ssm, new_attn) = jax.lax.scan(
            group_body, h, (grouped, cache["ssm"], cache["attn"]), unroll=cfg.scan_unroll)
        new_tail = None
        if rem:
            def inner(cc, ys):
                lp, conv, state = ys
                cc, nc = mamba2.mamba2_block_decode(
                    cc, lp, {"conv": conv, "state": state}, cfg)
                return cc, (nc["conv"], nc["state"])
            h, (tconvs, tstates) = jax.lax.scan(
                inner, h, (tail, cache["tail"]["conv"],
                           cache["tail"]["state"]), unroll=cfg.scan_unroll)
            new_tail = {"conv": tconvs, "state": tstates}
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = unembed(h, params["unembed"], cfg)
        return logits, {"ssm": new_ssm, "attn": new_attn, "tail": new_tail,
                        "pos": pos + 1}

    def _decode_encdec(self, params, cache, token):
        cfg = self.cfg
        pos = cache["pos"]
        positions = (jnp.full((1, 1), pos, jnp.int32) if pos.ndim == 0
                     else pos[:, None])
        h = embed(token, params["embed"])

        def body(carry, xs):
            lp, ck, cv, km, vm = xs
            hh = carry
            hn = rms_norm(hh, lp["ln1"], cfg.norm_eps)
            q, k, v = self._gqa_qkv(hn, lp["attn"], positions)
            ck, cv = attn.cache_update(ck, cv, k, v, pos)
            if cfg.pin_cache_sharding and not self._kv_heads_shardable():
                ck, cv = self._pin_cache(ck), self._pin_cache(cv)
                o = attn.decode_attention_length_sharded(q, ck, cv, pos + 1)
            else:
                o = attn.decode_attention(q, ck, cv, pos + 1)
            o = o.reshape(*o.shape[:2], -1)
            hh = hh + jnp.einsum("bse,ed->bsd", o, lp["attn"]["wo"])
            hn = rms_norm(hh, lp["ln_x"], cfg.norm_eps)
            hd = cfg.resolved_head_dim
            b = hn.shape[0]
            qx = jnp.einsum("bsd,de->bse", hn, lp["xattn"]["wq"]).reshape(
                b, 1, cfg.num_heads, hd)
            ox = attn.cross_attention(qx, km, vm)
            ox = ox.reshape(*ox.shape[:2], -1)
            hh = hh + jnp.einsum("bse,ed->bsd", ox, lp["xattn"]["wo"])
            hh, _ = self._mlp(hh, lp)
            return hh, (ck, cv)

        h, (nks, nvs) = jax.lax.scan(
            body, h, (params["layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]), unroll=cfg.scan_unroll)
        h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        logits = unembed(h, params["unembed"], cfg)
        return logits, {"k": nks, "v": nvs, "xk": cache["xk"],
                        "xv": cache["xv"], "pos": pos + 1}
