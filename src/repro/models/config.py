"""Unified model configuration covering all ten assigned architectures."""
from __future__ import annotations

import dataclasses


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- attention ---
    attn_type: str = "gqa"         # gqa | mla | none
    sliding_window: int = 0        # 0 = full attention; >0 = SWA width
    rope_theta: float = 10000.0
    # --- MLA (MiniCPM3 / DeepSeek-style) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0             # N
    ssm_head_dim: int = 64         # P
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_groups: int = 1            # G (B/C groups)
    ssm_conv_width: int = 4
    ssm_chunk: int = 256           # SSD chunk length
    # --- hybrid (Zamba2: shared attention every `hybrid_period` SSM layers) ---
    hybrid_period: int = 0
    # --- encoder-decoder (Seamless) ---
    encoder_layers: int = 0        # 0 = decoder-only
    # --- modality frontend stub ---
    frontend: str = "none"         # none | vision | audio
    frontend_tokens: int = 0       # patches / frames provided by input_specs
    # --- execution ---
    scan_unroll: int = 1   # >1: unroll layer scans (dry-run flop accounting)
    remat: bool = True     # activation-checkpoint each layer in train
    # --- §Perf beyond-paper optimization knobs (baseline = all off) ---
    attn_chunk: int = 0    # >0: online-softmax blocked attention (no SxS)
    mla_absorb: bool = False   # MLA decode: absorbed-matmul attention
    seq_parallel: bool = False  # sequence-parallel residuals (Megatron-SP)
    zero1: bool = False    # shard optimizer state over the data axis
    pin_cache_sharding: bool = False  # stop decode-cache reshard flapping
    swa_ring: bool = False  # ring-buffer KV cache sized to sliding_window
    # --- numerics ---
    dtype: str = "bfloat16"
    vocab_pad_mult: int = 2048     # pad vocab so model-axis sharding divides
    norm_eps: float = 1e-5
    citation: str = ""

    # ------------------------------ derived --------------------------- #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.vocab_pad_mult)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def kv_latent_dim(self) -> int:
        """MLA cache entry width per token: compressed KV + shared rope key."""
        return self.kv_lora_rank + self.qk_rope_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def num_hybrid_groups(self) -> int:
        if not self.hybrid_period:
            return 0
        return self.num_layers // self.hybrid_period

    @property
    def hybrid_remainder(self) -> int:
        if not self.hybrid_period:
            return 0
        return self.num_layers - self.num_hybrid_groups * self.hybrid_period

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim if self.num_heads else 0
        n_attn = (self.num_heads * hd * d) * 2 + (self.num_kv_heads * hd * d) * 2
        if self.attn_type == "mla":
            n_attn = (d * self.q_lora_rank
                      + self.q_lora_rank * self.num_heads
                      * (self.qk_nope_dim + self.qk_rope_dim)
                      + d * (self.kv_lora_rank + self.qk_rope_dim)
                      + self.kv_lora_rank * self.num_heads
                      * (self.qk_nope_dim + self.v_head_dim)
                      + self.num_heads * self.v_head_dim * d)
        n_mlp = 3 * d * f
        if self.num_experts:
            n_mlp = self.num_experts * 3 * d * f + d * self.num_experts
        n_ssm = 0
        if self.attn_type == "none" or self.family in ("ssm", "hybrid"):
            di, g, n, h = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            n_ssm = (2 * d * di + 2 * d * g * n + d * h   # z,x,B,C,dt projections
                     + self.ssm_conv_width * (di + 2 * g * n)
                     + 3 * h + di * d + di)
        emb = v * d
        if self.family == "ssm":
            per_layer = n_ssm
        elif self.family == "hybrid":
            per_layer = n_ssm  # plus one shared attention block below
        else:
            per_layer = n_attn + n_mlp
        total = self.num_layers * per_layer + 2 * emb
        if self.family == "hybrid":
            total += n_attn + 3 * d * f  # the single shared attn+mlp block
        if self.is_encdec:
            # encoder stack + decoder cross-attention
            total += self.encoder_layers * (n_attn + n_mlp)
            total += self.num_layers * n_attn  # cross-attn per decoder layer
        return total

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE top-k) for 6·N_active·D."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_moe = self.num_experts * 3 * d * f
        active_moe = self.experts_per_token * 3 * d * f
        return self.param_count() - self.num_layers * (dense_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
