"""Model zoo public API."""
from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.model import Model

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "Model"]
