"""Generic string-keyed registry shared by the five pluggable axes.

`repro.core.policies`, `repro.workloads` and `repro.sim.routing`
deliberately mirror each other: canonical-name normalization, a
registering decorator, `get_*` instantiation and `available_*` listing.
This module holds the one implementation they all wrap, so the axes
cannot drift apart. Error messages are parameterized because the
per-axis wordings are test-pinned ("unknown core policy ...", "unknown
workload scenario ...", "unknown cluster router ...") and must stay
byte-identical.

    _policies = Registry(noun="policy", kind="core policy",
                         decorator="register_policy",
                         expects="CorePolicy subclass",
                         check=lambda c: isinstance(c, type)
                         and issubclass(c, CorePolicy))
    register_policy = _policies.register
    get_policy = _policies.get
"""
from __future__ import annotations

from typing import Any, Callable


def canonical_name(name: str) -> str:
    """Normalize a user-supplied registry key: case-insensitive and
    underscore/hyphen-insensitive ("Least_Aged" -> "least-aged")."""
    return str(name).strip().lower().replace("_", "-")


class Registry:
    """One pluggable axis: decorator registration + name-keyed lookup.

    Args:
      noun:       short kind used in duplicate-name errors ("policy").
      kind:       full kind used in unknown-name errors ("core policy").
      decorator:  public decorator name for registration-type errors
                  ("register_policy").
      expects:    what the decorator accepts ("CorePolicy subclass",
                  "callable factory").
      check:      predicate validating a registered entry.
      set_name:   assign the canonical key to `entry.name` (class
                  registries do; factory registries don't).
      quote_prev: duplicate-name errors show the previous entry repr'd
                  (the scenario registry's historical wording) instead
                  of its bare `__name__`.
      post_get:   optional hook validating/transforming `get` results,
                  called as post_get(key, obj).
    """

    def __init__(self, *, noun: str, kind: str, decorator: str,
                 expects: str, check: Callable[[Any], bool],
                 set_name: bool = True, quote_prev: bool = False,
                 post_get: Callable[[str, Any], Any] | None = None):
        self.noun = noun
        self.kind = kind
        self.decorator = decorator
        self.expects = expects
        self.check = check
        self.set_name = set_name
        self.quote_prev = quote_prev
        self.post_get = post_get
        # Plain dict so axis modules can alias it as their historical
        # module-level `_REGISTRY` (tests reach in to clean up).
        self.store: dict[str, Any] = {}

    # ------------------------------------------------------------------ #
    def register(self, name: str):
        """Decorator: register an entry under `name`."""
        key = canonical_name(name)

        def deco(entry):
            if not self.check(entry):
                raise TypeError(f"@{self.decorator}({name!r}) expects a "
                                f"{self.expects}, got {entry!r}")
            prev = self.store.get(key)
            if prev is not None and prev is not entry:
                prev_desc = (repr(getattr(prev, "__name__", prev))
                             if self.quote_prev else prev.__name__)
                raise ValueError(f"{self.noun} name {key!r} already "
                                 f"registered to {prev_desc}")
            if self.set_name:
                entry.name = key
            self.store[key] = entry
            return entry

        return deco

    def get(self, name: str, **opts):
        """Instantiate/build the entry registered under `name`."""
        key = canonical_name(name)
        try:
            entry = self.store[key]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; available: "
                f"{', '.join(self.available())}") from None
        obj = entry(**opts)
        if self.post_get is not None:
            obj = self.post_get(key, obj)
        return obj

    def available(self) -> tuple[str, ...]:
        """Sorted canonical names of every registered entry."""
        return tuple(sorted(self.store))
