"""String-keyed registry of machine power models.

    @register_power_model("minmax-linear")
    class MinMaxLinearModel(PowerModel): ...

    model = get_power_model("minmax-linear")
    model = get_power_model("minmax-linear", governor="performance")

Names are case-insensitive and underscore/hyphen-insensitive, matching
the policy / scenario / router / carbon axes. Every `get_power_model`
call returns a NEW instance. The mechanics live in the shared
`repro.registry.Registry` (one implementation for all five axes).
"""
from __future__ import annotations

from repro.power.base import PowerModel
from repro.registry import Registry, canonical_name

_MODELS = Registry(
    noun="power model", kind="power model",
    decorator="register_power_model", expects="PowerModel subclass",
    check=lambda cls: isinstance(cls, type) and issubclass(cls,
                                                           PowerModel),
)
#: module-level alias matching the other axes (tests clean up through it)
_REGISTRY = _MODELS.store


def canonical_power_model_name(name: str) -> str:
    """Normalize a user-supplied model key ("MinMax_Linear" style)."""
    return canonical_name(name)


def register_power_model(name: str):
    """Class decorator: register a `PowerModel` subclass under `name`."""
    return _MODELS.register(name)


def get_power_model(name: str, **opts) -> PowerModel:
    """Instantiate the power model registered under `name` with `opts`."""
    return _MODELS.get(name, **opts)


def available_power_models() -> tuple[str, ...]:
    """Sorted canonical names of every registered power model."""
    return _MODELS.available()
