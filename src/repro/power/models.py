"""Built-in power models.

flat-tdp      — bit-exact re-homing of the simulator's historical
                implicit assumption: constant `(gpu + other) * util`
                watts regardless of core state. Golden-pinned so the
                `operational-embodied` carbon model reproduces its
                pre-power-subsystem numbers exactly.
tdp-per-core  — per-core TDP share: busy cores draw full share,
                shallow-idle cores a fraction, gated cores ~nothing,
                plus platform + accelerator floors.
minmax-linear — governor-aware linear model in the style of ichnos'
                PowerModel.py (min/max watts per core, draw linear in
                load between them; `ondemand` additionally scales busy
                draw with the settled frequency factor, so aged-slow
                cores genuinely burn less).
fitted-linear — linear regression coefficients per node type
                (named presets or explicit coefficient dict).

All watt defaults are chosen so the machine-level draw is comparable
to flat-tdp's 2160 W at the repo's assumed 0.6 utilization — models
differ in *shape* (how draw responds to gating, load, and frequency),
which is what the temporal consumers exploit.
"""
from __future__ import annotations

from repro.carbon.models import SERVER_GPU_TDP_W, SERVER_OTHER_TDP_W
from repro.power.base import PowerModel
from repro.power.registry import register_power_model
from repro.power.residency import StateResidency

_J_PER_KWH = 3.6e6

# SERVER_OTHER_TDP_W at the assumed utilization; the CPU-side models
# keep the accelerator as a constant floor at that same operating point
# so cross-model comparisons isolate the CPU-state response.
_DEFAULT_UTILIZATION = 0.6
_DEFAULT_GPU_FLOOR_W = SERVER_GPU_TDP_W * _DEFAULT_UTILIZATION   # 1680.0


def _check_nonnegative(**kwargs: float) -> None:
    for name, value in kwargs.items():
        if not value >= 0.0:          # also rejects NaN
            raise ValueError(f"{name} must be >= 0, got {value}")


@register_power_model("flat-tdp")
class FlatTdpModel(PowerModel):
    """Constant draw `(gpu_tdp_w + other_tdp_w) * utilization`.

    Residency-blind by construction: this is exactly the flat-watts
    stand-in the `operational-embodied` carbon model used before the
    power subsystem existed, re-homed here so the default config
    reproduces pre-PR operational numbers bit-exactly.
    """

    name = "flat-tdp"

    def __init__(self, gpu_tdp_w: float = SERVER_GPU_TDP_W,
                 other_tdp_w: float = SERVER_OTHER_TDP_W,
                 utilization: float = _DEFAULT_UTILIZATION):
        _check_nonnegative(gpu_tdp_w=gpu_tdp_w, other_tdp_w=other_tdp_w,
                           utilization=utilization)
        self.gpu_tdp_w = gpu_tdp_w
        self.other_tdp_w = other_tdp_w
        self.utilization = utilization

    def machine_power_w(self, busy_frac: float, idle_frac: float,
                        gated_frac: float, mean_busy_freq: float,
                        num_cores: int) -> float:
        return (self.gpu_tdp_w + self.other_tdp_w) * self.utilization

    def energy_kwh(self, residency: StateResidency) -> float:
        # Closed form (constant power) keeps the golden pin independent
        # of window partitioning.
        watts = (self.gpu_tdp_w + self.other_tdp_w) * self.utilization
        return watts * residency.duration_s / _J_PER_KWH


@register_power_model("tdp-per-core")
class TdpPerCoreModel(PowerModel):
    """Per-core TDP shares on top of platform + accelerator floors.

    Busy cores draw `core_tdp_w`, shallow-idle cores
    `idle_core_frac * core_tdp_w` (clocks gated, rails up), gated
    cores `gated_core_w` (~0: rails down in C6).
    """

    name = "tdp-per-core"

    def __init__(self, core_tdp_w: float = 13.75,
                 idle_core_frac: float = 0.3,
                 gated_core_w: float = 0.0,
                 platform_w: float = 250.0,
                 gpu_w: float = _DEFAULT_GPU_FLOOR_W):
        _check_nonnegative(core_tdp_w=core_tdp_w, gated_core_w=gated_core_w,
                           platform_w=platform_w, gpu_w=gpu_w)
        if not 0.0 <= idle_core_frac <= 1.0:
            raise ValueError(
                f"idle_core_frac must be in [0, 1], got {idle_core_frac}")
        self.core_tdp_w = core_tdp_w
        self.idle_core_frac = idle_core_frac
        self.gated_core_w = gated_core_w
        self.platform_w = platform_w
        self.gpu_w = gpu_w

    def machine_power_w(self, busy_frac: float, idle_frac: float,
                        gated_frac: float, mean_busy_freq: float,
                        num_cores: int) -> float:
        per_core = (busy_frac * self.core_tdp_w
                    + idle_frac * self.idle_core_frac * self.core_tdp_w
                    + gated_frac * self.gated_core_w)
        return self.platform_w + self.gpu_w + num_cores * per_core


_GOVERNORS = ("performance", "ondemand", "powersave")


@register_power_model("minmax-linear")
class MinMaxLinearModel(PowerModel):
    """Governor-aware min/max linear model (ichnos PowerModel.py style).

    Each core has a `min_core_w` (idle, lowest P-state) and
    `max_core_w` (busy, highest P-state) draw. The cpufreq governor
    decides where busy cores land between them:

      performance — busy cores pinned at `max_core_w`
      powersave   — busy cores pinned at `min_core_w`
      ondemand    — busy draw scales with the settled frequency
                    factor: `min + (max - min) * clamp(f, 0, 1)`, so
                    aging-slowed cores draw measurably less

    Shallow-idle cores draw `min_core_w`; gated cores `c6_core_w`.
    """

    name = "minmax-linear"

    def __init__(self, min_core_w: float = 1.5, max_core_w: float = 13.75,
                 c6_core_w: float = 0.1, platform_w: float = 250.0,
                 gpu_w: float = _DEFAULT_GPU_FLOOR_W,
                 governor: str = "ondemand"):
        _check_nonnegative(min_core_w=min_core_w, max_core_w=max_core_w,
                           c6_core_w=c6_core_w, platform_w=platform_w,
                           gpu_w=gpu_w)
        if max_core_w < min_core_w:
            raise ValueError(
                f"max_core_w ({max_core_w}) must be >= min_core_w "
                f"({min_core_w})")
        if governor not in _GOVERNORS:
            raise ValueError(f"unknown governor {governor!r}; available: "
                             f"{', '.join(_GOVERNORS)}")
        self.min_core_w = min_core_w
        self.max_core_w = max_core_w
        self.c6_core_w = c6_core_w
        self.platform_w = platform_w
        self.gpu_w = gpu_w
        self.governor = governor

    def _busy_core_w(self, mean_busy_freq: float) -> float:
        if self.governor == "performance":
            return self.max_core_w
        if self.governor == "powersave":
            return self.min_core_w
        f = min(max(mean_busy_freq, 0.0), 1.0)
        return self.min_core_w + (self.max_core_w - self.min_core_w) * f

    def machine_power_w(self, busy_frac: float, idle_frac: float,
                        gated_frac: float, mean_busy_freq: float,
                        num_cores: int) -> float:
        per_core = (busy_frac * self._busy_core_w(mean_busy_freq)
                    + idle_frac * self.min_core_w
                    + gated_frac * self.c6_core_w)
        return self.platform_w + self.gpu_w + num_cores * per_core


# Coefficients are per-machine linear terms:
#   P_cpu = c0 + c_busy*n_busy + c_idle*n_idle + c_gated*n_gated
#           + c_freq*(f - 1)*n_busy
# fitted offline against wall-power measurements for a node type.
NODE_COEFFS = {
    "xeon-40c": {"c0": 220.0, "c_busy": 12.5, "c_idle": 3.0,
                 "c_gated": 0.2, "c_freq": 40.0},
    "epyc-64c": {"c0": 180.0, "c_busy": 8.5, "c_idle": 2.2,
                 "c_gated": 0.15, "c_freq": 28.0},
}


@register_power_model("fitted-linear")
class FittedLinearModel(PowerModel):
    """Linear model with regression coefficients from node configs.

    Pick a preset with `node="xeon-40c"` or pass an explicit `coeffs`
    dict (keys `c0`, `c_busy`, `c_idle`, `c_gated`, `c_freq`).
    """

    name = "fitted-linear"

    def __init__(self, node: str = "xeon-40c",
                 coeffs: dict | None = None,
                 gpu_w: float = _DEFAULT_GPU_FLOOR_W):
        _check_nonnegative(gpu_w=gpu_w)
        if coeffs is None:
            if node not in NODE_COEFFS:
                raise ValueError(f"unknown node {node!r}; available: "
                                 f"{', '.join(sorted(NODE_COEFFS))}")
            coeffs = NODE_COEFFS[node]
        coeffs = dict(coeffs)
        missing = {"c0", "c_busy", "c_idle", "c_gated",
                   "c_freq"} - coeffs.keys()
        if missing:
            raise ValueError(
                f"coeffs missing keys: {', '.join(sorted(missing))}")
        self.node = node
        self.coeffs = coeffs
        self.gpu_w = gpu_w

    def machine_power_w(self, busy_frac: float, idle_frac: float,
                        gated_frac: float, mean_busy_freq: float,
                        num_cores: int) -> float:
        c = self.coeffs
        n_busy = num_cores * busy_frac
        cpu = (c["c0"] + c["c_busy"] * n_busy
               + c["c_idle"] * num_cores * idle_frac
               + c["c_gated"] * num_cores * gated_frac
               + c["c_freq"] * (mean_busy_freq - 1.0) * n_busy)
        return self.gpu_w + max(cpu, 0.0)
