"""Per-core C-state residency accounting — what the power models consume.

A machine's operational energy is determined by how long its cores sat
in each power regime, not by a flat utilization assumption. The
`CoreManager` keeps a `ResidencyAccumulator` in lockstep with its
event-loop bookkeeping: every state transition (assign / release /
gate / wake / settle) first banks the elapsed interval's core-seconds
under the *old* regime counts, exactly mirroring how dVth settlement
banks aging under the old ADF.

Regimes (the four states a `PowerModel` prices):

  busy        — C0, running an inference task (active-allocated)
  shallow idle — C0, no task (active-unallocated; clock-gated at best)
  gated       — C6 deep idle / power-gated (Algorithm 2's recovery
                state; the simulator's CState has one deep-idle level,
                so deep-idle and power-gated coincide here)

Alongside the scalar integrals the accumulator banks the same
core-seconds into fixed-width time windows, so operational carbon can
be priced against a *time-varying* grid intensity (power x intensity
integrated window by window) — the hook temporal scheduling needs.
The accumulator is pure bookkeeping: it never reads or perturbs the
aging state, so the settle hot path stays bit-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator


@dataclasses.dataclass(frozen=True)
class StateResidency:
    """Frozen per-machine core-state residency record over one horizon.

    All `*_core_s` fields are integrals of core-counts over time
    (core-seconds); `busy + idle + gated == num_cores * duration_s` up
    to float association. `freq_busy_core_s` weights each busy
    core-second by the settled frequency factor the task ran at, so
    `mean_busy_frequency` is the energy-relevant mean P-state.
    """

    num_cores: int
    duration_s: float
    busy_core_s: float
    idle_core_s: float
    gated_core_s: float
    freq_busy_core_s: float
    window_s: float
    window_busy_s: tuple[float, ...] = ()
    window_idle_s: tuple[float, ...] = ()
    window_gated_s: tuple[float, ...] = ()

    @property
    def utilization(self) -> float:
        """Fraction of core-time spent running tasks."""
        total = self.num_cores * self.duration_s
        return self.busy_core_s / total if total > 0.0 else 0.0

    @property
    def idle_frac(self) -> float:
        total = self.num_cores * self.duration_s
        return self.idle_core_s / total if total > 0.0 else 0.0

    @property
    def gated_frac(self) -> float:
        total = self.num_cores * self.duration_s
        return self.gated_core_s / total if total > 0.0 else 0.0

    @property
    def mean_busy_frequency(self) -> float:
        """Busy-time-weighted mean settled frequency factor (nominal
        1.0); 1.0 when nothing ever ran (it then only multiplies a zero
        busy fraction)."""
        if self.busy_core_s > 0.0:
            return self.freq_busy_core_s / self.busy_core_s
        return 1.0

    def iter_windows(self) -> Iterator[tuple[float, float, float, float,
                                             float]]:
        """Yield `(t_start, elapsed_s, busy_frac, idle_frac,
        gated_frac)` per non-empty time window. Windows are contiguous
        from t=0; only the final one may be partial."""
        n = self.num_cores
        for i, (b, s, g) in enumerate(zip(self.window_busy_s,
                                          self.window_idle_s,
                                          self.window_gated_s)):
            elapsed = (b + s + g) / n
            if elapsed <= 0.0:
                continue
            denom = n * elapsed
            yield (i * self.window_s, elapsed,
                   b / denom, s / denom, g / denom)

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "StateResidency":
        d = dict(d)
        for f in ("window_busy_s", "window_idle_s", "window_gated_s"):
            d[f] = tuple(float(x) for x in d.get(f, ()))
        return cls(**d)


class ResidencyAccumulator:
    """Mutable residency integrator owned by one `CoreManager`.

    `advance(now, n_busy, n_gated)` banks `[last_t, now)` under the
    given counts — callers must advance BEFORE changing any count,
    mirroring the settle-before-regime-change rule of the aging
    bookkeeping. O(1) per call (the interval lands in one time window
    except across the rare window boundary).
    """

    __slots__ = ("num_cores", "window_s", "last_t", "busy_core_s",
                 "idle_core_s", "gated_core_s", "freq_busy_core_s",
                 "_wb", "_wi", "_wg")

    def __init__(self, num_cores: int, window_s: float = 1.0):
        if window_s <= 0.0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.num_cores = num_cores
        self.window_s = window_s
        self.last_t = 0.0
        self.busy_core_s = 0.0
        self.idle_core_s = 0.0
        self.gated_core_s = 0.0
        self.freq_busy_core_s = 0.0
        self._wb: list[float] = []
        self._wi: list[float] = []
        self._wg: list[float] = []

    def advance(self, now: float, n_busy: int, n_gated: int) -> None:
        t0 = self.last_t
        dt = now - t0
        if dt <= 0.0:
            return
        self.last_t = now
        n_idle = self.num_cores - n_busy - n_gated
        self.busy_core_s += n_busy * dt
        self.idle_core_s += n_idle * dt
        self.gated_core_s += n_gated * dt
        w = self.window_s
        wb, wi, wg = self._wb, self._wi, self._wg
        i0 = int(t0 / w)
        i1 = int(now / w)
        if i1 >= len(wb):
            ext = i1 + 1 - len(wb)
            wb.extend([0.0] * ext)
            wi.extend([0.0] * ext)
            wg.extend([0.0] * ext)
        if i0 == i1:                      # common case: one window
            wb[i0] += n_busy * dt
            wi[i0] += n_idle * dt
            wg[i0] += n_gated * dt
            return
        t = t0
        for i in range(i0, i1 + 1):       # split across window boundaries
            seg = min((i + 1) * w, now) - t
            if seg > 0.0:
                wb[i] += n_busy * seg
                wi[i] += n_idle * seg
                wg[i] += n_gated * seg
            t += seg

    def add_busy_frequency(self, speed: float, duration_s: float) -> None:
        """Bank `duration_s` busy core-seconds weighted by the settled
        frequency factor the task ran at (called on release)."""
        if duration_s > 0.0:
            self.freq_busy_core_s += speed * duration_s

    def snapshot(self) -> StateResidency:
        return StateResidency(
            num_cores=self.num_cores,
            duration_s=self.last_t,
            busy_core_s=self.busy_core_s,
            idle_core_s=self.idle_core_s,
            gated_core_s=self.gated_core_s,
            freq_busy_core_s=self.freq_busy_core_s,
            window_s=self.window_s,
            window_busy_s=tuple(self._wb),
            window_idle_s=tuple(self._wi),
            window_gated_s=tuple(self._wg),
        )
