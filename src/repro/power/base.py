"""PowerModel protocol: per-core state residencies -> machine watts.

A power model prices one machine's draw from the fractions of core-time
spent busy / shallow-idle / gated (see `residency.StateResidency`),
plus the busy-time-weighted mean settled frequency factor (the aging
technique slows cores down, which genuinely changes dynamic power).

The protocol deliberately works on *fractions within a time window*,
not instantaneous core sets: energy is the windowed integral
`sum_w P(fracs_w) * elapsed_w`, and operational carbon prices each
window at the grid intensity of its midpoint — the temporal coupling
that makes carbon-aware deferral measurable instead of cosmetic.

Subclasses implement `machine_power_w`; `energy_kwh`,
`operational_g`, and `marginal_task_w` have generic defaults.
"""
from __future__ import annotations

from repro.carbon.intensity import CarbonIntensity
from repro.power.residency import StateResidency

_J_PER_KWH = 3.6e6


class PowerModel:
    """Base class for machine power models (the fifth registry axis).

    Constructor kwargs come from `ExperimentConfig.power_opts` via
    `get_power_model(name, **opts)`, so every option must have a
    sensible default.
    """

    name = "base"

    def machine_power_w(self, busy_frac: float, idle_frac: float,
                        gated_frac: float, mean_busy_freq: float,
                        num_cores: int) -> float:
        """Instantaneous machine draw (W) given core-state fractions.

        `busy_frac + idle_frac + gated_frac == 1`; `mean_busy_freq` is
        the settled frequency factor (nominal 1.0) of the busy cores.
        """
        raise NotImplementedError

    def energy_kwh(self, residency: StateResidency) -> float:
        """Machine energy (kWh) over the residency horizon: windowed
        integral of `machine_power_w`."""
        f = residency.mean_busy_frequency
        n = residency.num_cores
        joules = 0.0
        for _, elapsed, bf, if_, gf in residency.iter_windows():
            joules += self.machine_power_w(bf, if_, gf, f, n) * elapsed
        return joules / _J_PER_KWH

    def operational_g(self, residency: StateResidency,
                      intensity: CarbonIntensity,
                      t0: float = 0.0) -> float:
        """Operational carbon (gCO2eq) over the horizon: each residency
        window's energy priced at the grid intensity of its midpoint
        (`t0` offsets simulation time into intensity time)."""
        f = residency.mean_busy_frequency
        n = residency.num_cores
        grams = 0.0
        for t_start, elapsed, bf, if_, gf in residency.iter_windows():
            kwh = (self.machine_power_w(bf, if_, gf, f, n) * elapsed
                   / _J_PER_KWH)
            grams += kwh * intensity.g_per_kwh(t0 + t_start + 0.5 * elapsed)
        return grams

    def marginal_task_w(self, mean_busy_freq: float,
                        num_cores: int) -> float:
        """Extra draw (W) of running one more core busy instead of
        shallow-idle — the per-task operational signal routers score.
        Zero for residency-blind models like `flat-tdp`."""
        full = self.machine_power_w(1.0, 0.0, 0.0, mean_busy_freq,
                                    num_cores)
        idle = self.machine_power_w(0.0, 1.0, 0.0, mean_busy_freq,
                                    num_cores)
        return (full - idle) / num_cores

    def describe(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"
