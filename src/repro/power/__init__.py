"""Machine power models — the fifth pluggable registry axis.

Maps per-core C-state residencies (busy / shallow-idle / gated, plus
settled frequency) to machine watts, energy (kWh), and — priced
against a `CarbonIntensity` — operational gCO2eq. See `base` for the
`PowerModel` protocol, `models` for the built-ins, and `residency`
for the accounting the `CoreManager` keeps in its settle hot path.
"""
from repro.power.base import PowerModel
from repro.power.models import (FittedLinearModel, FlatTdpModel,
                                MinMaxLinearModel, NODE_COEFFS,
                                TdpPerCoreModel)
from repro.power.registry import (available_power_models,
                                  canonical_power_model_name,
                                  get_power_model, register_power_model)
from repro.power.residency import ResidencyAccumulator, StateResidency

__all__ = [
    "PowerModel", "FlatTdpModel", "TdpPerCoreModel", "MinMaxLinearModel",
    "FittedLinearModel", "NODE_COEFFS", "ResidencyAccumulator",
    "StateResidency", "available_power_models",
    "canonical_power_model_name", "get_power_model",
    "register_power_model",
]
