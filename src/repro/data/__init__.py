"""Deterministic synthetic data pipeline."""
