"""Deterministic synthetic token data pipeline.

Generates reproducible next-token-predictable streams (a mixture of a
Markov-chain "language" and copy motifs) so training loss measurably
decreases — useful for end-to-end driver validation without shipping a
corpus. Batches are yielded as numpy, device_put by the caller with the
appropriate sharding (the pipeline is host-side, like a tf.data feed).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 1
    branching: int = 8     # successors per state -> learnable structure


class SyntheticTokens:
    """Infinite deterministic stream of (tokens, labels) batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse Markov transition: each token allows `branching` successors
        self._succ = rng.integers(0, v, size=(v, cfg.branching))
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 1 + self._step)
        self._step += 1
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        choices = rng.integers(0, cfg.branching, (b, s))
        for t in range(1, s):
            toks[:, t] = self._succ[toks[:, t - 1], choices[:, t]]
        return {"tokens": toks}


def batches(cfg: DataConfig, n: int):
    it = SyntheticTokens(cfg)
    for _ in range(n):
        yield next(it)
