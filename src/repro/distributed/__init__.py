"""Distribution: sharding rules + HLO collective analysis."""
