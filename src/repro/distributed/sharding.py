"""Sharding rules: map model/cache/input arrays onto the production mesh.

Strategy (baseline; §Perf iterates on it):
  * weights: Megatron-style tensor parallelism on the `model` axis —
    column-parallel for up-projections (wq/wk/wv/w_gate/w_up/q_up/...),
    row-parallel for down-projections (wo/w_down/w_out); vocab sharded on
    `model` (vocab is padded to a multiple of 2048 so 16 always divides).
  * MoE experts: expert weights sharded on the d_ff dim over `model`
    (tensor-parallel experts) — legal for any expert count (40, 8).
  * activations/batch: sharded over (`pod`, `data`).
  * KV caches: batch -> data; heads -> model when the head count divides,
    else sequence -> model (flash-decoding style length sharding).

Every rule is divisibility-guarded: a dim that the axis does not divide
evenly is replicated instead (JAX rejects uneven jit-boundary shardings).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return dim % axis_size(mesh, axis) == 0


def dim_spec(mesh: Mesh, dim: int, axis):
    """axis if it divides dim, else replicate."""
    return axis if axis is not None and _fits(dim, mesh, axis) else None


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --------------------------------------------------------------------- #
# parameter specs
# --------------------------------------------------------------------- #
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "w_z", "w_x",
                 "q_up", "k_up", "v_up", "q_down", "kv_down"}
_ROW_PARALLEL = {"wo", "w_down", "w_out"}
_VOCAB = {"embed", "unembed"}


def _leaf_spec(name: str, shape: tuple, mesh: Mesh) -> P:
    nd = len(shape)
    if name in _VOCAB:
        return P(dim_spec(mesh, shape[0], "model"), None)
    if name in _COL_PARALLEL:
        if nd == 3:  # MoE expert weight (E, D, F): shard F
            return P(None, None, dim_spec(mesh, shape[2], "model"))
        return P(None, dim_spec(mesh, shape[1], "model"))
    if name in _ROW_PARALLEL:
        if nd == 3:  # MoE (E, F, D): shard F
            return P(None, dim_spec(mesh, shape[1], "model"), None)
        return P(dim_spec(mesh, shape[0], "model"), None)
    return P(*([None] * nd))  # norms, biases, router, conv, scalars


def param_specs(abstract_params, mesh: Mesh):
    """PartitionSpec tree for a (possibly layer-stacked) param tree.

    Stacked layer params have a leading num_layers dim — the rule applies
    to the trailing dims with a leading None.
    """
    def spec_for(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        shape = leaf.shape
        # stacked layers: strip leading layer dim(s) heuristically — the
        # registry of names is disjoint, so match on trailing dims.
        strip = 0
        under = {"layers", "enc_layers"}
        path_keys = [str(p.key) for p in path if hasattr(p, "key")]
        if path_keys and path_keys[0] in under:
            strip = 1
        base = _leaf_spec(name, shape[strip:], mesh)
        return P(*([None] * strip), *base)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


# --------------------------------------------------------------------- #
# cache specs
# --------------------------------------------------------------------- #
def kv_cache_spec(mesh: Mesh, shape: tuple) -> P:
    """(L, B, S, H, D) — batch->data, heads->model else seq->model."""
    _, b, s, h, _ = shape
    b_ax = dim_spec(mesh, b, batch_axes(mesh))
    h_ax = dim_spec(mesh, h, "model")
    s_ax = None if h_ax else dim_spec(mesh, s, "model")
    return P(None, b_ax, s_ax, h_ax, None)


def latent_cache_spec(mesh: Mesh, shape: tuple) -> P:
    """MLA latent (L, B, S, R) — batch->data, seq->model."""
    _, b, s, _ = shape
    return P(None, dim_spec(mesh, b, batch_axes(mesh)),
             dim_spec(mesh, s, "model"), None)


def ssm_cache_specs(mesh: Mesh, conv_shape: tuple, state_shape: tuple):
    """conv (L,B,W-1,C), state (L,B,H,P,N) — batch->data, heads/chan->model."""
    _, b, _, c = conv_shape
    _, _, h, _, _ = state_shape
    b_ax = dim_spec(mesh, b, batch_axes(mesh))
    return (P(None, b_ax, None, dim_spec(mesh, c, "model")),
            P(None, b_ax, dim_spec(mesh, h, "model"), None, None))


def cache_specs(cfg: ModelConfig, abstract_cache, mesh: Mesh):
    """Spec tree matching an abstract cache pytree (by key name).

    Rules apply to TRAILING dims so arbitrary leading stack dims (layers,
    hybrid groups, per-group layers) are handled uniformly:
      k/v/xk/xv : (..., B, S, H, D)  batch->data, heads->model else S->model
      latent    : (..., B, S, R)    batch->data, S->model
      conv      : (..., B, W, C)    batch->data, channels->model
      state     : (..., B, H, P, N) batch->data, heads->model
    """
    b_ax = batch_axes(mesh)

    def spec_for(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        shape = leaf.shape
        lead = [None] * (len(shape) - 4)
        if name == "pos":
            return P()
        if name in ("k", "v", "xk", "xv"):
            b, s, h, _ = shape[-4:]
            h_ax = dim_spec(mesh, h, "model")
            s_ax = None if h_ax else dim_spec(mesh, s, "model")
            return P(*lead, dim_spec(mesh, b, b_ax), s_ax, h_ax, None)
        if name == "latent":
            lead = [None] * (len(shape) - 3)
            b, s, _ = shape[-3:]
            return P(*lead, dim_spec(mesh, b, b_ax),
                     dim_spec(mesh, s, "model"), None)
        if name == "conv":
            lead = [None] * (len(shape) - 3)
            b, _, c = shape[-3:]
            return P(*lead, dim_spec(mesh, b, b_ax), None,
                     dim_spec(mesh, c, "model"))
        if name == "state":
            b, h, _, _ = shape[-4:]
            return P(*lead, dim_spec(mesh, b, b_ax),
                     dim_spec(mesh, h, "model"), None, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, abstract_cache)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def with_sharding(mesh: Mesh, abstract_tree, spec_tree):
    """Attach shardings to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        abstract_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
