"""Parse compiled HLO text for collective traffic (roofline §collective).

`cost_analysis()` does not expose collective bytes, so we scan the
post-SPMD HLO for all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops and sum their result-buffer sizes. Shapes in the
partitioned module are per-device, so totals are per-chip bytes.
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_name: bytes, ..., 'total': bytes, 'count': int}."""
    totals: dict = defaultdict(int)
    count = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if " = " not in stripped:
            continue
        op = None
        for cand in COLLECTIVE_OPS:
            # match the op invocation, not a variable name mention
            if f" {cand}(" in stripped or f" {cand}-start(" in stripped:
                op = cand
                break
        if op is None:
            continue
        # "-done" ops carry the same buffer as "-start"; count starts only.
        if f" {op}-done(" in stripped:
            continue
        lhs = stripped.split(" = ", 1)[1]
        # result shapes (possibly a tuple) precede " <op>(" / " <op>-start("
        cut = lhs.find(f" {op}(")
        if cut < 0:
            cut = lhs.find(f" {op}-start(")
        shapes_str = lhs[:cut] if cut >= 0 else lhs.split("(", 1)[0]
        nbytes = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(shapes_str))
        totals[op] += nbytes
        count += 1
    totals["total"] = sum(totals[o] for o in COLLECTIVE_OPS if o in totals)
    totals["count"] = count
    return dict(totals)


def duplicate_fusion_count(hlo_text: str) -> int:
    """Rough remat indicator: repeated identical fusion computations."""
    names = re.findall(r"^\s*%?(fused_computation[\w.]*)", hlo_text,
                       re.MULTILINE)
    return len(names) - len(set(names))
