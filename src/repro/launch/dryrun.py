import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, proving the distribution config is coherent
without hardware, and extract roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import dataclasses
import importlib
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, all_arch_names
from repro.distributed import hlo_analysis, sharding as shd
from repro.launch import mesh as mesh_lib
from repro.models import INPUT_SHAPES, Model
from repro.models.config import InputShape, ModelConfig
from repro.training import optimizer as opt_lib


def resolve_config(arch: str, shape_name: str,
                   variant: str = "baseline") -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ALIASES[arch]}")
    cfg = mod.LONG_CONTEXT if shape_name == "long_500k" else mod.FULL
    if variant == "optimized":
        # beyond-paper §Perf knobs (see EXPERIMENTS.md): chunked online-
        # softmax attention, absorbed MLA decode, sequence-parallel
        # residuals, ZeRO-1 optimizer sharding.
        cfg = dataclasses.replace(cfg, attn_chunk=2048, mla_absorb=True,
                                  seq_parallel=True, zero1=True,
                                  pin_cache_sharding=True, swa_ring=True)
    return cfg


def input_specs(arch: str, shape_name: str, mesh=None, cfg=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this step —
    weak-type-correct, shardable, no device allocation."""
    cfg = cfg or resolve_config(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh or mesh_lib.make_production_mesh()
    model = Model(cfg)
    b, s = shape.global_batch, shape.seq_len
    b_ax = shd.batch_axes(mesh)
    tok_spec = shd.P(shd.dim_spec(mesh, b, b_ax), None)
    specs: dict = {}

    n_front = front_len(cfg, shape)
    if shape.kind == "train":
        s_text = s - (n_front if cfg.family == "vlm" else 0)
        specs["batch"] = {"tokens": jax.ShapeDtypeStruct(
            (b, s_text), jnp.int32,
            sharding=shd.NamedSharding(mesh, tok_spec))}
        if n_front:
            e_spec = shd.P(shd.dim_spec(mesh, b, b_ax), None, None)
            specs["batch"]["embeds"] = jax.ShapeDtypeStruct(
                (b, n_front, cfg.d_model), jnp.float32,
                sharding=shd.NamedSharding(mesh, e_spec))
    elif shape.kind == "prefill":
        s_text = s - (n_front if cfg.family == "vlm" else 0)
        specs["tokens"] = jax.ShapeDtypeStruct(
            (b, s_text), jnp.int32, sharding=shd.NamedSharding(mesh, tok_spec))
        if n_front:
            e_spec = shd.P(shd.dim_spec(mesh, b, b_ax), None, None)
            specs["embeds"] = jax.ShapeDtypeStruct(
                (b, n_front, cfg.d_model), jnp.float32,
                sharding=shd.NamedSharding(mesh, e_spec))
    else:  # decode: one token + cache of seq_len
        specs["token"] = jax.ShapeDtypeStruct(
            (b, 1), jnp.int32, sharding=shd.NamedSharding(mesh, tok_spec))
        cache_abs = abstract_cache(model, cfg, shape)
        cache_spec = shd.cache_specs(cfg, cache_abs, mesh)
        specs["cache"] = shd.with_sharding(mesh, cache_abs, cache_spec)
    return specs


def front_len(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.frontend == "none":
        return 0
    if cfg.family == "vlm":
        return cfg.frontend_tokens
    # audio: encoder frames scale with the sequence, bounded for decode
    if shape.kind == "decode":
        return min(shape.seq_len // 4, 4096)
    return shape.seq_len // 4


def abstract_cache(model: Model, cfg: ModelConfig, shape: InputShape):
    """Abstract cache pytree for a decode step (ShapeDtypeStructs)."""
    b, s = shape.global_batch, shape.seq_len
    n_front = front_len(cfg, shape)
    # use eval_shape over the real prefill to derive exact cache shapes;
    # VLM prompts embed n_front patches inside the seq_len budget
    s_text = s - 1 - (n_front if cfg.family == "vlm" else 0)
    tok = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    emb = (jax.ShapeDtypeStruct((b, n_front, cfg.d_model), jnp.float32)
           if n_front else None)
    params_abs = model.abstract_params()

    def fn(p, t, e):
        _, cache = model.prefill(p, t, e, max_len=s)
        return cache

    return jax.eval_shape(fn, params_abs, tok, emb)


def _zero1_spec(abs_leaf, spec, mesh):
    """ZeRO-1 (§Perf): additionally shard optimizer moments over the
    data axis on the first dim the model axis doesn't already occupy."""
    dax = "data"
    if dax not in mesh.axis_names:
        return spec
    size = mesh.shape[dax]
    dims = list(spec)
    for i, (d, ax) in enumerate(zip(abs_leaf.shape, dims)):
        if ax is None and d % size == 0:
            dims[i] = dax
            break
    return shd.P(*dims)


def build_step(arch: str, shape_name: str, mesh, cfg=None):
    """Returns (step_fn, kwargs of sharded ShapeDtypeStructs)."""
    cfg = cfg or resolve_config(arch, shape_name)
    shape = INPUT_SHAPES[shape_name]
    model = Model(cfg)
    params_abs = model.abstract_params()
    pspecs = shd.param_specs(params_abs, mesh)
    params_in = shd.with_sharding(mesh, params_abs, pspecs)
    specs = input_specs(arch, shape_name, mesh, cfg)

    if shape.kind == "train":
        ocfg = opt_lib.AdamWConfig()
        opt_abs = jax.eval_shape(opt_lib.init_state, params_abs)
        mspec = (jax.tree.map(lambda a, s: _zero1_spec(a, s, mesh),
                              opt_abs.mu, pspecs,
                              is_leaf=lambda x: isinstance(x, shd.P))
                 if cfg.zero1 else jax.tree.map(lambda s: s, pspecs))
        ospecs = opt_lib.AdamWState(
            step=shd.P(),
            mu=mspec,
            nu=jax.tree.map(lambda s: s, mspec,
                            is_leaf=lambda x: isinstance(x, shd.P)))
        opt_in = shd.with_sharding(mesh, opt_abs, ospecs)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state, metrics = opt_lib.apply_updates(
                params, grads, opt_state, ocfg)
            return loss, params, opt_state, metrics

        return train_step, (params_in, opt_in, specs["batch"])

    if shape.kind == "prefill":
        def prefill_step(params, tokens, embeds=None):
            return model.prefill(params, tokens, embeds,
                                 max_len=shape.seq_len)
        args = (params_in, specs["tokens"])
        if "embeds" in specs:
            return (lambda p, t, e: prefill_step(p, t, e)), (
                params_in, specs["tokens"], specs["embeds"])
        return (lambda p, t: prefill_step(p, t)), args

    def serve_step(params, cache, token):
        return model.decode_step(params, cache, token)

    return serve_step, (params_in, specs["cache"], specs["token"])


def _measure(arch, shape_name, mesh, cfg):
    """Compile a fully-unrolled variant and return per-device cost dict."""
    cfg_u = dataclasses.replace(cfg, scan_unroll=1_000_000)
    step, args = build_step(arch, shape_name, mesh, cfg_u)
    with jax.set_mesh(mesh):
        compiled = jax.jit(step).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll}


def _coll_comb(a, b, fa=1.0, fb=1.0):
    keys = set(a) | set(b)
    return {k: max(0.0, fa * a.get(k, 0) + fb * b.get(k, 0)) for k in keys}


def _extrapolated_cost(arch, shape_name, mesh, cfg) -> dict:
    """Layer-accurate cost accounting without compiling the full depth:
    compile fully-UNROLLED reduced-depth variants and extrapolate the
    per-layer marginal cost linearly (exact for layer-homogeneous stacks;
    XLA cost_analysis counts a scan body once, so the deployment-form
    compile alone undercounts ~L x)."""
    if cfg.is_encdec:
        base = _measure(arch, shape_name, mesh, dataclasses.replace(
            cfg, num_layers=2, encoder_layers=2))
        d_enc = _measure(arch, shape_name, mesh, dataclasses.replace(
            cfg, num_layers=2, encoder_layers=4))
        d_dec = _measure(arch, shape_name, mesh, dataclasses.replace(
            cfg, num_layers=4, encoder_layers=2))
        n_e, n_d = cfg.encoder_layers - 2, cfg.num_layers - 2
        out = {}
        for k in ("flops", "bytes"):
            se = (d_enc[k] - base[k]) / 2
            sd = (d_dec[k] - base[k]) / 2
            out[k] = base[k] + se * n_e + sd * n_d
        ce = _coll_comb(d_enc["coll"], base["coll"], 0.5, -0.5)
        cd = _coll_comb(d_dec["coll"], base["coll"], 0.5, -0.5)
        coll = _coll_comb(base["coll"], _coll_comb(ce, cd, n_e, n_d))
        out["coll"] = coll
        return out
    if cfg.family == "hybrid":
        per, rem = cfg.hybrid_period, cfg.hybrid_remainder
        l1, l2 = per + rem, 2 * per + rem          # 1 and 2 groups
        steps = float(cfg.num_hybrid_groups - 1)   # extra groups beyond l1
    else:
        l1, l2 = 2, 4
        steps = (cfg.num_layers - l1) / (l2 - l1)  # extra (l2-l1) blocks
    c1 = _measure(arch, shape_name, mesh,
                  dataclasses.replace(cfg, num_layers=l1))
    c2 = _measure(arch, shape_name, mesh,
                  dataclasses.replace(cfg, num_layers=l2))
    out = {k: c1[k] + (c2[k] - c1[k]) * steps for k in ("flops", "bytes")}
    dcoll = _coll_comb(c2["coll"], c1["coll"], 1.0, -1.0)
    out["coll"] = _coll_comb(c1["coll"], dcoll, 1.0, steps)
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            unrolled_cost: bool = True, variant: str = "baseline") -> dict:
    """Dry-run one (arch x shape x mesh).

    Two compiles: (1) the deployment form (lax.scan over layers) proves the
    sharding lowers and yields the memory analysis; (2) a fully-unrolled
    form yields layer-accurate FLOP / bytes / collective accounting
    (cost_analysis counts a scan body once, not trip-count times).
    The unrolled pass runs on the single-pod mesh only (roofline scope).
    """
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cfg = resolve_config(arch, shape_name, variant)
    t0 = time.time()
    step_fn, args = build_step(arch, shape_name, mesh, cfg)
    with jax.set_mesh(mesh):
        lowered = jax.jit(step_fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    t_unroll = 0.0
    if unrolled_cost and not multi_pod:
        tu = time.time()
        del variant  # cfg already carries the variant knobs
        est = _extrapolated_cost(arch, shape_name, mesh, cfg)
        ca = {"flops": est["flops"], "bytes accessed": est["bytes"]}
        coll = est["coll"]
        t_unroll = time.time() - tu

    shape = INPUT_SHAPES[shape_name]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = cfg.active_param_count()
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    mult = 3 if shape.kind == "train" else 1  # fwd+bwd ~ 3x fwd
    model_flops = 2.0 * n_active * tokens * mult

    rec = {
        "arch": arch,
        "shape": shape_name,
        "variant": ("optimized" if (cfg.attn_chunk or cfg.mla_absorb
                                    or cfg.seq_parallel or cfg.zero1)
                    else "baseline"),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "unrolled_compile_s": round(t_unroll, 2),
        "per_device": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes": (ma.argument_size_in_bytes
                           + ma.temp_size_in_bytes),
            "flops": flops_dev,
            "bytes_accessed": bytes_dev,
            "collective_bytes": coll.get("total", 0),
        },
        "collectives": {k: v for k, v in coll.items()
                        if k not in ("total",)},
        "roofline_s": {
            "compute": flops_dev / mesh_lib.PEAK_BF16_FLOPS,
            "memory": bytes_dev / mesh_lib.HBM_BW,
            "collective": coll.get("total", 0) / mesh_lib.ICI_LINK_BW,
        },
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / (flops_dev * chips)
                               if flops_dev else 0.0),
        "params": cfg.param_count(),
        "active_params": n_active,
    }
    terms = rec["roofline_s"]
    rec["bottleneck"] = max(terms, key=terms.get)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default=None, help="JSON output directory")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    archs = all_arch_names() if args.all or not args.arch else [args.arch]
    shapes = (list(INPUT_SHAPES) if args.all or not args.shape
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch} x {shape_name} x {'2x16x16' if multi else '16x16'}"
                try:
                    rec = run_one(arch, shape_name, multi,
                                  variant=args.variant)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((tag, repr(e)))
                    print(f"FAIL  {tag}: {e}")
                    continue
                pd = rec["per_device"]
                print(f"OK    {tag}: compile={rec['compile_s']}s "
                      f"peak={pd['peak_bytes']/1e9:.2f}GB "
                      f"flops/dev={pd['flops']:.3e} "
                      f"coll/dev={pd['collective_bytes']/1e6:.1f}MB "
                      f"bottleneck={rec['bottleneck']}")
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    suffix = ("_opt" if args.variant == "optimized"
                              else "")
                    fn = (f"{arch}_{shape_name}_{rec['mesh']}{suffix}.json"
                          .replace("/", "_"))
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(rec, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
