"""Training driver: data pipeline -> model -> AdamW -> checkpoints.

On CPU this trains reduced configs (--smoke); on a TPU pod the same code
path shards params/optimizer over the production mesh via in_shardings.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import Model
from repro.training import optimizer as opt_lib


def make_train_step(model: Model, ocfg: opt_lib.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = opt_lib.apply_updates(
            params, grads, opt_state, ocfg)
        return params, opt_state, loss, metrics
    return jax.jit(train_step, donate_argnums=(0, 1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    ocfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                               total_steps=args.steps)
    params = model.init(jax.random.key(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} "
          f"(analytic {cfg.param_count():,})")
    opt_state = opt_lib.init_state(params)
    step0 = 0
    if args.ckpt_dir and store.latest_step(args.ckpt_dir) is not None:
        step0 = store.latest_step(args.ckpt_dir)
        params = store.restore(args.ckpt_dir, params)
        opt_state = store.restore(args.ckpt_dir, opt_state,
                                  name="opt_state.npz")
        print(f"restored step {step0} from {args.ckpt_dir}")

    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed))
    train_step = make_train_step(model, ocfg)

    losses = []
    t0 = time.time()
    for step in range(step0, args.steps):
        batch_np = next(data)
        batch = {"tokens": jnp.asarray(batch_np["tokens"])}
        if cfg.frontend != "none":
            batch["embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.float32)
        params, opt_state, loss, metrics = train_step(params, opt_state,
                                                      batch)
        losses.append(float(loss))
        if (step + 1) % args.log_every == 0:
            rate = args.batch * args.seq * args.log_every / (
                time.time() - t0)
            print(f"step {step+1:5d} loss {float(loss):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"tok/s {rate:,.0f}")
            t0 = time.time()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            store.save(args.ckpt_dir, step + 1, params, opt_state,
                       extra={"loss": float(loss)})
    if losses and losses[-1] < losses[0]:
        print(f"loss improved {losses[0]:.4f} -> {losses[-1]:.4f}")
    else:
        print("WARNING: loss did not improve")


if __name__ == "__main__":
    main()
