"""Serving driver: continuous-batching engine + aging-aware core manager.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 16 --policy proposed
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import available_policies
from repro.models import Model
from repro.serving.engine import InferenceEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--policy", default="proposed",
                    choices=list(available_policies()))
    ap.add_argument("--host-cores", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = InferenceEngine(
        model, params, max_batch=args.max_batch, max_len=args.max_len,
        policy=args.policy, num_host_cores=args.host_cores)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for r in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              args.prompt_len).tolist()
        engine.submit(prompt, max_new_tokens=args.new_tokens)
    engine.run_until_drained()
    dt = time.time() - t0
    total_tokens = args.requests * args.new_tokens
    print(f"served {args.requests} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:,.1f} tok/s)")
    rep = engine.host_cpu_report()
    print(f"host CPU [{rep['policy']}]: cores_active={rep['active_cores']}/"
          f"{args.host_cores} cv={rep['cv']:.4f} "
          f"mean_freq_degradation={rep['mean_degradation']:.5f} "
          f"cpu_tasks={rep['assigns']}")


if __name__ == "__main__":
    main()
