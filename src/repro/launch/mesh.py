"""Production mesh definitions (TPU v5e pods).

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because
the dry-run forces 512 host devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Single-device mesh for smoke runs on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_BF16_FLOPS = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_LINK_BW = 50e9           # bytes/s per link
