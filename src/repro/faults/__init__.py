"""Fault injection — the sixth registry axis.

Aging-induced core failures, machine crashes, and transient stalls as
pluggable `FaultModel`s (see `repro.faults.base`), selected per
experiment via `ExperimentConfig.fault_model` / `fault_opts`. The
default `"none"` builds no fault machinery at all and is bit-exact with
pre-fault behavior.
"""
from repro.faults.base import FaultDecision, FaultModel, FaultView
from repro.faults.registry import (
    available_fault_models,
    canonical_fault_model_name,
    get_fault_model,
    register_fault_model,
)

# importing the package registers the built-ins
from repro.faults import models as _models  # noqa: E402,F401

__all__ = [
    "FaultDecision",
    "FaultModel",
    "FaultView",
    "available_fault_models",
    "canonical_fault_model_name",
    "get_fault_model",
    "register_fault_model",
]
