"""String-keyed registry of fault-injection models — the sixth axis.

    @register_fault_model("guardband")
    class GuardbandFaults(FaultModel): ...

    model = get_fault_model("guardband")
    model = get_fault_model("guardband", margin=0.02)

Names are case-insensitive and underscore/hyphen-insensitive, matching
the policy / scenario / router / carbon / power axes. Every
`get_fault_model` call returns a NEW instance (models carry per-machine
state). The mechanics live in the shared `repro.registry.Registry` (one
implementation for all six axes).
"""
from __future__ import annotations

from repro.faults.base import FaultModel
from repro.registry import Registry, canonical_name

_MODELS = Registry(
    noun="fault model", kind="fault model",
    decorator="register_fault_model", expects="FaultModel subclass",
    check=lambda cls: isinstance(cls, type) and issubclass(cls,
                                                           FaultModel),
)
#: module-level alias matching the other axes (tests clean up through it)
_REGISTRY = _MODELS.store


def canonical_fault_model_name(name: str) -> str:
    """Normalize a user-supplied model key ("Machine_Crash" style)."""
    return canonical_name(name)


def register_fault_model(name: str):
    """Class decorator: register a `FaultModel` subclass under `name`."""
    return _MODELS.register(name)


def get_fault_model(name: str, **opts) -> FaultModel:
    """Instantiate the fault model registered under `name` with `opts`."""
    return _MODELS.get(name, **opts)


def available_fault_models() -> tuple[str, ...]:
    """Sorted canonical names of every registered fault model."""
    return _MODELS.available()
