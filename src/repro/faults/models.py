"""Built-in fault models (see `repro.faults.base` for the protocol).

  none            — bit-exact no-op. The engines build NO fault
                    machinery at all when `fault_model == "none"`, so
                    faultless runs draw no extra RNG, schedule no extra
                    events, and stay bit-identical to pre-fault goldens.
  guardband       — a core whose ΔVth-driven settled frequency has eaten
                    more than `margin` of the guardband fails
                    probabilistically, coupling failure rate to the
                    aging state each policy produces: policies that age
                    cores harder (or less evenly) lose more cores.
  machine-crash   — Poisson whole-machine crashes (rate 1/mttf_s) with a
                    deterministic `reboot_s` recovery window.
  transient-stall — temporary single-core slowdowns (Poisson onsets,
                    fixed slowdown factor and duration).
"""
from __future__ import annotations

import math

import numpy as np

from repro.faults.base import FaultDecision, FaultModel, FaultView
from repro.faults.registry import register_fault_model


@register_fault_model("none")
class NoFaults(FaultModel):
    """Nothing ever fails — the default, and deliberately *absent* at
    runtime: engines skip fault construction entirely for this name, so
    it exists to make the registry axis total (`get_fault_model("none")`
    resolves) and as the minimal protocol reference."""

    def periodic(self, view: FaultView) -> FaultDecision | None:
        return None


@register_fault_model("guardband")
class GuardbandFaults(FaultModel):
    """Aging-coupled core failures at the frequency guardband edge.

    A core is *eligible* once its settled `dvth / headroom` — the
    fraction of the frequency guardband its NBTI shift has consumed —
    exceeds `margin`. Each period an eligible core fails with
    probability `1 - exp(-hazard_per_s * over * period)` where
    `over = (dvth/headroom - margin) / margin`: the further past the
    margin, the steeper the hazard. This couples failures to the aging
    distribution each policy produces, which is the acceptance handle —
    `proposed` keeps per-core wear lower and more even than `linux`, so
    at equal horizons it must lose strictly fewer cores.

    One uniform is drawn per core every period *regardless* of
    eligibility, so the RNG stream is identical across policies and
    failure-count comparisons reflect aging state, not stream drift.
    """

    def __init__(self, margin: float = 0.012, hazard_per_s: float = 2.0,
                 max_failed_frac: float = 0.5):
        if margin <= 0.0:
            raise ValueError(f"margin must be > 0, got {margin}")
        if hazard_per_s <= 0.0:
            raise ValueError(f"hazard_per_s must be > 0, got {hazard_per_s}")
        if not 0.0 < max_failed_frac <= 1.0:
            raise ValueError(f"max_failed_frac must be in (0, 1], got "
                             f"{max_failed_frac}")
        self.margin = float(margin)
        self.hazard_per_s = float(hazard_per_s)
        self.max_failed_frac = float(max_failed_frac)

    def periodic(self, view: FaultView) -> FaultDecision | None:
        # Draw BEFORE any early-out so the stream stays policy-invariant.
        u = view.rng.random(view.num_cores)
        if not view.up:
            return None
        failed = view.failed_mask
        if failed.sum() >= self.max_failed_frac * view.num_cores:
            return None
        over = (view.degradation() - self.margin) / self.margin
        p = -np.expm1(-self.hazard_per_s * view.period_s
                      * np.maximum(over, 0.0))
        hits = np.flatnonzero((over > 0.0) & ~failed & (u < p))
        if not len(hits):
            return None
        return FaultDecision(fail_cores=tuple(int(c) for c in hits))


@register_fault_model("machine-crash")
class MachineCrashFaults(FaultModel):
    """Poisson machine crashes with a deterministic reboot window.

    Crash inter-arrivals are Exp(mttf_s) from the fault axis' seeded
    per-machine stream (the next crash time is pre-drawn, so detection
    is deterministic given the seed); recovery takes exactly `reboot_s`
    — everything in flight on the machine dies and is re-dispatched by
    the cluster's retry layer.
    """

    def __init__(self, mttf_s: float = 1800.0, reboot_s: float = 30.0):
        if mttf_s <= 0.0:
            raise ValueError(f"mttf_s must be > 0, got {mttf_s}")
        if reboot_s <= 0.0:
            raise ValueError(f"reboot_s must be > 0, got {reboot_s}")
        self.mttf_s = float(mttf_s)
        self.reboot_s = float(reboot_s)
        self._next_crash: float | None = None

    def periodic(self, view: FaultView) -> FaultDecision | None:
        if self._next_crash is None:
            self._next_crash = float(view.rng.exponential(self.mttf_s))
        if not view.up or view.now < self._next_crash:
            return None
        self._next_crash = (view.now + self.reboot_s
                            + float(view.rng.exponential(self.mttf_s)))
        return FaultDecision(crash=True, reboot_s=self.reboot_s)


@register_fault_model("transient-stall")
class TransientStallFaults(FaultModel):
    """Temporary single-core slowdowns (thermal throttling, SMIs, noisy
    neighbors): stall onsets are Poisson per machine (`rate_per_s`), a
    uniformly-drawn core runs at `slowdown` x its settled speed for
    `stall_s` seconds, then recovers. In-flight work on the core is
    re-rated through the same rebanking path promotions use."""

    def __init__(self, rate_per_s: float = 0.02, slowdown: float = 0.4,
                 stall_s: float = 5.0):
        if rate_per_s <= 0.0:
            raise ValueError(f"rate_per_s must be > 0, got {rate_per_s}")
        if not 0.0 < slowdown < 1.0:
            raise ValueError(f"slowdown must be in (0, 1), got {slowdown}")
        if stall_s <= 0.0:
            raise ValueError(f"stall_s must be > 0, got {stall_s}")
        self.rate_per_s = float(rate_per_s)
        self.slowdown = float(slowdown)
        self.stall_s = float(stall_s)

    def periodic(self, view: FaultView) -> FaultDecision | None:
        # Fixed two draws per period keep the stream policy-invariant.
        u = view.rng.random()
        core = int(view.rng.integers(view.num_cores))
        if not view.up:
            return None
        p = -math.expm1(-self.rate_per_s * view.period_s)
        if u >= p or view.failed_mask[core]:
            return None
        return FaultDecision(stall_cores=(core,),
                             stall_factor=self.slowdown,
                             stall_s=self.stall_s)
