"""Fault-model protocol: aging-induced failures as a pluggable axis.

The paper's argument is that extending CPU lifetime is only safe if the
*reliability* consequences of silicon aging are managed — guardband
violations, degraded cores, machine loss. This module defines the
contract a fault model implements so those consequences can actually
occur at runtime:

  * `FaultModel.periodic(view)` runs once per idling period per machine
    and returns a `FaultDecision` (cores to fail, cores to stall, or a
    machine crash) or `None`.
  * `FaultView` is the read-only window the model judges from — the
    machine's settled aging state, which cores already failed, whether
    the machine is up, and the fault axis' own seeded RNG stream.

Models are registered under `repro.faults.registry` (the sixth registry
axis) and instantiated per machine, mirroring how `CorePolicy` instances
are per-server. The handling of a decision — offlining cores, migrating
in-flight work, crash/reboot orchestration, request retries — lives in
the engines (`repro.sim.cluster.FaultCoordinator` for the event loop,
`repro.sim.fleetsim` vectorized), never in the model itself.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultDecision:
    """What a fault model wants to happen this period on one machine.

    `fail_cores` offline cores permanently (guardband violation);
    `stall_cores` slow cores to `stall_factor` x their settled speed for
    `stall_s` seconds; `crash=True` takes the whole machine down for a
    deterministic `reboot_s` window. A default-constructed decision is
    a no-op (models normally return `None` instead)."""

    fail_cores: tuple[int, ...] = ()
    stall_cores: tuple[int, ...] = ()
    stall_factor: float = 1.0
    stall_s: float = 0.0
    crash: bool = False
    reboot_s: float = 0.0

    def __bool__(self) -> bool:
        return bool(self.fail_cores or self.stall_cores or self.crash)


class FaultView:
    """Read-only per-machine window for fault models.

    Mirrors `CoreView`/`FleetView` one axis over: the model reads the
    machine's *settled* aging state (pure — `CoreManager._settled_dvth`
    never mutates) plus its own seeded RNG stream, and returns decisions
    instead of mutating anything.
    """

    __slots__ = ("_machine", "_rng", "period_s")

    def __init__(self, machine, rng: np.random.Generator, period_s: float):
        self._machine = machine
        self._rng = rng
        self.period_s = float(period_s)

    @property
    def machine_id(self) -> int:
        return self._machine.machine_id

    @property
    def now(self) -> float:
        return self._machine.queue.now

    @property
    def num_cores(self) -> int:
        return self._machine.manager.num_cores

    @property
    def rng(self) -> np.random.Generator:
        """The fault axis' own per-machine RNG stream (never shared with
        the manager or router streams, so adding faults cannot perturb
        their draws)."""
        return self._rng

    @property
    def up(self) -> bool:
        """Whether the machine is powered (False during a reboot window)."""
        return getattr(self._machine, "up", True)

    @property
    def failed_mask(self) -> np.ndarray:
        """(num_cores,) bool — cores already permanently offlined."""
        m = self._machine.manager.failed
        v = m.view()
        v.flags.writeable = False
        return v

    def degradation(self) -> np.ndarray:
        """(num_cores,) fractional guardband consumption at `now`:
        settled dVth / headroom, i.e. the fraction of the frequency
        guardband each core's NBTI shift has eaten (pure read)."""
        mgr = self._machine.manager
        return mgr._settled_dvth(self.now) / mgr.params.headroom

    def frequencies(self) -> np.ndarray:
        """(num_cores,) settled frequency factors at `now` (pure read)."""
        from repro.core import aging
        mgr = self._machine.manager
        return aging.frequency(mgr.params, mgr.f0,
                               mgr._settled_dvth(self.now))


class FaultModel:
    """Base class for fault-injection models (the sixth registry axis).

    Subclasses register with `@register_fault_model(name)` and are
    instantiated once per machine via `get_fault_model(name, **opts)` —
    they may carry per-machine state (e.g. a pre-drawn next crash time).
    """

    #: canonical registry key, set by @register_fault_model
    name: ClassVar[str] = "?"

    def periodic(self, view: FaultView) -> FaultDecision | None:
        """Called once per idling period; return what should fail (or
        `None`). RNG draws must come from `view.rng` only."""
        return None

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"
