"""Streaming telemetry: typed probes, structured event tracing, and
exporters (JSONL / Chrome trace / CSV / npz / Prometheus text).

See `repro.telemetry.hub` for the probe taxonomy and
`repro.telemetry.export` for the export surfaces.
"""
from repro.telemetry.hub import (
    NULL_HUB,
    Counter,
    Gauge,
    NullHub,
    TelemetryHub,
    Timeline,
    WindowedSeries,
    hist_bin_index,
    hist_bin_upper,
)
from repro.telemetry.export import (
    EVENT_SCHEMA_VERSION,
    chrome_trace,
    export_run,
    prometheus_text,
    read_jsonl,
    series_to_csv,
    series_to_npz,
    start_metrics_server,
    write_chrome_trace,
    write_jsonl,
)

__all__ = [
    "TelemetryHub", "NullHub", "NULL_HUB", "Counter", "Gauge",
    "WindowedSeries", "Timeline", "hist_bin_index", "hist_bin_upper",
    "EVENT_SCHEMA_VERSION", "write_jsonl", "read_jsonl", "chrome_trace",
    "write_chrome_trace", "series_to_csv", "series_to_npz",
    "prometheus_text", "export_run", "start_metrics_server",
]
