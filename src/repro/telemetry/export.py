"""Telemetry exporters: JSONL events, Chrome trace_event JSON,
CSV/npz time-series, and a Prometheus-style text snapshot.

Four surfaces for one hub:

  * `write_jsonl` / `read_jsonl` — the structured event stream, one
    JSON object per line with a metadata header line (schema, counters,
    drop counts). The round-trippable record of *why* things happened
    (gate/wake causes, carbon deferrals, routing justifications).
  * `chrome_trace` / `write_chrome_trace` — Chrome `trace_event` JSON:
    per-core busy / gated / oversubscription spans reconstructed from
    the event stream, loadable in Perfetto (`ui.perfetto.dev`) or
    `chrome://tracing`. pid = machine, tid = core (the per-machine
    oversubscription lane sits at tid = num_cores).
  * `series_to_csv` / `series_to_npz` — windowed series and timelines
    as flat tables / stacked arrays for pandas/matplotlib.
  * `prometheus_text` — text exposition format (counters, gauges, and
    per-series summaries) for the serving path's metrics endpoint;
    `start_metrics_server` serves it over HTTP.

`export_run(hub, directory)` writes all of them with canonical names —
what `run_experiment` calls when `telemetry_opts` carries an
`export_dir`, and what `examples/telemetry_report.py` reads back.
"""
from __future__ import annotations

import json
import math
import os
import threading
from typing import Any, Callable, Iterable

from repro.telemetry.hub import TelemetryHub, hist_bin_upper

__all__ = [
    "EVENT_SCHEMA_VERSION", "write_jsonl", "read_jsonl", "chrome_trace",
    "write_chrome_trace", "series_to_csv", "series_to_npz",
    "prometheus_text", "export_run", "start_metrics_server",
]

#: bumped when the JSONL event layout changes incompatibly
EVENT_SCHEMA_VERSION = 1

# Canonical file names inside an export directory.
EVENTS_FILE = "events.jsonl"
TRACE_FILE = "trace.json"
SERIES_CSV_FILE = "series.csv"
SERIES_NPZ_FILE = "series.npz"
PROM_FILE = "metrics.prom"


# --------------------------------------------------------------------- #
# JSONL event stream
# --------------------------------------------------------------------- #
def write_jsonl(hub: TelemetryHub, path: str) -> None:
    """One JSON object per line: a metadata header, then every retained
    event in emission order."""
    meta = {"kind": "telemetry_meta", "schema": EVENT_SCHEMA_VERSION}
    meta.update(hub.summary())
    with open(path, "w") as f:
        f.write(json.dumps(meta) + "\n")
        for ev in hub.events:
            f.write(json.dumps(ev) + "\n")


def read_jsonl(path: str) -> tuple[dict[str, Any], list[dict[str, Any]]]:
    """Read back a `write_jsonl` stream -> `(meta, events)`."""
    meta: dict[str, Any] = {}
    events: list[dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            if obj.get("kind") == "telemetry_meta":
                schema = obj.get("schema")
                if schema != EVENT_SCHEMA_VERSION:
                    raise ValueError(
                        f"unsupported telemetry schema {schema!r}; this "
                        f"version reads schema {EVENT_SCHEMA_VERSION}")
                meta = obj
            else:
                events.append(obj)
    return meta, events


# --------------------------------------------------------------------- #
# Chrome trace_event JSON (Perfetto / chrome://tracing)
# --------------------------------------------------------------------- #
_US = 1e6   # trace_event timestamps are microseconds


def _span(name: str, cat: str, pid: int, tid: int, t0: float, t1: float,
          args: dict | None = None) -> dict[str, Any]:
    ev = {"name": name, "cat": cat, "ph": "X", "pid": pid, "tid": tid,
          "ts": t0 * _US, "dur": max(t1 - t0, 0.0) * _US}
    if args:
        ev["args"] = args
    return ev


def chrome_trace(events: Iterable[dict[str, Any]],
                 t_end: float | None = None,
                 oversub_tid: int = 1000) -> dict[str, Any]:
    """Reconstruct per-core spans from the structured event stream.

    Pairs `assign`/`promote` -> `release` into *busy* spans, `gate` ->
    `wake` into *gated* spans, and `oversub` -> `promote`/`release`
    into per-task *oversub* spans on a dedicated per-machine lane
    (`tid = oversub_tid`). Spans still open at the end of the stream
    are closed at `t_end` (default: the last event time). Point events
    (`carbon_deferral`, `route`, `phase`) become instants so cause
    records stay visible next to the spans they explain.
    """
    events = list(events)
    if t_end is None:
        t_end = max((e["t"] for e in events), default=0.0)
    out: list[dict[str, Any]] = []
    busy_open: dict[tuple[int, int], tuple[float, int]] = {}
    gate_open: dict[tuple[int, int], tuple[float, str]] = {}
    over_open: dict[tuple[int, int], float] = {}

    for e in events:
        kind = e["kind"]
        t = e["t"]
        m = int(e.get("machine", 0))
        if kind in ("assign", "promote"):
            core = int(e["core"])
            task = int(e["task"])
            busy_open[(m, core)] = (t, task)
            if kind == "promote":
                tkey = (m, task)
                t0 = over_open.pop(tkey, None)
                if t0 is not None:
                    out.append(_span(f"oversub task {task}", "oversub",
                                     m, oversub_tid, t0, t,
                                     {"task": task,
                                      "cause": e.get("cause",
                                                     "promotion")}))
        elif kind == "oversub":
            over_open[(m, int(e["task"]))] = t
        elif kind == "release":
            core = int(e["core"])
            task = int(e["task"])
            if core < 0:
                t0 = over_open.pop((m, task), None)
                if t0 is not None:
                    out.append(_span(f"oversub task {task}", "oversub",
                                     m, oversub_tid, t0, t,
                                     {"task": task}))
                continue
            opened = busy_open.pop((m, core), None)
            if opened is not None:
                out.append(_span(f"task {task}", "busy", m, core,
                                 opened[0], t, {"task": task}))
        elif kind == "gate":
            core = int(e["core"])
            gate_open[(m, core)] = (t, e.get("cause", "policy"))
        elif kind == "wake":
            core = int(e["core"])
            opened = gate_open.pop((m, core), None)
            if opened is not None:
                out.append(_span("gated", "gated", m, core, opened[0], t,
                                 {"gate_cause": opened[1],
                                  "wake_cause": e.get("cause",
                                                      "policy")}))
        elif kind in ("carbon_deferral", "route", "phase"):
            args = {k: v for k, v in e.items()
                    if k not in ("kind", "t", "machine")}
            out.append({"name": kind, "cat": kind, "ph": "i", "s": "p",
                        "pid": m, "tid": 0, "ts": t * _US, "args": args})

    # close spans still open at the end of the horizon
    for (m, core), (t0, task) in busy_open.items():
        out.append(_span(f"task {task}", "busy", m, core, t0, t_end,
                         {"task": task, "open": True}))
    for (m, core), (t0, cause) in gate_open.items():
        out.append(_span("gated", "gated", m, core, t0, t_end,
                         {"gate_cause": cause, "open": True}))
    for (m, task), t0 in over_open.items():
        out.append(_span(f"oversub task {task}", "oversub", m,
                         oversub_tid, t0, t_end,
                         {"task": task, "open": True}))
    out.sort(key=lambda e: e["ts"])
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(hub: TelemetryHub, path: str,
                       t_end: float | None = None) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(hub.events, t_end=t_end), f)
        f.write("\n")


# --------------------------------------------------------------------- #
# time-series tables
# --------------------------------------------------------------------- #
def series_to_csv(hub: TelemetryHub, path: str) -> None:
    """Every windowed series flattened into one long-format CSV:
    `series,t_start,window_s,count,total,mean,min,max`."""
    with open(path, "w") as f:
        f.write("series,t_start,window_s,count,total,mean,min,max\n")
        for name in sorted(hub.series):
            for w in hub.series[name].windows():
                f.write(f"{name},{w['t_start']:.9g},{w['window_s']:.9g},"
                        f"{w['count']},{w['total']:.12g},"
                        f"{w['mean']:.12g},{w['min']:.12g},"
                        f"{w['max']:.12g}\n")


def series_to_npz(hub: TelemetryHub, path: str) -> None:
    """Windowed series and timelines as stacked arrays.

    Per series `<name>`: `series/<name>/t_start|count|total|min|max`.
    Per timeline `<name>`: `timeline/<name>/t` (T,) and
    `timeline/<name>/values` (T, D). Names are sanitized into npz keys
    verbatim (they already avoid '/' ambiguity by convention).
    """
    import numpy as np

    arrays: dict[str, Any] = {}
    for name, s in hub.series.items():
        ws = s.windows()
        arrays[f"series/{name}/t_start"] = np.asarray(
            [w["t_start"] for w in ws])
        arrays[f"series/{name}/count"] = np.asarray(
            [w["count"] for w in ws])
        arrays[f"series/{name}/total"] = np.asarray(
            [w["total"] for w in ws])
        arrays[f"series/{name}/min"] = np.asarray([w["min"] for w in ws])
        arrays[f"series/{name}/max"] = np.asarray([w["max"] for w in ws])
    for name, tl in hub.timelines.items():
        samples = tl.samples()
        arrays[f"timeline/{name}/t"] = np.asarray(
            [t for t, _ in samples])
        arrays[f"timeline/{name}/values"] = np.asarray(
            [v for _, v in samples])
    with open(path, "wb") as f:
        np.savez(f, **arrays)


# --------------------------------------------------------------------- #
# Prometheus-style text snapshot
# --------------------------------------------------------------------- #
def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return f"repro_{out}"


def prometheus_text(hub: TelemetryHub,
                    extra_gauges: dict[str, float] | None = None) -> str:
    """Text exposition snapshot: counters as `_total`, gauges verbatim,
    series as count/sum plus cumulative histogram buckets over the
    retained windows — one metrics surface shared by live serving and
    simulation exports."""
    lines: list[str] = []
    for name, c in sorted(hub.counters.items()):
        n = _prom_name(name)
        lines.append(f"# TYPE {n}_total counter")
        lines.append(f"{n}_total {c.value}")
    gauges = {n: g.value for n, g in hub.gauges.items()}
    if extra_gauges:
        gauges.update(extra_gauges)
    for name in sorted(gauges):
        v = gauges[name]
        if isinstance(v, float) and math.isnan(v):
            continue
        n = _prom_name(name)
        lines.append(f"# TYPE {n} gauge")
        lines.append(f"{n} {v:.10g}")
    for name, s in sorted(hub.series.items()):
        n = _prom_name(name)
        lines.append(f"# TYPE {n} histogram")
        cum = 0
        inf_emitted = False
        for i, c in enumerate(s.merged_bins()):
            if not c:
                continue
            cum += c
            le = hist_bin_upper(i)
            inf_emitted = math.isinf(le)
            le_s = "+Inf" if inf_emitted else f"{le:.6g}"
            lines.append(f'{n}_bucket{{le="{le_s}"}} {cum}')
        if not inf_emitted:   # exposition format requires an +Inf bucket
            lines.append(f'{n}_bucket{{le="+Inf"}} {cum}')
        lines.append(f"{n}_sum {s.total:.10g}")
        lines.append(f"{n}_count {s.count}")
    return "\n".join(lines) + "\n"


def start_metrics_server(snapshot: Callable[[], str], port: int = 0):
    """Serve `snapshot()` at `/metrics` on a daemon thread; returns the
    `HTTPServer` (its `server_port` is the bound port — pass `port=0`
    for an ephemeral one, `shutdown()` to stop)."""
    from http.server import BaseHTTPRequestHandler, HTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):          # noqa: N802 (http.server API)
            if self.path not in ("/metrics", "/"):
                self.send_response(404)
                self.end_headers()
                return
            body = snapshot().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # quiet
            pass

    server = HTTPServer(("127.0.0.1", port), Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    return server


# --------------------------------------------------------------------- #
# one-call export
# --------------------------------------------------------------------- #
def export_run(hub: TelemetryHub, directory: str,
               t_end: float | None = None) -> dict[str, str]:
    """Write every surface into `directory` (created if missing) with
    canonical names; returns `{surface: path}`."""
    os.makedirs(directory, exist_ok=True)
    paths = {
        "events_jsonl": os.path.join(directory, EVENTS_FILE),
        "chrome_trace": os.path.join(directory, TRACE_FILE),
        "series_csv": os.path.join(directory, SERIES_CSV_FILE),
        "series_npz": os.path.join(directory, SERIES_NPZ_FILE),
        "prometheus": os.path.join(directory, PROM_FILE),
    }
    write_jsonl(hub, paths["events_jsonl"])
    write_chrome_trace(hub, paths["chrome_trace"], t_end=t_end)
    series_to_csv(hub, paths["series_csv"])
    series_to_npz(hub, paths["series_npz"])
    with open(paths["prometheus"], "w") as f:
        f.write(prometheus_text(hub))
    return paths
