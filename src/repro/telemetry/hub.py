"""TelemetryHub — typed streaming probes + structured event tracing.

The simulator's headline claims are *temporal* (p99-managed aging
evolution, windowed carbon, wake-deferral causality), but results used
to surface only end-of-run scalars. The hub is the one sink every layer
publishes into:

  * `Counter` / `Gauge`     — monotonic totals and last-value samples.
  * `WindowedSeries`        — ring-buffered fixed-width time windows,
                              each aggregating count/sum/min/max plus a
                              log-bucketed histogram, so quantiles of a
                              signal survive a simulated month in
                              bounded memory (ROADMAP streaming-metrics
                              groundwork).
  * `Timeline`              — ring of `(t, vector)` samples (per-core
                              frequency/dVth snapshots, carbon windows).
  * structured event log    — ring-buffered dicts with cause
                              attribution (`gate` / `wake` / `assign` /
                              `promote` / `oversub` / `carbon_deferral`
                              / `route` / `phase`), the raw stream the
                              JSONL and Chrome-trace exporters replay.

Everything is bounded: events and timelines are `deque(maxlen=...)`
rings, series retain the last `max_windows` windows; overflow counts
are kept (`events_dropped`, per-series `dropped_windows`) so truncation
is visible, never silent.

Zero-cost when disabled: producers hold `None` (or the `NULL_HUB`
no-op) and guard every emission with one attribute test, so the
bit-exact fast-path suites and `BENCH_sim.json` are untouched when
telemetry is off. Recording is pure observation — it never mutates
aging state or draws from simulation RNG streams — so telemetry-ON
runs produce bit-identical `ExperimentResult`s too (pinned in
tests/test_telemetry.py).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Iterable

__all__ = [
    "Counter", "Gauge", "WindowedSeries", "Timeline", "TelemetryHub",
    "NullHub", "NULL_HUB", "DEFAULT_MAX_EVENTS", "DEFAULT_MAX_WINDOWS",
    "DEFAULT_TIMELINE_MAXLEN", "HIST_BINS", "hist_bin_index",
    "hist_bin_upper",
]

DEFAULT_MAX_EVENTS = 200_000
DEFAULT_MAX_WINDOWS = 4096
DEFAULT_TIMELINE_MAXLEN = 4096

# Log-bucketed histogram layout shared by every series: 8 buckets per
# decade across [1e-6, 1e6), plus an underflow bucket (index 0, values
# <= 0 or < 1e-6) and an overflow bucket (last index). 98 buckets total.
_HIST_LO_EXP = -6
_HIST_HI_EXP = 6
_HIST_PER_DECADE = 8
HIST_BINS = (_HIST_HI_EXP - _HIST_LO_EXP) * _HIST_PER_DECADE + 2


def hist_bin_index(v: float) -> int:
    """Bucket index for value `v` under the shared log layout."""
    if v <= 0.0 or v < 10.0 ** _HIST_LO_EXP:
        return 0
    if v >= 10.0 ** _HIST_HI_EXP:
        return HIST_BINS - 1
    return 1 + int((math.log10(v) - _HIST_LO_EXP) * _HIST_PER_DECADE)


def hist_bin_upper(i: int) -> float:
    """Upper edge of bucket `i` (inf for the overflow bucket)."""
    if i <= 0:
        return 10.0 ** _HIST_LO_EXP
    if i >= HIST_BINS - 1:
        return math.inf
    return 10.0 ** (_HIST_LO_EXP + i / _HIST_PER_DECADE)


class Counter:
    """Monotonically increasing probe (`assigns`, `gates`, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Counter({self.name!r}, {self.value})"


class Gauge:
    """Last-value probe (`events_per_sec`, phase wall times, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def __repr__(self) -> str:
        return f"Gauge({self.name!r}, {self.value})"


@dataclasses.dataclass
class _Window:
    """One live aggregation window of a `WindowedSeries`."""

    index: int                      # window number = floor(t / window_s)
    count: int = 0
    total: float = 0.0
    vmin: float = math.inf
    vmax: float = -math.inf
    bins: list[int] = dataclasses.field(
        default_factory=lambda: [0] * HIST_BINS)

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self.bins[hist_bin_index(v)] += 1


class WindowedSeries:
    """Ring-buffered windowed aggregates + quantile sketch of one signal.

    `observe(t, v)` lands `v` in the window `floor(t / window_s)`;
    windows are materialized only when they receive data (a sparse
    signal over a week does not allocate a week of windows), and only
    the most recent `max_windows` are retained — older ones fall off
    the ring, counted in `dropped_windows`. Observation times are
    expected (sim event loops guarantee it) to be non-decreasing; a
    stale `t` still lands correctly if its window is retained and is
    dropped (counted) otherwise.
    """

    __slots__ = ("name", "window_s", "max_windows", "_ring",
                 "dropped_windows", "dropped_observations")

    def __init__(self, name: str, window_s: float = 1.0,
                 max_windows: int = DEFAULT_MAX_WINDOWS):
        if window_s <= 0.0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        if max_windows < 1:
            raise ValueError(f"max_windows must be >= 1, got {max_windows}")
        self.name = name
        self.window_s = window_s
        self.max_windows = max_windows
        self._ring: collections.deque[_Window] = collections.deque()
        self.dropped_windows = 0
        self.dropped_observations = 0

    def observe(self, t: float, v: float) -> None:
        idx = int(t / self.window_s)
        ring = self._ring
        if ring:
            last = ring[-1].index
            if idx < last:
                # Rare out-of-order observation: fold into its window if
                # still retained, else count the drop (never silently).
                for w in reversed(ring):
                    if w.index == idx:
                        w.observe(v)
                        return
                    if w.index < idx:
                        break
                self.dropped_observations += 1
                return
            if idx == last:
                ring[-1].observe(v)
                return
        w = _Window(idx)
        w.observe(v)
        ring.append(w)
        if len(ring) > self.max_windows:
            ring.popleft()
            self.dropped_windows += 1

    # -- read side ----------------------------------------------------- #
    @property
    def count(self) -> int:
        """Observations in the retained windows."""
        return sum(w.count for w in self._ring)

    @property
    def total(self) -> float:
        return sum(w.total for w in self._ring)

    def windows(self) -> list[dict[str, Any]]:
        """Frozen per-window aggregates, oldest retained first."""
        return [{"t_start": w.index * self.window_s,
                 "window_s": self.window_s,
                 "count": w.count, "total": w.total,
                 "mean": w.total / w.count,
                 "min": w.vmin, "max": w.vmax}
                for w in self._ring]

    def merged_bins(self) -> list[int]:
        """Histogram buckets summed over the retained windows."""
        out = [0] * HIST_BINS
        for w in self._ring:
            for i, c in enumerate(w.bins):
                if c:
                    out[i] += c
        return out

    def quantile(self, q: float) -> float:
        """Approximate q-quantile over the retained windows (upper edge
        of the bucket holding the q-th observation; NaN when empty)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        bins = self.merged_bins()
        n = sum(bins)
        if n == 0:
            return float("nan")
        rank = q * (n - 1)
        seen = 0
        for i, c in enumerate(bins):
            seen += c
            if seen > rank:
                return hist_bin_upper(i)
        return hist_bin_upper(HIST_BINS - 1)

    def __repr__(self) -> str:
        return (f"WindowedSeries({self.name!r}, window_s={self.window_s}, "
                f"windows={len(self._ring)})")


class Timeline:
    """Ring of `(t, vector)` samples — per-core frequency/dVth
    snapshots, carbon-window rows. Values are stored as plain tuples so
    exports and round-trips never alias live simulator arrays."""

    __slots__ = ("name", "_ring", "dropped")

    def __init__(self, name: str, maxlen: int = DEFAULT_TIMELINE_MAXLEN):
        if maxlen < 1:
            raise ValueError(f"maxlen must be >= 1, got {maxlen}")
        self.name = name
        self._ring: collections.deque[tuple[float, tuple[float, ...]]] = \
            collections.deque(maxlen=maxlen)
        self.dropped = 0

    def record(self, t: float, values: Iterable[float]) -> None:
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        # Float ndarrays convert through C (`tolist` yields Python
        # floats), skipping the per-element genexpr — same tuples, just
        # cheaper; everything else takes the generic coercion.
        dtype = getattr(values, "dtype", None)
        if dtype is not None and dtype.kind == "f":
            vals = tuple(values.tolist())
        else:
            vals = tuple(float(v) for v in values)
        self._ring.append((float(t), vals))

    def samples(self) -> list[tuple[float, tuple[float, ...]]]:
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    def __repr__(self) -> str:
        return f"Timeline({self.name!r}, points={len(self._ring)})"


class TelemetryHub:
    """The one sink all layers publish probes and events into.

    One hub serves one experiment (cluster + managers + routers +
    runner self-profiling) or one serving engine. Producers cache the
    probe objects they emit into (`hub.counter(...)` at construction),
    so the hot-path cost with telemetry ON is one method call per
    emission and with telemetry OFF exactly one `is not None` test.
    """

    enabled = True

    def __init__(self, window_s: float = 1.0,
                 max_events: int = DEFAULT_MAX_EVENTS,
                 max_windows: int = DEFAULT_MAX_WINDOWS,
                 timeline_every: int = 1,
                 timeline_maxlen: int = DEFAULT_TIMELINE_MAXLEN):
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        if timeline_every < 1:
            raise ValueError(f"timeline_every must be >= 1, got "
                             f"{timeline_every}")
        self.window_s = float(window_s)
        self.max_windows = int(max_windows)
        self.timeline_every = int(timeline_every)
        self.timeline_maxlen = int(timeline_maxlen)
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.series: dict[str, WindowedSeries] = {}
        self.timelines: dict[str, Timeline] = {}
        self.events: collections.deque[dict[str, Any]] = \
            collections.deque(maxlen=int(max_events))
        self.events_dropped = 0

    # -- probe access (producers cache the returned objects) ----------- #
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def get_series(self, name: str,
                   window_s: float | None = None) -> WindowedSeries:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = WindowedSeries(
                name, window_s=window_s or self.window_s,
                max_windows=self.max_windows)
        return s

    def timeline(self, name: str,
                 maxlen: int | None = None) -> Timeline:
        tl = self.timelines.get(name)
        if tl is None:
            tl = self.timelines[name] = Timeline(
                name, maxlen=maxlen or self.timeline_maxlen)
        return tl

    # -- convenience emitters ------------------------------------------ #
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, t: float, v: float) -> None:
        self.get_series(name).observe(t, v)

    def event(self, kind: str, t: float, **fields) -> None:
        """Append one structured event to the ring-buffered log."""
        ev = self.events
        if len(ev) == ev.maxlen:
            self.events_dropped += 1
        fields["kind"] = kind
        fields["t"] = t
        ev.append(fields)

    def push(self, ev: dict[str, Any]) -> None:
        """Hot-path `event()`: append a caller-built event dict (which
        must already carry `"kind"` and `"t"`) without the kwargs
        repack. Same ring, same drop accounting."""
        evq = self.events
        if len(evq) == evq.maxlen:
            self.events_dropped += 1
        evq.append(ev)

    # -- read side ------------------------------------------------------ #
    def summary(self) -> dict[str, Any]:
        """JSON-safe digest of everything the hub holds — the optional
        `ExperimentResult.telemetry_summary` payload. Scalar metrics of
        the run itself never live here (they are result fields); this
        is the map of what was *emitted*."""
        kinds: dict[str, int] = {}
        for e in self.events:
            kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
        return {
            "events": len(self.events),
            "events_dropped": self.events_dropped,
            "event_kinds": dict(sorted(kinds.items())),
            "counters": {n: c.value
                         for n, c in sorted(self.counters.items())},
            "gauges": {n: g.value for n, g in sorted(self.gauges.items())},
            "series": {
                n: {"windows": len(s._ring), "count": s.count,
                    "window_s": s.window_s,
                    "dropped_windows": s.dropped_windows}
                for n, s in sorted(self.series.items())},
            "timelines": {n: {"points": len(tl), "dropped": tl.dropped}
                          for n, tl in sorted(self.timelines.items())},
        }

    @classmethod
    def from_opts(cls, opts: dict[str, Any]) -> "TelemetryHub":
        """Build a hub from `ExperimentConfig.telemetry_options`
        (ignoring runner-level keys like `export_dir`)."""
        kw = {k: v for k, v in opts.items()
              if k in ("window_s", "max_events", "max_windows",
                       "timeline_every", "timeline_maxlen")}
        return cls(**kw)

    def __repr__(self) -> str:
        return (f"TelemetryHub(events={len(self.events)}, "
                f"series={len(self.series)}, "
                f"counters={len(self.counters)})")


class _NullProbe:
    """No-op Counter/Gauge/Series/Timeline stand-in."""

    __slots__ = ()
    name = "null"
    value = 0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, *a, **kw) -> None:
        pass

    def record(self, *a, **kw) -> None:
        pass


_NULL_PROBE = _NullProbe()


class NullHub:
    """No-op hub: every probe accessor returns a shared no-op object and
    every emitter does nothing. Lets API users write unconditional
    `hub.event(...)` code; the simulator's own hot paths use `None` +
    one `is not None` test instead, which is cheaper still."""

    enabled = False

    def counter(self, name: str) -> _NullProbe:
        return _NULL_PROBE

    def gauge(self, name: str) -> _NullProbe:
        return _NULL_PROBE

    def get_series(self, name: str, window_s=None) -> _NullProbe:
        return _NULL_PROBE

    def timeline(self, name: str, maxlen=None) -> _NullProbe:
        return _NULL_PROBE

    def inc(self, name: str, n: int = 1) -> None:
        pass

    def set_gauge(self, name: str, v: float) -> None:
        pass

    def observe(self, name: str, t: float, v: float) -> None:
        pass

    def event(self, kind: str, t: float, **fields) -> None:
        pass

    def push(self, ev: dict[str, Any]) -> None:
        pass

    def summary(self) -> dict[str, Any]:
        return {}

    def __repr__(self) -> str:
        return "NullHub()"


NULL_HUB = NullHub()
