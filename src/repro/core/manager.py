"""CoreManager — the per-server aging-aware CPU core management runtime.

This is the paper's contribution as a deployable component (Fig. 3): it
owns the per-core aging state of one inference server's CPU, routes every
CPU inference task through a pluggable task-to-core policy
(`repro.core.policies`), and applies the working-set corrections the
policy returns from its periodic hook (Selective Core Idling for the
proposed technique).

The manager is policy-agnostic: policies only see a read-only `CoreView`
(masks, dVth, f0, idle history, rng), while the manager keeps exclusive
write access to the NBTI bookkeeping. A core's dVth advances lazily with
the ADF of the (C-state, allocated) regime it was in, and every regime
change first settles the elapsed interval under the old ADF.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import aging, mapping, temperature, variation
from repro.core.policies import CorePolicy, CoreView, get_policy
from repro.core.temperature import CState
from repro.power.residency import ResidencyAccumulator, StateResidency

OVERSUBSCRIBED = -1  # sentinel core id for tasks that didn't get a core

_ACTIVE = int(CState.ACTIVE)
_DEEP_IDLE = int(CState.DEEP_IDLE)


@dataclasses.dataclass
class ManagerMetrics:
    """Accumulated observability for one server's CPU."""

    oversub_task_seconds: float = 0.0   # integral of T_oversub (paper §3.3)
    idle_norm_samples: list = dataclasses.field(default_factory=list)
    active_count_samples: list = dataclasses.field(default_factory=list)
    task_count_samples: list = dataclasses.field(default_factory=list)
    assigns: int = 0
    oversub_assigns: int = 0


class CoreManager:
    def __init__(
        self,
        num_cores: int,
        policy: CorePolicy | str = "proposed",
        aging_params: aging.AgingParams = aging.DEFAULT_PARAMS,
        variation_params: variation.VariationParams | None = None,
        rng: np.random.Generator | None = None,
        idling_period_s: float = 1.0,
        policy_opts: dict | None = None,
        on_promote=None,
        on_demote=None,
        res_window_s: float = 1.0,
        telemetry=None,
        telemetry_id: int = 0,
    ):
        self.num_cores = num_cores
        # Called as on_promote(task_id, core, now, speed) whenever a task
        # leaves the oversubscription queue for a real core, where `speed`
        # is the promoted core's settled frequency factor — the caller can
        # recompute the task's remaining duration (the simulator reschedules
        # its completion event; see `Machine.run_cpu_task`).
        self.on_promote = on_promote
        # Called as on_demote(task_id, now, speed) when the fault layer
        # pushes a task OFF its core (core failure) back into the
        # oversubscription queue — the inverse of on_promote, reusing
        # the same rebanking machinery (`Machine._on_demote`).
        self.on_demote = on_demote
        self.params = aging_params
        self.idling_period_s = idling_period_s
        self.rng = rng if rng is not None else np.random.default_rng(0)
        self.policy = self._resolve_policy(policy, policy_opts)
        vp = variation_params or variation.VariationParams(
            f_nominal=aging_params.f_nominal)
        self.f0 = variation.sample_initial_frequencies(vp, num_cores, self.rng)

        n = num_cores
        self.dvth = np.zeros(n)
        self.c_state = np.full(n, CState.ACTIVE, dtype=np.int8)
        self.task_of_core = np.full(n, -1, dtype=np.int64)   # task id or -1
        self.idle_history = np.zeros((n, mapping.IDLE_HISTORY_LEN))
        # Per-core write cursor into the idle-history ring (plain ints:
        # this is pure event-loop bookkeeping, never consumed as an array).
        self.hist_pos = [0] * n
        self.idle_since = np.zeros(n)        # when core last became unassigned
        self.last_update = np.zeros(n)       # last dvth settlement time
        self.cum_work = np.zeros(n)          # least-aged baseline age proxy
        self.core_of_task: dict[int, int] = {}
        self.task_start: dict[int, float] = {}
        self.oversub_tasks: set[int] = set()
        # task -> sim time up to which its oversubscribed wait has been
        # added to `metrics.oversub_task_seconds` (each second of the
        # T_oversub integral is counted exactly once).
        self._oversub_accounted: dict[int, float] = {}
        self.metrics = ManagerMetrics()
        self.now = 0.0
        self._view = CoreView(self)

        # ---- event-loop fast-path state (see "incremental indices") ---- #
        # Per-core idle score kept in lockstep with `idle_history`
        # (bit-identical to `mapping.idle_scores`, see `_record_idle_end`).
        self.idle_score = np.zeros(n)
        # Lazy max-heap over free working-set cores: entries are
        # (-idle_score, core, stamp). `_stamp[core]` increments on every
        # eligibility transition (assign / release / gate / wake), so any
        # entry whose stamp is stale is garbage and is dropped at peek
        # time. Ordering matches `mapping.select_core` exactly: highest
        # score first, ties to the lowest core index.
        self._free_heap: list[tuple[float, int, int]] = \
            [(-0.0, i, 0) for i in range(n)]
        self._stamp: list[int] = [0] * n
        # Cores currently running a task (the oversubscribed-speed bound
        # only needs these; maintained O(1) per assign/release).
        self._busy_cores: set[int] = set()
        # Regime ADFs precomputed once per manager. `_adf_settle` mirrors
        # the scalar settle path (`K * adf_unscaled_cached`); the busy
        # constant mirrors the vectorized `aging.adf` the oversubscribed
        # bound historically flowed through — the two derivations differ
        # in multiplication order and may differ in the last ulp, so each
        # fast path keeps its own to stay bit-exact.
        p = self.params
        self._adf_settle = tuple(
            tuple(p.K * aging.adf_unscaled_cached(
                p, temperature.core_temperature_c(CState(cs), alloc),
                temperature.core_stress(CState(cs), alloc))
                for alloc in (False, True))
            for cs in (_ACTIVE, _DEEP_IDLE))
        self._adf_busy_vec = float(aging.adf(
            p, np.float64(temperature.TEMP_ACTIVE_ALLOCATED_C),
            np.float64(temperature.STRESS_ACTIVE)))
        self._inv_n = 1.0 / p.n
        self._n_exp = p.n
        self._headroom = p.headroom
        # C-state residency integrals for the power models. Pure additive
        # bookkeeping driven off the busy set + gated count: it never reads
        # or reorders the aging math, so the settle paths stay bit-exact.
        self.residency_acc = ResidencyAccumulator(n, window_s=res_window_s)
        self._n_gated = 0
        # task -> settled frequency factor it runs at (assign/promote
        # time); consumed on release for frequency-weighted busy time.
        self._task_speed: dict[int, float] = {}
        # ---- fault layer (repro.faults) ---- #
        # Permanently offlined cores (guardband failures). A failed core
        # is held in DEEP_IDLE (power-fenced: NBTI stress ends, so its
        # aging freezes — matching the frozen-ADF treatment of gated
        # cores) and never re-enters the free heap or wake candidates.
        self.failed = np.zeros(n, dtype=bool)
        # core -> transient slowdown factor; empty dict == zero cost on
        # the assign hot path (one falsy check per assign).
        self._stalls: dict[int, float] = {}
        # Telemetry sink (repro.telemetry.TelemetryHub) or None. Hot
        # paths guard every emission with one `is not None` test so the
        # disabled cost is exactly that test — recording is pure
        # observation and never touches aging state or the RNG.
        self._tel = telemetry if (
            telemetry is not None and getattr(telemetry, "enabled", True)
        ) else None
        self._tel_id = int(telemetry_id)
        self._tel_tick = 0
        if self._tel is not None:
            # Cache the probe objects once (the hub idiom): emissions
            # become one bound-method call, not a name lookup per event.
            tel, mid = self._tel, self._tel_id
            self._c_assigns = tel.counter("assigns")
            self._c_oversub_assigns = tel.counter("oversub_assigns")
            self._c_promotions = tel.counter("promotions")
            self._c_gates = tel.counter("gates")
            self._c_wakes = tel.counter("wakes")
            self._c_deferrals = tel.counter("carbon_deferrals")
            self._s_active = tel.get_series(f"m{mid}/active_cores")
            self._s_oversub = tel.get_series(f"m{mid}/oversub_tasks")
            self._tl_freq = tel.timeline(f"m{mid}/freq")
            self._tl_dvth = tel.timeline(f"m{mid}/dvth")
            self._tl_cstate = tel.timeline(f"m{mid}/cstate")

    @staticmethod
    def _resolve_policy(policy, policy_opts) -> CorePolicy:
        if isinstance(policy, CorePolicy):
            if policy_opts:
                raise TypeError("policy_opts only applies when the policy "
                                "is given by name; pass the options to the "
                                "constructor of your CorePolicy instance "
                                "instead")
            return policy
        return get_policy(policy, **dict(policy_opts or {}))

    @property
    def policy_name(self) -> str:
        return self.policy.name

    @property
    def view(self) -> CoreView:
        """Read-only view of this manager's per-core state."""
        return self._view

    # ------------------------------------------------------------------ #
    # aging bookkeeping
    # ------------------------------------------------------------------ #
    def _settle(self, i: int, now: float) -> None:
        """Advance core i's dVth from last_update to `now` under its
        current regime. Must be called BEFORE any regime change.

        numpy-free scalar path: the regime ADF comes from the per-manager
        `_adf_settle` table (same value `K * adf_unscaled_cached` returned
        per call before, minus the enum + dict-hash round trips), and the
        recursive update is `aging.advance_dvth_scalar` inlined on plain
        floats (`.item()` reads skip numpy-scalar boxing)."""
        tau = now - self.last_update.item(i)
        if tau > 0.0:
            a = self._adf_settle[self.c_state.item(i)][
                1 if self.task_of_core.item(i) >= 0 else 0]
            if a > 0.0:
                d = self.dvth.item(i)
                self.dvth[i] = a * ((d / a) ** self._inv_n + tau) \
                    ** self._n_exp
            self.last_update[i] = now

    # ------------------------------------------------------------------ #
    # incremental indices (event-loop fast paths)
    # ------------------------------------------------------------------ #
    def _record_idle_end(self, core: int, idle_duration: float) -> None:
        """`mapping.record_idle_end` + incremental idle-score update."""
        h = self.idle_history
        pos = self.hist_pos[core]
        h[core, pos % mapping.IDLE_HISTORY_LEN] = idle_duration
        self.hist_pos[core] = pos + 1
        # Recompute the row's score with numpy's pairwise-summation tree
        # for 8 elements, so the cached score stays bit-identical to
        # `mapping.idle_scores` (a plain left-to-right sum would drift
        # by ulps and could flip argmax ties).
        if mapping.IDLE_HISTORY_LEN == 8:
            r0, r1, r2, r3, r4, r5, r6, r7 = h[core].tolist()
            self.idle_score[core] = (
                ((r0 + r1) + (r2 + r3)) + ((r4 + r5) + (r6 + r7)))
        else:
            self.idle_score[core] = float(h[core].sum())

    def _peek_best_free(self) -> int:
        """Free working-set core with the highest idle score, or -1.

        Equivalent to `mapping.select_core(active, assigned,
        idle_history)` — including first-index tie-breaking — but served
        from the lazy heap in O(log n) amortized. Stale entries (stamp
        mismatch, or state flipped behind the manager's back) are
        discarded on the way; the returned core stays in the heap until
        an eligibility transition invalidates it."""
        h = self._free_heap
        stamp = self._stamp
        c_state = self.c_state
        task_of_core = self.task_of_core
        while h:
            _, core, st = h[0]
            if (st != stamp[core] or c_state.item(core) != _ACTIVE
                    or task_of_core.item(core) >= 0):
                heapq.heappop(h)
                continue
            return core
        return -1

    def _push_free(self, core: int) -> None:
        """Core just became eligible (free + working set): index it."""
        stamp = self._stamp[core] + 1
        self._stamp[core] = stamp
        heapq.heappush(self._free_heap,
                       (-self.idle_score.item(core), core, stamp))

    def _mark_busy(self, core: int, task_id: int, now: float) -> None:
        """Shared assign/promote tail: settle the ended idle window and
        hand the core to `task_id` (invalidates its free-heap entry)."""
        idle_dur = now - self.idle_since.item(core)
        self._record_idle_end(core, idle_dur if idle_dur > 0.0 else 0.0)
        self._settle(core, now)          # settle idle regime
        # Bank the interval's residency under the old counts before the
        # busy set grows (same settle-before-change rule as the aging).
        self.residency_acc.advance(now, len(self._busy_cores),
                                   self._n_gated)
        self.task_of_core[core] = task_id
        self.core_of_task[task_id] = core
        self.task_start[task_id] = now
        self._stamp[core] += 1
        self._busy_cores.add(core)

    def _busy_max_frequency(self, now: float) -> float:
        """Settled frequency of the fastest *busy* core at `now` — the
        oversubscribed-task speed bound — without building fleet-wide
        settled arrays. Bit-identical to masking
        `aging.frequency(params, f0, _settled_dvth(now))` to busy cores
        (pinned by tests/test_fastpath.py): busy cores all share the
        (C0, allocated) regime, so one vectorized-derivation ADF
        constant plus the same ufunc chain over just the busy *subset*
        reproduces the old full-fleet computation. (A pure-scalar loop
        would not: numpy's array `**` and libm's scalar `**` disagree by
        an ulp on some inputs, so the advance must stay a ufunc.)"""
        if not self._busy_cores:
            # Pure promotion race: nothing busy, fall back to the
            # fleet-wide settled maximum (rare; keep the vectorized path).
            freqs = aging.frequency(self.params, self.f0,
                                    self._settled_dvth(now))
            return float(np.max(freqs))
        idx = np.fromiter(self._busy_cores, dtype=np.intp,
                          count=len(self._busy_cores))
        a = self._adf_busy_vec
        tau = np.maximum(now - self.last_update[idx], 0.0)
        d = self.dvth[idx]
        new = a * ((d / a) ** self._inv_n + tau) ** self._n_exp
        settled = np.where(tau > 0.0, new, d)
        return float(np.max(self.f0[idx]
                            * (1.0 - settled / self._headroom)))

    def _settled_dvth(self, now: float) -> np.ndarray:
        """Every core's dVth advanced to `now` under its current regime,
        WITHOUT mutating state (pure; also backs `CoreView.dvth_now`)."""
        tau = np.maximum(now - self.last_update, 0.0)
        temps, stress = temperature.regime_arrays(self.c_state,
                                                  self.task_of_core >= 0)
        adf_vals = aging.adf(self.params, temps, stress)
        return aging.advance_dvth(self.params, self.dvth, adf_vals, tau)

    def settle_all(self, now: float) -> None:
        """Vectorized settlement of every core (used by the periodic path
        and by metric snapshots; mirrors the Pallas aging_update kernel)."""
        self.residency_acc.advance(now, len(self._busy_cores),
                                   self._n_gated)
        if not (now - self.last_update > 0).any():
            self.now = max(self.now, now)
            return
        self.dvth = self._settled_dvth(now)
        self.last_update = np.maximum(self.last_update, now)
        self.now = max(self.now, now)

    # ------------------------------------------------------------------ #
    # task lifecycle
    # ------------------------------------------------------------------ #
    def assign(self, task_id: int, now: float) -> float:
        """Route one CPU inference task to a core via the policy.

        Returns the execution speed factor (degraded f / nominal f) the
        simulator should apply to the task duration; oversubscribed tasks
        additionally share cores, handled by the caller via load factor.
        """
        if now > self.now:
            self.now = now
        self.metrics.assigns += 1
        core = self.policy.select_core(self._view)

        if core < 0:
            self.oversub_tasks.add(task_id)
            self.core_of_task[task_id] = OVERSUBSCRIBED
            self.task_start[task_id] = now
            self._oversub_accounted[task_id] = now
            self.metrics.oversub_assigns += 1
            tel = self._tel
            if tel is not None:
                self._c_oversub_assigns.inc()
                tel.push({"kind": "oversub", "t": now,
                          "machine": self._tel_id, "task": task_id,
                          "cause": "oversubscription",
                          "waiting": len(self.oversub_tasks)})
            # Oversubscribed tasks time-share already-busy cores, so the
            # settled frequency of the fastest *busy* core bounds their
            # speed — pristine idle (or power-gated) cores are not
            # executing anything and must not inflate the bound. Only
            # when no core is busy at all (pure promotion races) fall
            # back to the fleet-wide settled maximum.
            return self._busy_max_frequency(now)

        # End the core's idle period -> record idle duration (Alg. 1 input).
        self._mark_busy(core, task_id, now)
        # aging.frequency_scalar inlined (Eq. 1) on plain floats.
        speed = self.f0.item(core) * (1.0 - self.dvth.item(core)
                                      / self._headroom)
        if self._stalls:
            stall = self._stalls.get(core)
            if stall is not None:
                speed *= stall
        self._task_speed[task_id] = speed
        tel = self._tel
        if tel is not None:
            self._c_assigns.inc()
            tel.push({"kind": "assign", "t": now, "machine": self._tel_id,
                      "core": core, "task": task_id, "speed": speed})
        return speed

    def release(self, task_id: int, now: float) -> None:
        if now > self.now:
            self.now = now
        core = self.core_of_task.pop(task_id, None)
        start = self.task_start.pop(task_id, now)
        if core is None:
            return
        if core == OVERSUBSCRIBED:
            self.oversub_tasks.discard(task_id)
            self._task_speed.pop(task_id, None)
            self._account_oversub(task_id, now)
            if self._tel is not None:
                self._tel.push({"kind": "release", "t": now,
                                "machine": self._tel_id, "core": -1,
                                "task": task_id})
            if self.oversub_tasks:
                self._promote_oversubscribed(now)
            return
        self._settle(core, now)          # settle allocated regime
        self.cum_work[core] += now - start
        speed = self._task_speed.pop(task_id, None)
        if speed is not None:
            self.residency_acc.add_busy_frequency(speed, now - start)
        self.residency_acc.advance(now, len(self._busy_cores),
                                   self._n_gated)
        self.task_of_core[core] = -1
        self._busy_cores.discard(core)
        self.idle_since[core] = now
        self._push_free(core)
        if self._tel is not None:
            self._tel.push({"kind": "release", "t": now,
                            "machine": self._tel_id, "core": core,
                            "task": task_id})
        self.policy.on_release(self._view, core)
        if self.oversub_tasks:
            self._promote_oversubscribed(now)

    def _account_oversub(self, task_id: int, now: float,
                         final: bool = True) -> None:
        """Add `task_id`'s not-yet-counted oversubscribed wait to the
        T_oversub integral. `final=False` keeps the task in the books
        (periodic accrual for still-waiting tasks)."""
        since = (self._oversub_accounted.pop(task_id, now) if final
                 else self._oversub_accounted.get(task_id, now))
        self.metrics.oversub_task_seconds += max(now - since, 0.0)
        if not final:
            self._oversub_accounted[task_id] = now

    def _promote_oversubscribed(self, now: float) -> None:
        """When a core frees up, move a waiting oversubscribed task onto it.

        Promotion is manager-internal FIFO and always uses the Algorithm-1
        idle-score mapping (not the policy): a promoted task usually has
        exactly one candidate core — the one that just freed.
        """
        while self.oversub_tasks:
            core = self._peek_best_free()
            if core < 0:
                return
            task_id = min(self.oversub_tasks)  # FIFO by id (ids are ordered)
            self.oversub_tasks.discard(task_id)
            self._account_oversub(task_id, now)
            self._mark_busy(core, task_id, now)
            speed = aging.frequency_scalar(
                self.params, float(self.f0[core]), float(self.dvth[core]))
            if self._stalls:
                stall = self._stalls.get(core)
                if stall is not None:
                    speed *= stall
            self._task_speed[task_id] = speed
            if self._tel is not None:
                self._c_promotions.inc()
                self._tel.push({"kind": "promote", "t": now,
                                "machine": self._tel_id, "core": core,
                                "task": task_id, "speed": speed,
                                "cause": "promotion"})
            if self.on_promote is not None:
                self.on_promote(task_id, core, now, speed)

    # ------------------------------------------------------------------ #
    # fault layer (repro.faults — only called when faults are active)
    # ------------------------------------------------------------------ #
    def fail_core(self, core: int, now: float) -> None:
        """Permanently offline `core` (guardband violation): settle its
        aging, power-fence it (DEEP_IDLE — NBTI stress ends), and demote
        any in-flight task back into the oversubscription queue so the
        promotion machinery migrates it to a surviving core."""
        if self.failed.item(core):
            return
        self._settle(core, now)
        self.residency_acc.advance(now, len(self._busy_cores),
                                   self._n_gated)
        self.failed[core] = True
        self._stalls.pop(core, None)
        tid = int(self.task_of_core.item(core))
        self.c_state[core] = CState.DEEP_IDLE
        self._stamp[core] += 1           # drop any free-heap entry
        if tid >= 0:
            self.task_of_core[core] = -1
            self._busy_cores.discard(core)
            self.cum_work[core] += now - self.task_start.get(tid, now)
            self.core_of_task[tid] = OVERSUBSCRIBED
            self.oversub_tasks.add(tid)
            self._oversub_accounted[tid] = now
            self._task_speed.pop(tid, None)
            if self.on_demote is not None:
                # Same speed bound oversubscribed assigns get: the
                # fastest surviving busy core's settled frequency.
                self.on_demote(tid, now, self._busy_max_frequency(now))
        self._n_gated = int((self.c_state == CState.DEEP_IDLE).sum())
        if self.oversub_tasks:
            # Migration = demotion + immediate promotion when a free
            # core exists (the PR-4 rebanking path reschedules it).
            self._promote_oversubscribed(now)

    def crash(self, now: float) -> None:
        """Machine lost power: every in-flight task dies, all cores
        power down (DEEP_IDLE — aging freezes while the machine is
        dark). The caller (cluster fault layer) owns request retries and
        the eventual `reboot`."""
        self.settle_all(now)
        for tid in list(self.oversub_tasks):
            self._account_oversub(tid, now)
        self.oversub_tasks.clear()
        for tid, core in self.core_of_task.items():
            if core >= 0:
                self.cum_work[core] += now - self.task_start.get(tid, now)
        self.core_of_task.clear()
        self.task_start.clear()
        self._task_speed.clear()
        self._stalls.clear()
        self._oversub_accounted.clear()
        self.task_of_core[:] = -1
        self._busy_cores.clear()
        self.c_state[:] = CState.DEEP_IDLE
        for i in range(self.num_cores):
            self._stamp[i] += 1
        self._n_gated = self.num_cores

    def reboot(self, now: float) -> None:
        """Power restored after `crash`: wake every surviving core into
        a fresh-boot working set (the policy re-gates on its next
        periodic); failed cores stay fenced."""
        self.settle_all(now)
        up = np.flatnonzero(~self.failed)
        self.c_state[~self.failed] = CState.ACTIVE
        self.idle_since[:] = now
        for i in up:
            self._push_free(int(i))
        self._n_gated = int(self.failed.sum())

    def set_core_slowdown(self, core: int, now: float,
                          factor: float) -> None:
        """Transient stall: new assigns on `core` run at `factor` x its
        settled speed, and any in-flight task is re-rated through the
        promotion rebanking callback (bank progress, reschedule)."""
        self._stalls[core] = factor
        self._rerate_core(core, now, factor)

    def clear_core_slowdown(self, core: int, now: float) -> None:
        """Stall expired: restore full speed (re-rates in-flight work)."""
        if self._stalls.pop(core, None) is not None:
            self._rerate_core(core, now, 1.0)

    def _rerate_core(self, core: int, now: float, factor: float) -> None:
        tid = int(self.task_of_core.item(core))
        if tid < 0:
            return
        self._settle(core, now)
        speed = aging.frequency_scalar(
            self.params, float(self.f0[core]), float(self.dvth[core])) \
            * factor
        self._task_speed[tid] = speed
        if self.on_promote is not None:
            self.on_promote(tid, core, now, speed)

    # ------------------------------------------------------------------ #
    # periodic control + metrics
    # ------------------------------------------------------------------ #
    def periodic(self, now: float) -> None:
        """Run once per idling period: settle aging accurately, sample
        metrics, and apply the policy's working-set correction (Selective
        Core Idling for the proposed technique; baselines return None)."""
        self.settle_all(now)
        n = self.num_cores
        active = int((self.c_state == CState.ACTIVE).sum())
        assigned = int((self.task_of_core >= 0).sum())
        oversub = len(self.oversub_tasks)
        self.metrics.idle_norm_samples.append((active - assigned - oversub) / n)
        self.metrics.active_count_samples.append(active)
        self.metrics.task_count_samples.append(assigned + oversub)
        # Keep the T_oversub integral live for still-waiting tasks; the
        # remainder of each wait is added at release/promotion, so no
        # second is ever counted twice.
        for task_id in self.oversub_tasks:
            self._account_oversub(task_id, now, final=False)

        tel = self._tel
        if tel is not None:
            self._s_active.observe(now, active)
            self._s_oversub.observe(now, oversub)
            self._tel_tick += 1
            if self._tel_tick % tel.timeline_every == 0:
                # settle_all just ran, so dvth is settled to `now`;
                # frequency() here is a pure read of Eq. 1.
                self._record_timelines(now)

        corr = self.policy.periodic(self._view)
        if corr is None:
            return
        # Validate BEFORE mutating: a partial application would leave the
        # manager's bookkeeping corrupted, the exact failure mode the
        # read-only CoreView exists to prevent.
        busy = np.asarray(corr.to_idle)[
            self.task_of_core[corr.to_idle] >= 0] if len(corr.to_idle) else []
        if len(busy):
            raise ValueError(f"policy {self.policy.name!r} tried to idle "
                             f"cores {[int(i) for i in busy]} while they "
                             f"run tasks")
        cause = getattr(corr, "cause", "policy")
        deferred = getattr(corr, "deferred_wakes", 0)
        for i in corr.to_idle:
            # settle_all already brought core i to `now`; close its idle
            # window and power-gate.
            i = int(i)
            idle_dur = now - self.idle_since[i]
            self._record_idle_end(i, idle_dur if idle_dur > 0.0 else 0.0)
            self.c_state[i] = CState.DEEP_IDLE
            self._stamp[i] += 1          # no longer in the free-core heap
            if tel is not None:
                self._c_gates.inc()
                tel.push({"kind": "gate", "t": now,
                          "machine": self._tel_id, "core": i,
                          "cause": cause})
        for i in corr.to_wake:
            i = int(i)
            if self.failed.item(i):
                # Policies see `CoreView.failed_mask`, but a custom
                # policy that ignores it must still never resurrect a
                # failed core.
                continue
            self.c_state[i] = CState.ACTIVE
            self.idle_since[i] = now
            self._push_free(i)
            if tel is not None:
                self._c_wakes.inc()
                tel.push({"kind": "wake", "t": now,
                          "machine": self._tel_id, "core": i,
                          "cause": cause})
        if tel is not None and deferred:
            self._c_deferrals.inc(deferred)
            tel.push({"kind": "carbon_deferral", "t": now,
                      "machine": self._tel_id, "deferred": deferred,
                      "oversub": oversub,
                      "cause": "carbon-aware-deferral"})
        # settle_all already advanced the residency clock to `now`, so the
        # gated-count change takes effect from this instant. Recount from
        # c_state (not a +/- delta) so nonstandard corrections can't drift
        # the residency books.
        self._n_gated = int((self.c_state == CState.DEEP_IDLE).sum())
        if len(corr.to_wake):
            self._promote_oversubscribed(now)

    # ------------------------------------------------------------------ #
    # observability
    # ------------------------------------------------------------------ #
    def _record_timelines(self, now: float) -> None:
        """Per-core aging/frequency/regime snapshot into the cached
        hub timelines (called from `periodic` after `settle_all`, so
        `dvth` is already settled to `now`; pure reads, no mutation)."""
        freq = aging.frequency(self.params, self.f0, self.dvth)
        self._tl_freq.record(now, freq)
        self._tl_dvth.record(now, self.dvth)
        self._tl_cstate.record(now, self.c_state.astype(np.float64))

    def _frequencies_now(self, settle: bool = True) -> np.ndarray:
        if settle:
            self.settle_all(self.now)
        return aging.frequency(self.params, self.f0, self.dvth)

    def frequencies(self, now: float | None = None) -> np.ndarray:
        if now is not None:
            self.settle_all(now)
        return self._frequencies_now(settle=False)

    def frequency_cv(self, now: float | None = None) -> float:
        f = self.frequencies(now)
        return float(np.std(f) / np.mean(f))

    def mean_frequency_degradation(self, now: float | None = None) -> float:
        f = self.frequencies(now)
        return float(np.mean(self.f0 - f))

    def residency(self, now: float | None = None) -> StateResidency:
        """Frozen core-state residency record up to `now` (default: the
        manager's current time). Advances only the residency clock —
        the aging state is untouched."""
        t = self.now if now is None else now
        self.residency_acc.advance(t, len(self._busy_cores), self._n_gated)
        return self.residency_acc.snapshot()

    def snapshot(self) -> dict:
        f = self._frequencies_now(settle=False)
        return {
            "f0": self.f0.copy(),
            "f": f,
            "dvth": self.dvth.copy(),
            "active": (self.c_state == CState.ACTIVE).copy(),
            "cv": float(np.std(f) / np.mean(f)),
            "mean_degradation": float(np.mean(self.f0 - f)),
        }
