"""CoreManager — the per-server aging-aware CPU core management runtime.

This is the paper's contribution as a deployable component (Fig. 3): it
owns the per-core aging state of one inference server's CPU, routes every
CPU inference task through a task-to-core policy, and (for the proposed
technique) periodically runs Selective Core Idling.

Policies:
  * PROPOSED   — Algorithm 1 mapping + Algorithm 2 selective idling.
  * LINUX      — probabilistic task->core model of a stock Linux LLM
                 inference server (built from captured CPU data, paper
                 §6.1.1); all cores always C0.
  * LEAST_AGED — Zhao'23: assign away from aged cores using cumulative
                 executed work as the age estimate; all cores always C0.

The manager is exact about NBTI bookkeeping: a core's dVth advances lazily
with the ADF of the (C-state, allocated) regime it was in, and every
regime change first settles the elapsed interval under the old ADF.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core import aging, idling, mapping, temperature, variation
from repro.core.temperature import CState


class Policy(enum.Enum):
    PROPOSED = "proposed"
    LINUX = "linux"
    LEAST_AGED = "least-aged"


OVERSUBSCRIBED = -1  # sentinel core id for tasks that didn't get a core


@dataclasses.dataclass
class ManagerMetrics:
    """Accumulated observability for one server's CPU."""

    oversub_task_seconds: float = 0.0   # integral of T_oversub (paper §3.3)
    idle_norm_samples: list = dataclasses.field(default_factory=list)
    active_count_samples: list = dataclasses.field(default_factory=list)
    task_count_samples: list = dataclasses.field(default_factory=list)
    assigns: int = 0
    oversub_assigns: int = 0


class CoreManager:
    def __init__(
        self,
        num_cores: int,
        policy: Policy = Policy.PROPOSED,
        aging_params: aging.AgingParams = aging.DEFAULT_PARAMS,
        variation_params: variation.VariationParams | None = None,
        rng: np.random.Generator | None = None,
        idling_period_s: float = 1.0,
        linux_stickiness: float = 0.3,
    ):
        self.num_cores = num_cores
        self.policy = policy
        self.params = aging_params
        self.idling_period_s = idling_period_s
        self.rng = rng if rng is not None else np.random.default_rng(0)
        vp = variation_params or variation.VariationParams(
            f_nominal=aging_params.f_nominal)
        self.f0 = variation.sample_initial_frequencies(vp, num_cores, self.rng)

        n = num_cores
        self.dvth = np.zeros(n)
        self.c_state = np.full(n, CState.ACTIVE, dtype=np.int8)
        self.task_of_core = np.full(n, -1, dtype=np.int64)   # task id or -1
        self.idle_history = np.zeros((n, mapping.IDLE_HISTORY_LEN))
        self.hist_pos = np.zeros(n, dtype=np.int64)
        self.idle_since = np.zeros(n)        # when core last became unassigned
        self.last_update = np.zeros(n)       # last dvth settlement time
        self.cum_work = np.zeros(n)          # least-aged baseline age proxy
        self.core_of_task: dict[int, int] = {}
        self.task_start: dict[int, float] = {}
        self.oversub_tasks: set[int] = set()
        self.linux_stickiness = linux_stickiness
        self._linux_last_core = -1
        self.metrics = ManagerMetrics()
        self.now = 0.0

    # ------------------------------------------------------------------ #
    # aging bookkeeping
    # ------------------------------------------------------------------ #
    def _regime(self, i: int) -> tuple[float, float]:
        """(temperature C, stress Y) of core i's current regime."""
        cs = CState(int(self.c_state[i]))
        allocated = self.task_of_core[i] >= 0
        return (temperature.core_temperature_c(cs, allocated),
                temperature.core_stress(cs, allocated))

    def _settle(self, i: int, now: float) -> None:
        """Advance core i's dVth from last_update to `now` under its
        current regime. Must be called BEFORE any regime change."""
        tau = now - self.last_update[i]
        if tau > 0.0:
            t_c, y = self._regime(i)
            a = self.params.K * _adf_unscaled_cached(self.params, t_c) if y > 0 else 0.0
            self.dvth[i] = aging.advance_dvth_scalar(
                self.params, float(self.dvth[i]), a, tau)
            self.last_update[i] = now

    def settle_all(self, now: float) -> None:
        """Vectorized settlement of every core (used by the periodic path
        and by metric snapshots; mirrors the Pallas aging_update kernel)."""
        tau = now - self.last_update
        if not (tau > 0).any():
            self.now = max(self.now, now)
            return
        allocated = self.task_of_core >= 0
        active = self.c_state == CState.ACTIVE
        temps = np.where(
            active,
            np.where(allocated, temperature.TEMP_ACTIVE_ALLOCATED_C,
                     temperature.TEMP_ACTIVE_UNALLOCATED_C),
            temperature.TEMP_DEEP_IDLE_C,
        )
        stress = np.where(active, temperature.STRESS_ACTIVE,
                          temperature.STRESS_DEEP_IDLE)
        adf_vals = aging.adf(self.params, temps, stress)
        self.dvth = aging.advance_dvth(self.params, self.dvth, adf_vals,
                                       np.maximum(tau, 0.0))
        self.last_update = np.maximum(self.last_update, now)
        self.now = max(self.now, now)

    # ------------------------------------------------------------------ #
    # task lifecycle
    # ------------------------------------------------------------------ #
    def assign(self, task_id: int, now: float) -> float:
        """Route one CPU inference task to a core (Algorithm 1 / baseline).

        Returns the execution speed factor (degraded f / nominal f) the
        simulator should apply to the task duration; oversubscribed tasks
        additionally share cores, handled by the caller via load factor.
        """
        self.now = max(self.now, now)
        self.metrics.assigns += 1
        active_mask = self.c_state == CState.ACTIVE
        assigned_mask = self.task_of_core >= 0

        if self.policy is Policy.PROPOSED:
            core = mapping.select_core(active_mask, assigned_mask,
                                       self.idle_history)
        elif self.policy is Policy.LEAST_AGED:
            core = self._select_least_work(active_mask, assigned_mask)
        else:
            core = self._select_linux(active_mask, assigned_mask)

        if core < 0:
            self.oversub_tasks.add(task_id)
            self.core_of_task[task_id] = OVERSUBSCRIBED
            self.task_start[task_id] = now
            self.metrics.oversub_assigns += 1
            # Oversubscribed tasks time-share already-busy cores; nominal
            # frequency of the fastest busy core bounds their speed.
            return float(np.max(self._frequencies_now(settle=False)))

        # End the core's idle period -> record idle duration (Alg. 1 input).
        idle_dur = now - self.idle_since[core]
        mapping.record_idle_end(self.idle_history, self.hist_pos, core,
                                max(idle_dur, 0.0))
        self._settle(core, now)          # settle idle regime
        self.task_of_core[core] = task_id
        self.core_of_task[task_id] = core
        self.task_start[task_id] = now
        return aging.frequency_scalar(self.params, float(self.f0[core]),
                                      float(self.dvth[core]))

    def release(self, task_id: int, now: float) -> None:
        self.now = max(self.now, now)
        core = self.core_of_task.pop(task_id, None)
        start = self.task_start.pop(task_id, now)
        if core is None:
            return
        if core == OVERSUBSCRIBED:
            self.oversub_tasks.discard(task_id)
            self.metrics.oversub_task_seconds += now - start
            self._promote_oversubscribed(now)
            return
        self._settle(core, now)          # settle allocated regime
        self.cum_work[core] += now - start
        self.task_of_core[core] = -1
        self.idle_since[core] = now
        self._promote_oversubscribed(now)

    def _promote_oversubscribed(self, now: float) -> None:
        """When a core frees up, move a waiting oversubscribed task onto it."""
        while self.oversub_tasks:
            active_mask = self.c_state == CState.ACTIVE
            assigned_mask = self.task_of_core >= 0
            free = active_mask & ~assigned_mask
            if not free.any():
                return
            task_id = min(self.oversub_tasks)  # FIFO by id (ids are ordered)
            self.oversub_tasks.discard(task_id)
            self.metrics.oversub_task_seconds += now - self.task_start[task_id]
            core = mapping.select_core(active_mask, assigned_mask,
                                       self.idle_history)
            idle_dur = now - self.idle_since[core]
            mapping.record_idle_end(self.idle_history, self.hist_pos, core,
                                    max(idle_dur, 0.0))
            self._settle(core, now)
            self.task_of_core[core] = task_id
            self.core_of_task[task_id] = core
            self.task_start[task_id] = now

    # ------------------------------------------------------------------ #
    # baseline selectors
    # ------------------------------------------------------------------ #
    def _select_least_work(self, active_mask, assigned_mask) -> int:
        cand = active_mask & ~assigned_mask
        if not cand.any():
            return -1
        return int(np.argmin(np.where(cand, self.cum_work, np.inf)))

    def _select_linux(self, active_mask, assigned_mask) -> int:
        """Probabilistic model of stock-Linux task placement: CFS mostly
        picks an idle core but exhibits cache-affinity stickiness (captured
        distribution per Wilkins'24 is skewed, not uniform)."""
        cand = np.flatnonzero(active_mask & ~assigned_mask)
        if cand.size == 0:
            return -1
        last = self._linux_last_core
        if last in cand and self.rng.random() < self.linux_stickiness:
            core = last
        else:
            # Skewed preference for low-numbered cores (topology order),
            # matching the packed distributions seen in server captures.
            w = 1.0 / (1.0 + 0.05 * np.arange(cand.size))
            core = int(self.rng.choice(cand, p=w / w.sum()))
        self._linux_last_core = core
        return core

    # ------------------------------------------------------------------ #
    # periodic control (Algorithm 2) + metrics
    # ------------------------------------------------------------------ #
    def periodic(self, now: float) -> None:
        """Run once per idling period: settle aging accurately, sample
        metrics, and (PROPOSED only) execute Selective Core Idling."""
        self.settle_all(now)
        n = self.num_cores
        active = int((self.c_state == CState.ACTIVE).sum())
        assigned = int((self.task_of_core >= 0).sum())
        oversub = len(self.oversub_tasks)
        self.metrics.idle_norm_samples.append((active - assigned - oversub) / n)
        self.metrics.active_count_samples.append(active)
        self.metrics.task_count_samples.append(assigned + oversub)
        self.metrics.oversub_task_seconds += oversub * self.idling_period_s

        if self.policy is not Policy.PROPOSED:
            return
        corr = idling.core_correction(n, active, assigned, oversub)
        to_idle, to_wake = idling.apply_correction(
            corr,
            self.c_state == CState.ACTIVE,
            self.task_of_core >= 0,
            self.dvth,
        )
        for i in to_idle:
            # settle_all already brought core i to `now`; close its idle
            # window and power-gate.
            idle_dur = now - self.idle_since[i]
            mapping.record_idle_end(self.idle_history, self.hist_pos, int(i),
                                    max(idle_dur, 0.0))
            self.c_state[i] = CState.DEEP_IDLE
        for i in to_wake:
            self.c_state[i] = CState.ACTIVE
            self.idle_since[i] = now
        if len(to_wake):
            self._promote_oversubscribed(now)

    # ------------------------------------------------------------------ #
    # observability
    # ------------------------------------------------------------------ #
    def _frequencies_now(self, settle: bool = True) -> np.ndarray:
        if settle:
            self.settle_all(self.now)
        return aging.frequency(self.params, self.f0, self.dvth)

    def frequencies(self, now: float | None = None) -> np.ndarray:
        if now is not None:
            self.settle_all(now)
        return self._frequencies_now(settle=False)

    def frequency_cv(self, now: float | None = None) -> float:
        f = self.frequencies(now)
        return float(np.std(f) / np.mean(f))

    def mean_frequency_degradation(self, now: float | None = None) -> float:
        f = self.frequencies(now)
        return float(np.mean(self.f0 - f))

    def snapshot(self) -> dict:
        f = self._frequencies_now(settle=False)
        return {
            "f0": self.f0.copy(),
            "f": f,
            "dvth": self.dvth.copy(),
            "active": (self.c_state == CState.ACTIVE).copy(),
            "cv": float(np.std(f) / np.mean(f)),
            "mean_degradation": float(np.mean(self.f0 - f)),
        }


# Cache exp() factors per (params, temperature) — only 3 temperatures exist.
_ADF_CACHE: dict[tuple[int, float], float] = {}


def _adf_unscaled_cached(params: aging.AgingParams, temp_c: float) -> float:
    key = (id(params), temp_c)
    v = _ADF_CACHE.get(key)
    if v is None:
        import math
        t_k = temp_c + 273.15
        v = (math.exp(-params.E0 / (params.kB * t_k))
             * math.exp(params.c_field * params.vdd / (params.kB * t_k)))
        _ADF_CACHE[key] = v
    return v
