"""CoreManager — the per-server aging-aware CPU core management runtime.

This is the paper's contribution as a deployable component (Fig. 3): it
owns the per-core aging state of one inference server's CPU, routes every
CPU inference task through a pluggable task-to-core policy
(`repro.core.policies`), and applies the working-set corrections the
policy returns from its periodic hook (Selective Core Idling for the
proposed technique).

The manager is policy-agnostic: policies only see a read-only `CoreView`
(masks, dVth, f0, idle history, rng), while the manager keeps exclusive
write access to the NBTI bookkeeping. A core's dVth advances lazily with
the ADF of the (C-state, allocated) regime it was in, and every regime
change first settles the elapsed interval under the old ADF.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import aging, mapping, temperature, variation
from repro.core.policies import CorePolicy, CoreView, get_policy
from repro.core.temperature import CState

OVERSUBSCRIBED = -1  # sentinel core id for tasks that didn't get a core


@dataclasses.dataclass
class ManagerMetrics:
    """Accumulated observability for one server's CPU."""

    oversub_task_seconds: float = 0.0   # integral of T_oversub (paper §3.3)
    idle_norm_samples: list = dataclasses.field(default_factory=list)
    active_count_samples: list = dataclasses.field(default_factory=list)
    task_count_samples: list = dataclasses.field(default_factory=list)
    assigns: int = 0
    oversub_assigns: int = 0


class CoreManager:
    def __init__(
        self,
        num_cores: int,
        policy: CorePolicy | str = "proposed",
        aging_params: aging.AgingParams = aging.DEFAULT_PARAMS,
        variation_params: variation.VariationParams | None = None,
        rng: np.random.Generator | None = None,
        idling_period_s: float = 1.0,
        policy_opts: dict | None = None,
    ):
        self.num_cores = num_cores
        self.params = aging_params
        self.idling_period_s = idling_period_s
        self.rng = rng if rng is not None else np.random.default_rng(0)
        self.policy = self._resolve_policy(policy, policy_opts)
        vp = variation_params or variation.VariationParams(
            f_nominal=aging_params.f_nominal)
        self.f0 = variation.sample_initial_frequencies(vp, num_cores, self.rng)

        n = num_cores
        self.dvth = np.zeros(n)
        self.c_state = np.full(n, CState.ACTIVE, dtype=np.int8)
        self.task_of_core = np.full(n, -1, dtype=np.int64)   # task id or -1
        self.idle_history = np.zeros((n, mapping.IDLE_HISTORY_LEN))
        self.hist_pos = np.zeros(n, dtype=np.int64)
        self.idle_since = np.zeros(n)        # when core last became unassigned
        self.last_update = np.zeros(n)       # last dvth settlement time
        self.cum_work = np.zeros(n)          # least-aged baseline age proxy
        self.core_of_task: dict[int, int] = {}
        self.task_start: dict[int, float] = {}
        self.oversub_tasks: set[int] = set()
        # task -> sim time up to which its oversubscribed wait has been
        # added to `metrics.oversub_task_seconds` (each second of the
        # T_oversub integral is counted exactly once).
        self._oversub_accounted: dict[int, float] = {}
        self.metrics = ManagerMetrics()
        self.now = 0.0
        self._view = CoreView(self)

    @staticmethod
    def _resolve_policy(policy, policy_opts) -> CorePolicy:
        if isinstance(policy, CorePolicy):
            if policy_opts:
                raise TypeError("policy_opts only applies when the policy "
                                "is given by name; pass the options to the "
                                "constructor of your CorePolicy instance "
                                "instead")
            return policy
        return get_policy(policy, **dict(policy_opts or {}))

    @property
    def policy_name(self) -> str:
        return self.policy.name

    @property
    def view(self) -> CoreView:
        """Read-only view of this manager's per-core state."""
        return self._view

    # ------------------------------------------------------------------ #
    # aging bookkeeping
    # ------------------------------------------------------------------ #
    def _regime(self, i: int) -> tuple[float, float]:
        """(temperature C, stress Y) of core i's current regime."""
        cs = CState(int(self.c_state[i]))
        allocated = self.task_of_core[i] >= 0
        return (temperature.core_temperature_c(cs, allocated),
                temperature.core_stress(cs, allocated))

    def _settle(self, i: int, now: float) -> None:
        """Advance core i's dVth from last_update to `now` under its
        current regime. Must be called BEFORE any regime change."""
        tau = now - self.last_update[i]
        if tau > 0.0:
            t_c, y = self._regime(i)
            a = self.params.K * aging.adf_unscaled_cached(self.params, t_c, y)
            self.dvth[i] = aging.advance_dvth_scalar(
                self.params, float(self.dvth[i]), a, tau)
            self.last_update[i] = now

    def _settled_dvth(self, now: float) -> np.ndarray:
        """Every core's dVth advanced to `now` under its current regime,
        WITHOUT mutating state (pure; also backs `CoreView.dvth_now`)."""
        tau = np.maximum(now - self.last_update, 0.0)
        allocated = self.task_of_core >= 0
        active = self.c_state == CState.ACTIVE
        temps = np.where(
            active,
            np.where(allocated, temperature.TEMP_ACTIVE_ALLOCATED_C,
                     temperature.TEMP_ACTIVE_UNALLOCATED_C),
            temperature.TEMP_DEEP_IDLE_C,
        )
        stress = np.where(active, temperature.STRESS_ACTIVE,
                          temperature.STRESS_DEEP_IDLE)
        adf_vals = aging.adf(self.params, temps, stress)
        return aging.advance_dvth(self.params, self.dvth, adf_vals, tau)

    def settle_all(self, now: float) -> None:
        """Vectorized settlement of every core (used by the periodic path
        and by metric snapshots; mirrors the Pallas aging_update kernel)."""
        if not (now - self.last_update > 0).any():
            self.now = max(self.now, now)
            return
        self.dvth = self._settled_dvth(now)
        self.last_update = np.maximum(self.last_update, now)
        self.now = max(self.now, now)

    # ------------------------------------------------------------------ #
    # task lifecycle
    # ------------------------------------------------------------------ #
    def assign(self, task_id: int, now: float) -> float:
        """Route one CPU inference task to a core via the policy.

        Returns the execution speed factor (degraded f / nominal f) the
        simulator should apply to the task duration; oversubscribed tasks
        additionally share cores, handled by the caller via load factor.
        """
        self.now = max(self.now, now)
        self.metrics.assigns += 1
        core = self.policy.select_core(self._view)

        if core < 0:
            self.oversub_tasks.add(task_id)
            self.core_of_task[task_id] = OVERSUBSCRIBED
            self.task_start[task_id] = now
            self._oversub_accounted[task_id] = now
            self.metrics.oversub_assigns += 1
            # Oversubscribed tasks time-share already-busy cores, so the
            # settled frequency of the fastest *busy* core bounds their
            # speed — pristine idle (or power-gated) cores are not
            # executing anything and must not inflate the bound. Only
            # when no core is busy at all (pure promotion races) fall
            # back to the fleet-wide settled maximum.
            freqs = aging.frequency(self.params, self.f0,
                                    self._settled_dvth(now))
            busy = self.task_of_core >= 0
            pool = freqs[busy] if busy.any() else freqs
            return float(np.max(pool))

        # End the core's idle period -> record idle duration (Alg. 1 input).
        idle_dur = now - self.idle_since[core]
        mapping.record_idle_end(self.idle_history, self.hist_pos, core,
                                max(idle_dur, 0.0))
        self._settle(core, now)          # settle idle regime
        self.task_of_core[core] = task_id
        self.core_of_task[task_id] = core
        self.task_start[task_id] = now
        return aging.frequency_scalar(self.params, float(self.f0[core]),
                                      float(self.dvth[core]))

    def release(self, task_id: int, now: float) -> None:
        self.now = max(self.now, now)
        core = self.core_of_task.pop(task_id, None)
        start = self.task_start.pop(task_id, now)
        if core is None:
            return
        if core == OVERSUBSCRIBED:
            self.oversub_tasks.discard(task_id)
            self._account_oversub(task_id, now)
            self._promote_oversubscribed(now)
            return
        self._settle(core, now)          # settle allocated regime
        self.cum_work[core] += now - start
        self.task_of_core[core] = -1
        self.idle_since[core] = now
        self.policy.on_release(self._view, core)
        self._promote_oversubscribed(now)

    def _account_oversub(self, task_id: int, now: float,
                         final: bool = True) -> None:
        """Add `task_id`'s not-yet-counted oversubscribed wait to the
        T_oversub integral. `final=False` keeps the task in the books
        (periodic accrual for still-waiting tasks)."""
        since = (self._oversub_accounted.pop(task_id, now) if final
                 else self._oversub_accounted.get(task_id, now))
        self.metrics.oversub_task_seconds += max(now - since, 0.0)
        if not final:
            self._oversub_accounted[task_id] = now

    def _promote_oversubscribed(self, now: float) -> None:
        """When a core frees up, move a waiting oversubscribed task onto it.

        Promotion is manager-internal FIFO and always uses the Algorithm-1
        idle-score mapping (not the policy): a promoted task usually has
        exactly one candidate core — the one that just freed.
        """
        while self.oversub_tasks:
            active_mask = self.c_state == CState.ACTIVE
            assigned_mask = self.task_of_core >= 0
            free = active_mask & ~assigned_mask
            if not free.any():
                return
            task_id = min(self.oversub_tasks)  # FIFO by id (ids are ordered)
            self.oversub_tasks.discard(task_id)
            self._account_oversub(task_id, now)
            core = mapping.select_core(active_mask, assigned_mask,
                                       self.idle_history)
            idle_dur = now - self.idle_since[core]
            mapping.record_idle_end(self.idle_history, self.hist_pos, core,
                                    max(idle_dur, 0.0))
            self._settle(core, now)
            self.task_of_core[core] = task_id
            self.core_of_task[task_id] = core
            self.task_start[task_id] = now

    # ------------------------------------------------------------------ #
    # periodic control + metrics
    # ------------------------------------------------------------------ #
    def periodic(self, now: float) -> None:
        """Run once per idling period: settle aging accurately, sample
        metrics, and apply the policy's working-set correction (Selective
        Core Idling for the proposed technique; baselines return None)."""
        self.settle_all(now)
        n = self.num_cores
        active = int((self.c_state == CState.ACTIVE).sum())
        assigned = int((self.task_of_core >= 0).sum())
        oversub = len(self.oversub_tasks)
        self.metrics.idle_norm_samples.append((active - assigned - oversub) / n)
        self.metrics.active_count_samples.append(active)
        self.metrics.task_count_samples.append(assigned + oversub)
        # Keep the T_oversub integral live for still-waiting tasks; the
        # remainder of each wait is added at release/promotion, so no
        # second is ever counted twice.
        for task_id in self.oversub_tasks:
            self._account_oversub(task_id, now, final=False)

        corr = self.policy.periodic(self._view)
        if corr is None:
            return
        # Validate BEFORE mutating: a partial application would leave the
        # manager's bookkeeping corrupted, the exact failure mode the
        # read-only CoreView exists to prevent.
        busy = np.asarray(corr.to_idle)[
            self.task_of_core[corr.to_idle] >= 0] if len(corr.to_idle) else []
        if len(busy):
            raise ValueError(f"policy {self.policy.name!r} tried to idle "
                             f"cores {[int(i) for i in busy]} while they "
                             f"run tasks")
        for i in corr.to_idle:
            # settle_all already brought core i to `now`; close its idle
            # window and power-gate.
            idle_dur = now - self.idle_since[i]
            mapping.record_idle_end(self.idle_history, self.hist_pos, int(i),
                                    max(idle_dur, 0.0))
            self.c_state[i] = CState.DEEP_IDLE
        for i in corr.to_wake:
            self.c_state[i] = CState.ACTIVE
            self.idle_since[i] = now
        if len(corr.to_wake):
            self._promote_oversubscribed(now)

    # ------------------------------------------------------------------ #
    # observability
    # ------------------------------------------------------------------ #
    def _frequencies_now(self, settle: bool = True) -> np.ndarray:
        if settle:
            self.settle_all(self.now)
        return aging.frequency(self.params, self.f0, self.dvth)

    def frequencies(self, now: float | None = None) -> np.ndarray:
        if now is not None:
            self.settle_all(now)
        return self._frequencies_now(settle=False)

    def frequency_cv(self, now: float | None = None) -> float:
        f = self.frequencies(now)
        return float(np.std(f) / np.mean(f))

    def mean_frequency_degradation(self, now: float | None = None) -> float:
        f = self.frequencies(now)
        return float(np.mean(self.f0 - f))

    def snapshot(self) -> dict:
        f = self._frequencies_now(settle=False)
        return {
            "f0": self.f0.copy(),
            "f": f,
            "dvth": self.dvth.copy(),
            "active": (self.c_state == CState.ACTIVE).copy(),
            "cv": float(np.std(f) / np.mean(f)),
            "mean_degradation": float(np.mean(self.f0 - f)),
        }
