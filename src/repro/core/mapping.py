"""Task-to-Core Mapping (paper Algorithm 1).

Selects, among the *working set* (active cores) that have no task
assigned, the core with the highest *idle score* — the sum of its last
eight idle durations (the same rolling window the Linux cpuidle governor
keeps).  A mostly-idle core is an inexpensive estimate of a lesser-aged
core, so stress is distributed least-aged-first without CPU profiling.

These functions are the *reference* implementation of Algorithm 1: the
event-loop hot path in `CoreManager` answers the same argmax from an
incrementally-maintained idle-score array + lazy free-core heap
(`CoreView.best_idle_core`), and tests/test_fastpath.py pins the two
against each other bit-exactly.
"""
from __future__ import annotations

import numpy as np

IDLE_HISTORY_LEN = 8  # paper: "last eight idle durations", like cpuidle


def idle_scores(idle_history: np.ndarray) -> np.ndarray:
    """Sum the rolling idle-duration window per core. (N, 8) -> (N,)."""
    return idle_history.sum(axis=-1)


def select_core(
    active_mask: np.ndarray,
    task_assigned: np.ndarray,
    idle_history: np.ndarray,
) -> int:
    """Algorithm 1. Returns the selected core index, or -1 if none free.

    Args:
      active_mask:   (N,) bool — core is in the working set (C0).
      task_assigned: (N,) bool — core already runs an inference task.
      idle_history:  (N, IDLE_HISTORY_LEN) float seconds.
    """
    candidates = active_mask & ~task_assigned
    if not candidates.any():
        return -1
    scores = idle_scores(idle_history)
    # Non-candidates must never win the argmax.
    masked = np.where(candidates, scores, -np.inf)
    return int(np.argmax(masked))


def record_idle_end(idle_history: np.ndarray, hist_pos: np.ndarray,
                    core: int, idle_duration: float) -> None:
    """Push a finished idle period into the core's rolling window."""
    idle_history[core, hist_pos[core] % IDLE_HISTORY_LEN] = idle_duration
    hist_pos[core] += 1
