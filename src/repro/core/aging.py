"""NBTI reaction-diffusion aging model (paper §3.2).

Implements the paper's aging physics:

  f(t)        = f0 * (1 - dVth / (Vdd - Vth))                       (Eq. 1)
  dVth(t_p)   = ADF_p * [ (dVth(t_{p-1}) / ADF_p)^(1/n) + tau_p ]^n
  ADF(T,V,Y)  = K * exp(-E0 / (kB*T)) * exp(C_field*Vdd / (kB*T)) * Y^n  (Eq. 2)

where the recursive dVth update lets a core move through intervals with
different ADFs (different temperatures / stress levels / idle states) while
accumulating a single threshold-voltage shift.  Deep idle (C6) power-gates
the core: no transistor switching, stress Y = 0, and the shift is frozen.

`K` is a fitting parameter calibrated exactly as the paper describes: for
22nm technology the worst-case 10-year frequency reduction is 30% [Ansari
'23], so we solve dVth(10yr, T=54C, Y=1) = 0.3 * (Vdd - Vth) for K.

Everything is provided in three flavours:
  * scalar / numpy  — the simulator fast path (per-event, per-core),
  * jnp             — vectorized fleet analytics, the Pallas kernel oracle,
  * the Pallas kernel itself lives in repro/kernels/aging_update.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

SECONDS_PER_YEAR = 365.25 * 24 * 3600.0
TEN_YEARS_S = 10.0 * SECONDS_PER_YEAR


@dataclasses.dataclass(frozen=True)
class AgingParams:
    """Physical constants of the NBTI model (22nm-class, see DESIGN.md)."""

    n: float = 1.0 / 6.0          # reaction-diffusion time exponent
    kB: float = 8.617333e-5       # Boltzmann constant [eV/K]
    E0: float = 0.1897            # activation energy [eV]
    c_field: float = 0.075        # B/tox folded field coefficient [eV/V]
    vdd: float = 1.0              # supply voltage [V]
    vth: float = 0.45             # nominal threshold voltage [V]
    f_nominal: float = 1.0        # normalized nominal max frequency
    worst_case_temp_c: float = 54.0       # Table 1: C0 + allocated task
    worst_case_lifetime_red: float = 0.30  # 30% freq drop @ 10 years
    K: float = dataclasses.field(default=0.0)  # fitting parameter, solved

    @property
    def headroom(self) -> float:
        """Vdd - Vth, the denominator of Eq. 1."""
        return self.vdd - self.vth


def _adf_unscaled(params: AgingParams, temp_c: float, stress: float) -> float:
    """ADF / K — everything in Eq. 2 except the fitting parameter."""
    if stress <= 0.0:
        return 0.0
    t_k = temp_c + 273.15
    return (
        math.exp(-params.E0 / (params.kB * t_k))
        * math.exp(params.c_field * params.vdd / (params.kB * t_k))
        * stress ** params.n
    )


# exp() factors per (params, T, Y) — the simulator only ever sees the
# three Table-1 regimes, so this stays tiny. Keyed on the frozen params
# value (hashable dataclass), NOT id(params): a GC'd-and-reused id could
# otherwise serve stale factors for new params.
_ADF_UNSCALED_CACHE: dict[tuple[AgingParams, float, float], float] = {}


def adf_unscaled_cached(params: AgingParams, temp_c: float,
                        stress: float) -> float:
    """Memoized `_adf_unscaled` — the event-loop fast path (`CoreManager`
    settles a core's regime on every assign/release)."""
    key = (params, temp_c, stress)
    v = _ADF_UNSCALED_CACHE.get(key)
    if v is None:
        v = _adf_unscaled(params, temp_c, stress)
        _ADF_UNSCALED_CACHE[key] = v
    return v


def solve_k(params: AgingParams) -> AgingParams:
    """Calibrate K so worst-case 10-year aging costs 30% of frequency.

    From a fresh core, dVth(t) = ADF * t^n, so
        K = dVth_target / (adf_unscaled * t^n).
    """
    target_dvth = params.worst_case_lifetime_red * params.headroom
    base = _adf_unscaled(params, params.worst_case_temp_c, 1.0)
    k = target_dvth / (base * TEN_YEARS_S ** params.n)
    return dataclasses.replace(params, K=k)


DEFAULT_PARAMS = solve_k(AgingParams())


def adf(params: AgingParams, temp_c, stress):
    """Aging-degradation factor (Eq. 2). Vectorized over numpy inputs.

    stress == 0 (deep idle) yields ADF == 0, which `advance_dvth`
    interprets as "aging halted".
    """
    temp_c = np.asarray(temp_c, dtype=np.float64)
    stress = np.asarray(stress, dtype=np.float64)
    t_k = temp_c + 273.15
    out = (
        params.K
        * np.exp(-params.E0 / (params.kB * t_k))
        * np.exp(params.c_field * params.vdd / (params.kB * t_k))
        * np.where(stress > 0.0, stress, 1.0) ** params.n
    )
    return np.where(stress > 0.0, out, 0.0)


def advance_dvth(params: AgingParams, dvth, adf_value, tau):
    """One step of the recursive dVth update (paper §3.2).

    dVth' = ADF * [ (dVth/ADF)^(1/n) + tau ]^n;  ADF == 0 freezes dVth.
    Vectorized over numpy arrays; `tau` in seconds.
    """
    dvth = np.asarray(dvth, dtype=np.float64)
    adf_value = np.asarray(adf_value, dtype=np.float64)
    tau = np.asarray(tau, dtype=np.float64)
    safe_adf = np.where(adf_value > 0.0, adf_value, 1.0)
    eff_time = (dvth / safe_adf) ** (1.0 / params.n)  # equivalent stress time
    new = safe_adf * (eff_time + tau) ** params.n
    return np.where((adf_value > 0.0) & (tau > 0.0), new, dvth)


def advance_dvth_scalar(params: AgingParams, dvth: float, adf_value: float,
                        tau: float) -> float:
    """Scalar fast path for the event loop (avoids numpy dispatch)."""
    if adf_value <= 0.0 or tau <= 0.0:
        return dvth
    eff_time = (dvth / adf_value) ** (1.0 / params.n)
    return adf_value * (eff_time + tau) ** params.n


def frequency(params: AgingParams, f0, dvth):
    """Eq. 1 — degraded max frequency given threshold-voltage shift."""
    return np.asarray(f0) * (1.0 - np.asarray(dvth) / params.headroom)


def frequency_scalar(params: AgingParams, f0: float, dvth: float) -> float:
    return f0 * (1.0 - dvth / params.headroom)


def dvth_after(params: AgingParams, temp_c: float, stress: float,
               duration_s: float, dvth0: float = 0.0) -> float:
    """Convenience: shift after `duration_s` at constant (T, Y)."""
    a = float(adf(params, temp_c, stress))
    return advance_dvth_scalar(params, dvth0, a, duration_s)
