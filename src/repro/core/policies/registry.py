"""String-keyed registry of core-management policies.

    @register_policy("proposed")
    class ProposedPolicy(CorePolicy): ...

    policy = get_policy("proposed")            # fresh instance
    policy = get_policy("linux", stickiness=0.5)

Names are case-insensitive and underscore/hyphen-insensitive, so
"least-aged", "least_aged" and "LEAST_AGED" all resolve to the same
policy. Every `get_policy` call returns a NEW instance: policies carry
per-server state and must not be shared across managers.

The mechanics live in the shared `repro.registry.Registry` (one
implementation for the policy / scenario / router axes).
"""
from __future__ import annotations

from repro.core.policies.base import CorePolicy
from repro.registry import Registry, canonical_name

_POLICIES = Registry(
    noun="policy", kind="core policy", decorator="register_policy",
    expects="CorePolicy subclass",
    check=lambda cls: isinstance(cls, type) and issubclass(cls, CorePolicy),
)
#: historical module-level alias (tests clean up through it)
_REGISTRY = _POLICIES.store


def canonical_policy_name(name: str) -> str:
    """Normalize a user-supplied policy key ("least_aged" -> "least-aged")."""
    return canonical_name(name)


def register_policy(name: str):
    """Class decorator: register a `CorePolicy` subclass under `name`."""
    return _POLICIES.register(name)


def get_policy(name: str, **opts) -> CorePolicy:
    """Instantiate the policy registered under `name` with `opts`."""
    return _POLICIES.get(name, **opts)


def available_policies() -> tuple[str, ...]:
    """Sorted canonical names of every registered policy."""
    return _POLICIES.available()
