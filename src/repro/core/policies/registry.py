"""String-keyed registry of core-management policies.

    @register_policy("proposed")
    class ProposedPolicy(CorePolicy): ...

    policy = get_policy("proposed")            # fresh instance
    policy = get_policy("linux", stickiness=0.5)

Names are case-insensitive and underscore/hyphen-insensitive, so
"least-aged", "least_aged" and "LEAST_AGED" all resolve to the same
policy. Every `get_policy` call returns a NEW instance: policies carry
per-server state and must not be shared across managers.
"""
from __future__ import annotations

from repro.core.policies.base import CorePolicy

_REGISTRY: dict[str, type[CorePolicy]] = {}


def canonical_policy_name(name: str) -> str:
    """Normalize a user-supplied policy key ("least_aged" -> "least-aged")."""
    return str(name).strip().lower().replace("_", "-")


def register_policy(name: str):
    """Class decorator: register a `CorePolicy` subclass under `name`."""
    key = canonical_policy_name(name)

    def deco(cls: type[CorePolicy]) -> type[CorePolicy]:
        if not (isinstance(cls, type) and issubclass(cls, CorePolicy)):
            raise TypeError(f"@register_policy({name!r}) expects a "
                            f"CorePolicy subclass, got {cls!r}")
        prev = _REGISTRY.get(key)
        if prev is not None and prev is not cls:
            raise ValueError(f"policy name {key!r} already registered "
                             f"to {prev.__name__}")
        cls.name = key
        _REGISTRY[key] = cls
        return cls

    return deco


def get_policy(name: str, **opts) -> CorePolicy:
    """Instantiate the policy registered under `name` with `opts`."""
    key = canonical_policy_name(name)
    try:
        cls = _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown core policy {name!r}; available: "
            f"{', '.join(available_policies())}") from None
    return cls(**opts)


def available_policies() -> tuple[str, ...]:
    """Sorted canonical names of every registered policy."""
    return tuple(sorted(_REGISTRY))
