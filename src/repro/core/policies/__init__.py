"""Pluggable task-to-core management policies.

Built-ins (importing this package registers them):

  proposed     — paper Algorithms 1+2 (idle-score mapping + selective idling)
  linux        — probabilistic stock-Linux placement model (§6.1.1)
  least-aged   — Zhao'23 cumulative-work baseline
  round-robin  — naive wear-leveling strawman
  aging-greedy — dVth-exact placement oracle (no idling)

Adding a policy:

    from repro.core.policies import CorePolicy, register_policy

    @register_policy("my-policy")
    class MyPolicy(CorePolicy):
        def select_core(self, view):
            ...

then `CoreManager(n, policy="my-policy")` or
`ExperimentConfig(policy="my-policy")` picks it up by name.
"""
from repro.core.policies.base import CorePolicy, CoreView, IdleCorrection
from repro.core.policies.registry import (available_policies,
                                          canonical_policy_name, get_policy,
                                          register_policy)

# Import built-ins for their @register_policy side effects.
from repro.core.policies import aging_greedy as _aging_greedy  # noqa: F401
from repro.core.policies import least_aged as _least_aged      # noqa: F401
from repro.core.policies import linux as _linux                # noqa: F401
from repro.core.policies import proposed as _proposed          # noqa: F401
from repro.core.policies import round_robin as _round_robin    # noqa: F401

__all__ = [
    "CorePolicy", "CoreView", "IdleCorrection", "available_policies",
    "canonical_policy_name", "get_policy", "register_policy",
]
