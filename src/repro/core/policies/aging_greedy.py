"""Aging-greedy oracle baseline: route by true current degradation."""
from __future__ import annotations

import numpy as np

from repro.core.policies.base import CorePolicy, CoreView
from repro.core.policies.registry import register_policy


@register_policy("aging-greedy")
class AgingGreedyPolicy(CorePolicy):
    """Assign each task to the free core with the smallest *settled*
    threshold-voltage shift — the natural oracle for Algorithm 1's
    idle-score heuristic, as if per-core aging sensors were read on
    every placement (paper §5 assumes such reads are only affordable on
    the slow periodic path). Upper-bounds what dVth-exact placement
    buys without selective idling: like least-aged it never power-gates,
    so mean aging matches the always-C0 baselines.
    """

    def select_core(self, view: CoreView) -> int:
        cand = view.active_mask & ~view.assigned_mask
        if not cand.any():
            return -1
        return int(np.argmin(np.where(cand, view.dvth_now(), np.inf)))
