"""Round-robin baseline: naive stateless wear-leveling."""
from __future__ import annotations

from repro.core.policies.base import CorePolicy, CoreView
from repro.core.policies.registry import register_policy


@register_policy("round-robin")
class RoundRobinPolicy(CorePolicy):
    """Cycle a cursor over the cores and take the next free one.

    The classic wear-leveling strawman: perfectly uniform task counts,
    but blind to both process variation and accumulated aging, and it
    keeps the whole working set in C0 (no age-halting). Included to
    separate "spread the load evenly" from "spread the *stress*
    evenly" in policy sweeps.
    """

    def __init__(self):
        self._cursor = 0

    def select_core(self, view: CoreView) -> int:
        n = view.num_cores
        free = view.active_mask & ~view.assigned_mask
        if not free.any():
            return -1
        for k in range(n):
            core = (self._cursor + k) % n
            if free[core]:
                self._cursor = (core + 1) % n
                return core
        return -1
