"""Core-policy protocol: the pluggable task-to-core decision surface.

A `CorePolicy` makes three kinds of decisions for one server's CPU:

  * `select_core(view)` — which free core runs the next inference task
    (Algorithm 1 in the proposed technique; CFS-like placement in the
    Linux baseline; age-proxy argmins in the others).
  * `on_release(view, core)` — observe a task leaving a core (hook for
    policies that keep their own bookkeeping).
  * `periodic(view)` — once per idling period, optionally return an
    `IdleCorrection` telling the manager which cores to power-gate or
    wake (Algorithm 2 for the proposed technique; `None` = leave the
    working set alone, the baseline behaviour).

Policies never mutate manager state directly: they see a read-only
`CoreView`, so the NBTI bookkeeping (lazy dVth settlement, idle-history
ring buffers, task maps) cannot be corrupted by a buggy or adversarial
policy. A policy instance is owned by exactly one `CoreManager` — any
internal state (stickiness memory, round-robin cursor) is per-server.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import numpy as np

from repro.core.temperature import CState

_EMPTY = np.empty(0, dtype=np.int64)


def _readonly(a: np.ndarray) -> np.ndarray:
    v = a.view()
    v.flags.writeable = False
    return v


class CoreView:
    """Read-only window onto one CoreManager's per-core state.

    Arrays are zero-copy read-only views refreshed on every property
    access (the manager reassigns some of them during settlement), so a
    policy may hold the `CoreView` itself but should not cache arrays
    across calls.
    """

    __slots__ = ("_m",)

    def __init__(self, manager):
        self._m = manager

    # -- shape / clock ------------------------------------------------- #
    @property
    def num_cores(self) -> int:
        return self._m.num_cores

    @property
    def now(self) -> float:
        """Manager's current simulation/wall time."""
        return self._m.now

    @property
    def idling_period_s(self) -> float:
        return self._m.idling_period_s

    # -- per-core state ------------------------------------------------ #
    @property
    def active_mask(self) -> np.ndarray:
        """(N,) bool — core is in the working set (C0, not power-gated)."""
        return self._m.c_state == CState.ACTIVE

    @property
    def assigned_mask(self) -> np.ndarray:
        """(N,) bool — core currently runs an inference task."""
        return self._m.task_of_core >= 0

    @property
    def idle_history(self) -> np.ndarray:
        """(N, IDLE_HISTORY_LEN) float — rolling idle-duration windows."""
        return _readonly(self._m.idle_history)

    @property
    def dvth(self) -> np.ndarray:
        """(N,) float — threshold-voltage shift as of each core's last
        settlement (lazily updated; see `dvth_now` for settled values)."""
        return _readonly(self._m.dvth)

    @property
    def f0(self) -> np.ndarray:
        """(N,) float — process-variation initial max frequencies."""
        return _readonly(self._m.f0)

    @property
    def cum_work(self) -> np.ndarray:
        """(N,) float — cumulative task-seconds executed per core (the
        Zhao'23 least-aged age proxy, maintained by the manager)."""
        return _readonly(self._m.cum_work)

    @property
    def failed_mask(self) -> np.ndarray:
        """(N,) bool — cores permanently offlined by the fault layer
        (`repro.faults`). All-False unless a fault model is active;
        failed cores are held in deep idle and must never be woken."""
        return _readonly(self._m.failed)

    @property
    def oversub_count(self) -> int:
        """Number of tasks currently waiting without a core."""
        return len(self._m.oversub_tasks)

    @property
    def rng(self) -> np.random.Generator:
        """The manager's RNG — shared so seeded runs are reproducible."""
        return self._m.rng

    # -- derived ------------------------------------------------------- #
    def best_idle_core(self) -> int:
        """Free working-set core with the highest idle score, or -1 —
        Algorithm 1's argmax, answered from the manager's incremental
        free-core index instead of a fresh masked argmax. Equivalent to
        `mapping.select_core(active_mask, assigned_mask, idle_history)`
        including first-index tie-breaking (pinned by
        tests/test_fastpath.py); read-only from the policy's view."""
        return self._m._peek_best_free()

    def dvth_now(self) -> np.ndarray:
        """(N,) float — dVth settled to `now` without mutating manager
        state. Models reading accurate aging-sensor data (paper §5)."""
        out = self._m._settled_dvth(self._m.now)
        out.flags.writeable = False
        return out


@dataclasses.dataclass(frozen=True)
class IdleCorrection:
    """Periodic working-set adjustment returned by `CorePolicy.periodic`.

    The manager applies it: `to_idle` cores are settled, their idle
    window recorded, and power-gated (C6); `to_wake` cores return to C0.
    Cores running a task must never appear in `to_idle`.

    `cause` attributes the decision for telemetry ("policy" for the
    plain reaction function, "carbon-aware" when
    `idling.temporal_adjustment` reshaped it); `deferred_wakes` counts
    wake-ups the carbon-aware path held back this period. Both are
    observability-only — the manager applies `to_idle`/`to_wake`
    identically regardless.
    """

    to_idle: np.ndarray = _EMPTY
    to_wake: np.ndarray = _EMPTY
    cause: str = "policy"
    deferred_wakes: int = 0

    def __bool__(self) -> bool:
        return bool(len(self.to_idle) or len(self.to_wake))


class CorePolicy:
    """Base class for task-to-core management policies.

    Subclasses register under a string key with `@register_policy(name)`
    and are instantiated per-manager via `get_policy(name, **opts)`.
    """

    #: canonical registry key, set by @register_policy
    name: ClassVar[str] = "?"

    def select_core(self, view: CoreView) -> int:
        """Pick a core for the next task, or -1 to oversubscribe."""
        raise NotImplementedError

    def on_release(self, view: CoreView, core: int) -> None:
        """A task just left `core` (policy-side bookkeeping hook)."""

    def periodic(self, view: CoreView) -> IdleCorrection | None:
        """Once per idling period; return a correction or None."""
        return None

    # Legacy alias: pre-registry code read `manager.policy.value` off the
    # old `Policy` enum; the registry key plays that role now.
    @property
    def value(self) -> str:
        return self.name

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"
