"""Least-aged baseline (Zhao'23): route away from worked cores."""
from __future__ import annotations

import numpy as np

from repro.core.policies.base import CorePolicy, CoreView
from repro.core.policies.registry import register_policy


@register_policy("least-aged")
class LeastAgedPolicy(CorePolicy):
    """Assign each task to the free core with the least cumulative
    executed work — the age estimate of Zhao'23. Evens wear out but
    keeps every core in C0, so total aging is never reduced.
    """

    def select_core(self, view: CoreView) -> int:
        cand = view.active_mask & ~view.assigned_mask
        if not cand.any():
            return -1
        return int(np.argmin(np.where(cand, view.cum_work, np.inf)))
