"""The paper's technique: Algorithm 1 mapping + Algorithm 2 idling."""
from __future__ import annotations

from repro.core import idling
from repro.core.policies.base import CorePolicy, CoreView, IdleCorrection
from repro.core.policies.registry import register_policy


@register_policy("proposed")
class ProposedPolicy(CorePolicy):
    """Aging-aware core management (paper Algorithms 1 + 2).

    Tasks go to the free working-set core with the highest idle score
    (sum of its last eight idle durations — a cheap lesser-aged
    estimate), and a per-period reaction function sizes the working set
    to throughput, power-gating spare cores most-aged-first so their
    NBTI aging halts.

    `carbon_aware=True` adds the temporal dimension: during dirty-grid
    hours (current `CarbonIntensity` above `dirty_frac` x its mean) the
    periodic correction is reshaped by `idling.temporal_adjustment` —
    gating amplified by `gate_gain`, wake-ups partially deferred by
    `defer_frac` while at most `guard_tasks` tasks are oversubscribed
    (the p99-latency guard). The default (`carbon_aware=False`) is
    bit-exact with the pre-option behaviour.
    """

    def __init__(self, carbon_aware: bool = False,
                 intensity="diurnal", intensity_opts=None,
                 dirty_frac: float = 1.05, defer_frac: float = 0.5,
                 guard_tasks: int = 2, gate_gain: float = 2.0):
        if not 0.0 <= defer_frac <= 1.0:
            raise ValueError(f"defer_frac must be in [0, 1], got "
                             f"{defer_frac}")
        if gate_gain < 1.0:
            raise ValueError(f"gate_gain must be >= 1, got {gate_gain}")
        if guard_tasks < 0:
            raise ValueError(f"guard_tasks must be >= 0, got "
                             f"{guard_tasks}")
        if dirty_frac <= 0.0:
            raise ValueError(f"dirty_frac must be > 0, got {dirty_frac}")
        self.carbon_aware = bool(carbon_aware)
        self.dirty_frac = dirty_frac
        self.defer_frac = defer_frac
        self.guard_tasks = guard_tasks
        self.gate_gain = gate_gain
        self._intensity = None
        self._intensity_mean = 0.0
        if self.carbon_aware:
            from repro.carbon.intensity import get_intensity
            self._intensity = get_intensity(
                intensity, **dict(intensity_opts or {}))
            self._intensity_mean = self._intensity.mean_g_per_kwh()

    def select_core(self, view: CoreView) -> int:
        # Algorithm 1's masked argmax, answered by the manager's
        # incremental free-core index (same selection as
        # `mapping.select_core(view.active_mask, view.assigned_mask,
        # view.idle_history)`, without rebuilding masks per task).
        return view.best_idle_core()

    def periodic(self, view: CoreView) -> IdleCorrection | None:
        active_mask = view.active_mask
        assigned_mask = view.assigned_mask
        corr = idling.core_correction(
            view.num_cores,
            int(active_mask.sum()),
            int(assigned_mask.sum()),
            view.oversub_count,
        )
        cause = "policy"
        deferred = 0
        if self._intensity is not None:
            corr0 = corr
            corr = idling.temporal_adjustment(
                corr, self._intensity.g_per_kwh(view.now),
                self._intensity_mean, view.oversub_count,
                dirty_frac=self.dirty_frac, defer_frac=self.defer_frac,
                guard_tasks=self.guard_tasks, gate_gain=self.gate_gain)
            if corr != corr0:
                cause = "carbon-aware"
                if corr0 < 0:
                    # corr0 wanted -corr0 wake-ups; the adjustment kept
                    # only -corr of them (corr > corr0 here).
                    deferred = corr - corr0
        to_idle, to_wake = idling.apply_correction(
            corr, active_mask, assigned_mask, view.dvth,
            failed_mask=view.failed_mask)
        if not (len(to_idle) or len(to_wake) or deferred):
            return None
        return IdleCorrection(to_idle=to_idle, to_wake=to_wake,
                              cause=cause, deferred_wakes=deferred)
