"""The paper's technique: Algorithm 1 mapping + Algorithm 2 idling."""
from __future__ import annotations

from repro.core import idling
from repro.core.policies.base import CorePolicy, CoreView, IdleCorrection
from repro.core.policies.registry import register_policy


@register_policy("proposed")
class ProposedPolicy(CorePolicy):
    """Aging-aware core management (paper Algorithms 1 + 2).

    Tasks go to the free working-set core with the highest idle score
    (sum of its last eight idle durations — a cheap lesser-aged
    estimate), and a per-period reaction function sizes the working set
    to throughput, power-gating spare cores most-aged-first so their
    NBTI aging halts.
    """

    def select_core(self, view: CoreView) -> int:
        # Algorithm 1's masked argmax, answered by the manager's
        # incremental free-core index (same selection as
        # `mapping.select_core(view.active_mask, view.assigned_mask,
        # view.idle_history)`, without rebuilding masks per task).
        return view.best_idle_core()

    def periodic(self, view: CoreView) -> IdleCorrection | None:
        active_mask = view.active_mask
        assigned_mask = view.assigned_mask
        corr = idling.core_correction(
            view.num_cores,
            int(active_mask.sum()),
            int(assigned_mask.sum()),
            view.oversub_count,
        )
        to_idle, to_wake = idling.apply_correction(
            corr, active_mask, assigned_mask, view.dvth)
        if not (len(to_idle) or len(to_wake)):
            return None
        return IdleCorrection(to_idle=to_idle, to_wake=to_wake)
