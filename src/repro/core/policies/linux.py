"""Stock-Linux baseline: probabilistic CFS-like task placement."""
from __future__ import annotations

import numpy as np

from repro.core.policies.base import CorePolicy, CoreView
from repro.core.policies.registry import register_policy


@register_policy("linux")
class LinuxPolicy(CorePolicy):
    """Probabilistic model of a stock Linux LLM inference server (paper
    §6.1.1), built from captured CPU data: CFS mostly picks an idle core
    but exhibits cache-affinity stickiness, with a skewed preference for
    low-numbered cores (topology order, per Wilkins'24 captures). All
    cores stay in C0 — no selective idling, aging never halts.
    """

    def __init__(self, stickiness: float = 0.3):
        self.stickiness = float(stickiness)
        self._last_core = -1

    def select_core(self, view: CoreView) -> int:
        cand = np.flatnonzero(view.active_mask & ~view.assigned_mask)
        if cand.size == 0:
            return -1
        last = self._last_core
        if last in cand and view.rng.random() < self.stickiness:
            core = last
        else:
            w = 1.0 / (1.0 + 0.05 * np.arange(cand.size))
            core = int(view.rng.choice(cand, p=w / w.sum()))
        self._last_core = core
        return core
