"""Process-variation model for initial core frequencies f0 (paper §3.2).

Chip area is a 10x10 grid; each cell gets a Gaussian random variable p_kl
with spatial correlation rho_{ij,kl} = exp(-alpha * dist(ij, kl))
[Raghunathan '13].  Critical paths live entirely inside cells, and

    f0(core) = K' * min_{k,l in core's cells} (1 / p_kl)

The mean of p is solved so that a variation-free chip hits the nominal
frequency: p == mu everywhere => f0 = K'/mu = f_nominal => mu = K'/f_nominal.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np


@dataclasses.dataclass(frozen=True)
class VariationParams:
    n_chip: int = 10          # grid is n_chip x n_chip
    k_prime: float = 1.0      # technology constant K'
    alpha: float = 0.5        # spatial correlation decay
    sigma_frac: float = 0.05  # sigma as a fraction of the mean
    f_nominal: float = 1.0


@functools.lru_cache(maxsize=8)
def _correlation_cholesky(n_chip: int, alpha: float) -> np.ndarray:
    """Cholesky factor of the grid correlation matrix (cached)."""
    coords = np.stack(
        np.meshgrid(np.arange(n_chip), np.arange(n_chip), indexing="ij"), -1
    ).reshape(-1, 2).astype(np.float64)
    d = np.linalg.norm(coords[:, None, :] - coords[None, :, :], axis=-1)
    corr = np.exp(-alpha * d)
    # jitter for numerical PSD safety
    corr += 1e-10 * np.eye(corr.shape[0])
    return np.linalg.cholesky(corr)


def sample_grid(params: VariationParams, rng: np.random.Generator) -> np.ndarray:
    """Sample one chip's correlated p grid, shape (n_chip, n_chip)."""
    n = params.n_chip
    chol = _correlation_cholesky(n, params.alpha)
    z = rng.standard_normal(n * n)
    mu = params.k_prime / params.f_nominal
    sigma = params.sigma_frac * mu
    p = mu + sigma * (chol @ z)
    # p is a delay-like quantity; keep it strictly positive.
    p = np.clip(p, 0.2 * mu, None)
    return p.reshape(n, n)


def core_cell_partition(n_chip: int, num_cores: int) -> list[np.ndarray]:
    """Assign grid cells to cores contiguously in raster order.

    Every core owns >= 1 cell; when num_cores > cells, cores share cells
    round-robin (still deterministic).
    """
    cells = np.arange(n_chip * n_chip)
    if num_cores <= len(cells):
        return [np.asarray(chunk) for chunk in np.array_split(cells, num_cores)]
    return [np.asarray([cells[i % len(cells)]]) for i in range(num_cores)]


def sample_initial_frequencies(
    params: VariationParams, num_cores: int, rng: np.random.Generator
) -> np.ndarray:
    """Per-core f0 for one chip: K' * min over owned cells of 1/p."""
    grid = sample_grid(params, rng).reshape(-1)
    parts = core_cell_partition(params.n_chip, num_cores)
    f0 = np.array(
        [params.k_prime * np.min(1.0 / grid[cells]) for cells in parts],
        dtype=np.float64,
    )
    return f0
