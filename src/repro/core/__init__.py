"""The paper's primary contribution: aging-aware CPU core management.

Public API:
  aging       — NBTI reaction-diffusion physics (Eq. 1, 2, recursion)
  variation   — process-variation f0 sampling
  temperature — Table-1 C-state temperature/stress model
  mapping     — Algorithm 1 (Task-to-Core Mapping)
  idling      — Algorithm 2 (Selective Core Idling + reaction function)
  policies    — pluggable CorePolicy registry (proposed, linux,
                least-aged, round-robin, aging-greedy, + user-defined)
  manager     — policy-agnostic CoreManager runtime
  carbon      — compatibility re-export of `repro.carbon` (the pluggable
                carbon-accounting subsystem: models + intensity signals)
"""
from repro.core import (aging, carbon, idling, mapping, policies,
                        temperature, variation)
from repro.core.manager import OVERSUBSCRIBED, CoreManager, ManagerMetrics
from repro.core.policies import (CorePolicy, CoreView, IdleCorrection,
                                 available_policies, get_policy,
                                 register_policy)

__all__ = [
    "aging", "carbon", "idling", "mapping", "policies", "temperature",
    "variation", "CoreManager", "ManagerMetrics", "OVERSUBSCRIBED",
    "CorePolicy", "CoreView", "IdleCorrection", "available_policies",
    "get_policy", "register_policy",
]
