"""The paper's primary contribution: aging-aware CPU core management.

Public API:
  aging       — NBTI reaction-diffusion physics (Eq. 1, 2, recursion)
  variation   — process-variation f0 sampling
  temperature — Table-1 C-state temperature/stress model
  mapping     — Algorithm 1 (Task-to-Core Mapping)
  idling      — Algorithm 2 (Selective Core Idling + reaction function)
  manager     — CoreManager runtime (proposed + linux + least-aged policies)
  carbon      — embodied-carbon amortization estimates
"""
from repro.core import aging, carbon, idling, mapping, temperature, variation
from repro.core.manager import CoreManager, ManagerMetrics, Policy

__all__ = [
    "aging", "carbon", "idling", "mapping", "temperature", "variation",
    "CoreManager", "ManagerMetrics", "Policy",
]
