"""Selective Core Idling (paper Algorithm 2) and its reaction function.

Periodically sizes the dynamic *working set* of C0 cores to the current
inference throughput.  The controller computes a normalized error

    e = (N - C_sleep - T) / N,   T = min(N, assigned + oversubscribed)

(positive => spare active cores => underutilization; negative =>
oversubscription) and maps it through an asymmetric piecewise reaction
function:

    F(e) = tan(0.785 * e)     e >= 0   (slow: aging is a long-term effect)
    F(e) = arctan(1.55 * e)   e <  0   (fast: latency impact is immediate)

The scaled correction int(N * F(e)) is the number of cores to put to deep
idle (positive, most-aged first) or wake up (negative, least-aged first) —
both orderings complement the even-out behaviour of Algorithm 1.
"""
from __future__ import annotations

import math

import numpy as np

UNDERUTIL_GAIN = 0.785   # tan gain   (paper Alg. 2 line 11)
OVERSUB_GAIN = 1.55      # arctan gain (paper Alg. 2 line 13)


def reaction_function(e_norm: float) -> float:
    """Piecewise reaction F: [-1, 1] -> (-1, 1). See module docstring."""
    if e_norm >= 0.0:
        return math.tan(UNDERUTIL_GAIN * e_norm)
    return math.atan(OVERSUB_GAIN * e_norm)


def core_correction(
    total_cores: int,
    active_cores: int,
    assigned_tasks: int,
    oversub_tasks: int,
) -> int:
    """Algorithm 2 lines 1-17: number of cores to idle (+) or wake (-)."""
    n = total_cores
    c_sleep = n - active_cores
    tasks = min(n, assigned_tasks + oversub_tasks)
    e = (n - c_sleep - tasks) / n
    return int(n * reaction_function(e))


def temporal_adjustment(
    correction: int,
    intensity_now: float,
    intensity_mean: float,
    oversub_tasks: int,
    dirty_frac: float = 1.05,
    defer_frac: float = 0.5,
    guard_tasks: int = 2,
    gate_gain: float = 2.0,
) -> int:
    """Carbon-aware temporal reshaping of Algorithm 2's correction.

    During *dirty-grid* hours (`intensity_now > dirty_frac *
    intensity_mean`) the controller leans harder into deep idling:
    gating corrections are amplified (`gate_gain`), and wake-up
    corrections are partially deferred (`defer_frac` of the requested
    wakes held back) so cores stay power-gated — not aging, not burning
    watts — until the grid is cleaner. The p99-latency guard: deferral
    only applies while at most `guard_tasks` tasks are oversubscribed;
    beyond that, latency is already at stake and every requested wake
    goes through. Clean hours pass the correction through unchanged,
    so the reaction function's steady-state behaviour is untouched.
    """
    if correction == 0 or intensity_now <= dirty_frac * intensity_mean:
        return correction
    if correction > 0:
        return int(correction * gate_gain)
    if oversub_tasks > guard_tasks:
        return correction
    return correction + int(-correction * defer_frac)


def apply_correction(
    correction: int,
    active_mask: np.ndarray,
    task_assigned: np.ndarray,
    age_key: np.ndarray,
    failed_mask: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Algorithm 2 lines 18-22: flip idle states, aging-aware ordering.

    Args:
      correction: +k => put k cores to deep idle; -k => wake k cores.
      active_mask: (N,) bool, True = C0.
      task_assigned: (N,) bool; cores running a task are never idled.
      age_key: (N,) float, larger = more aged (we use dVth directly — the
        periodic path may read accurate aging-sensor data, paper §5).
      failed_mask: optional (N,) bool of permanently-failed cores
        (`repro.faults`); a failed core is parked in deep idle and must
        never be woken. None (or all-False) leaves the selection
        identical to the pre-fault behavior.

    Returns (indices_to_idle, indices_to_wake); caller mutates state so it
    can also account idle-history bookkeeping and timestamps.
    """
    if correction > 0:
        # Most-aged-first among active cores without a task (failed
        # cores are never active, so no extra mask is needed here).
        cand = np.flatnonzero(active_mask & ~task_assigned)
        order = cand[np.argsort(-age_key[cand], kind="stable")]
        return order[:correction], np.empty(0, dtype=np.int64)
    if correction < 0:
        # Least-aged-first among deep-idle survivors.
        idle = ~active_mask if failed_mask is None \
            else ~active_mask & ~failed_mask
        cand = np.flatnonzero(idle)
        order = cand[np.argsort(age_key[cand], kind="stable")]
        return np.empty(0, dtype=np.int64), order[: -correction]
    empty = np.empty(0, dtype=np.int64)
    return empty, empty
