"""Compatibility re-export: carbon accounting moved to `repro.carbon`.

The single hard-coded linear lifetime-extension formula that lived here
is now the `linear-extension` model in the pluggable `repro.carbon`
subsystem (bit-exact, golden-pinned in tests/test_carbon.py), alongside
a reliability-threshold lifetime model and an EcoServe-style
operational+embodied footprint model driven by grid `CarbonIntensity`
signals. New code should do:

    from repro.carbon import get_carbon_model
    est = get_carbon_model("linear-extension").lifetime(deg_ref, deg_tech)

`carbon.estimate` / `CarbonEstimate` / `yearly_footprint` keep working
through this module unchanged.
"""
from __future__ import annotations

from repro.carbon.base import (BASELINE_LIFESPAN_YEARS,
                               CPU_EMBODIED_KGCO2EQ, MAX_EXTENSION_FACTOR,
                               MIN_EXTENSION_FACTOR)
from repro.carbon.models import (CarbonEstimate, GPU_EMBODIED_KGCO2EQ,
                                 HOURS_PER_YEAR, SERVER_GPU_TDP_W,
                                 SERVER_OTHER_TDP_W,
                                 cluster_yearly_emissions, estimate,
                                 lifetime_extension, reference_degradation,
                                 yearly_footprint)

__all__ = [
    "BASELINE_LIFESPAN_YEARS", "CPU_EMBODIED_KGCO2EQ",
    "MAX_EXTENSION_FACTOR", "MIN_EXTENSION_FACTOR", "CarbonEstimate",
    "GPU_EMBODIED_KGCO2EQ", "HOURS_PER_YEAR", "SERVER_GPU_TDP_W",
    "SERVER_OTHER_TDP_W", "cluster_yearly_emissions", "estimate",
    "lifetime_extension", "reference_degradation", "yearly_footprint",
]
