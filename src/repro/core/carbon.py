"""Embodied-carbon amortization model (paper §2.1, §6.2 Fig. 7).

Amortization accounts embodied carbon over the asset's operating life:
a CPU with E kgCO2eq embodied over L years emits E/L kgCO2eq per year.
The paper extends CPU life by slowing aging: lifetime extension is
estimated with a *linear model* — the ratio of `linux` mean frequency
degradation to the technique's mean frequency degradation:

    extension = deg_linux / deg_technique
    life'     = 3 years * extension
    yearly'   = E / life'
    saving    = 1 - yearly'/yearly = 1 - 1/extension

Constants come from Li'24 ("Towards Carbon-efficient LLM Life Cycle"):
a typical Linux LLM inference server refreshes hardware every 3 years,
with 278.3 kgCO2eq CPU embodied carbon over that lifespan.
"""
from __future__ import annotations

import dataclasses

from repro.core import aging, temperature

CPU_EMBODIED_KGCO2EQ = 278.3   # per server CPU over baseline lifespan [18]
BASELINE_LIFESPAN_YEARS = 3.0  # hardware refresh cycle [18]


@dataclasses.dataclass(frozen=True)
class CarbonEstimate:
    extension_factor: float
    extended_life_years: float
    yearly_kgco2eq: float
    baseline_yearly_kgco2eq: float
    reduction_frac: float


def lifetime_extension(deg_linux: float, deg_technique: float) -> float:
    """Linear lifetime-extension model. Degradations must be >= 0."""
    if deg_technique <= 0.0:
        # Technique halted aging entirely within the horizon; cap the
        # extension at a large, finite factor to stay physical.
        return 100.0
    return max(deg_linux / deg_technique, 1e-6)


def estimate(deg_linux: float, deg_technique: float,
             embodied_kg: float = CPU_EMBODIED_KGCO2EQ,
             base_life_years: float = BASELINE_LIFESPAN_YEARS) -> CarbonEstimate:
    ext = lifetime_extension(deg_linux, deg_technique)
    life = base_life_years * ext
    yearly = embodied_kg / life
    base_yearly = embodied_kg / base_life_years
    return CarbonEstimate(
        extension_factor=ext,
        extended_life_years=life,
        yearly_kgco2eq=yearly,
        baseline_yearly_kgco2eq=base_yearly,
        reduction_frac=1.0 - yearly / base_yearly,
    )


def cluster_yearly_emissions(per_server_estimates: list[CarbonEstimate]) -> float:
    return sum(e.yearly_kgco2eq for e in per_server_estimates)


def reference_degradation(params: aging.AgingParams,
                          elapsed_s: float) -> float:
    """Worst-case mean frequency degradation of a fresh core aged
    continuously at active-allocated stress for `elapsed_s` — the
    linear-aging reference the carbon-greedy router and the fleet
    carbon metrics normalize against (stands in for the `linux`
    baseline of `lifetime_extension` within a single run)."""
    dvth = aging.dvth_after(params, temperature.TEMP_ACTIVE_ALLOCATED_C,
                            temperature.STRESS_ACTIVE,
                            max(elapsed_s, 1e-9))
    return params.f_nominal * dvth / params.headroom


# ------------------------------------------------------------------ #
# Fig.-1-style motivation model: operational vs embodied carbon of an
# inference server as grid carbon intensity falls (paper Fig. 1).
# ------------------------------------------------------------------ #
SERVER_GPU_TDP_W = 4 * 700.0        # 4x accelerator server (H100-class)
SERVER_OTHER_TDP_W = 800.0          # host CPU/mem/fans
# Accelerator embodied is comparatively small: Li'24 (paper [18]) finds
# the CPU die + mainboard dominate inference-server embodied carbon.
GPU_EMBODIED_KGCO2EQ = 150.0
HOURS_PER_YEAR = 8766.0


def yearly_footprint(carbon_intensity_g_per_kwh: float,
                     utilization: float = 0.6,
                     cpu_life_years: float = BASELINE_LIFESPAN_YEARS,
                     gpu_life_years: float = BASELINE_LIFESPAN_YEARS) -> dict:
    """Yearly kgCO2eq of one inference server split into operational and
    embodied (CPU vs accelerator) components, for a grid at the given
    carbon intensity. Reproduces the paper's Fig.-1 observation: as
    intensity drops, CPU embodied dominates."""
    energy_kwh = (SERVER_GPU_TDP_W + SERVER_OTHER_TDP_W) \
        * utilization * HOURS_PER_YEAR / 1000.0
    operational = energy_kwh * carbon_intensity_g_per_kwh / 1000.0
    cpu_embodied = CPU_EMBODIED_KGCO2EQ / cpu_life_years
    gpu_embodied = GPU_EMBODIED_KGCO2EQ / gpu_life_years
    total = operational + cpu_embodied + gpu_embodied
    return {
        "carbon_intensity": carbon_intensity_g_per_kwh,
        "operational_kg": operational,
        "cpu_embodied_kg": cpu_embodied,
        "gpu_embodied_kg": gpu_embodied,
        "total_kg": total,
        "cpu_embodied_frac": cpu_embodied / total,
    }
