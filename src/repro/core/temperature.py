"""Core temperature model (paper Table 1, derived from Fig. 4).

The paper measures an Intel Xeon while toggling cores between C0/C6:

    | Idle-state | C-state | Inference task | Temperature |
    | Active     | C0      | Allocated      | 54.00 C     |
    | Active     | C0      | Unallocated    | 51.08 C     |
    | Deep idle  | C6      | N/A            | 48.00 C     |

Stress Y follows the paper's worst-case assumption: any executing work
(inference task, or OS time-sharing system tasks on unallocated active
cores) applies Y = 1; power-gated C6 cores switch no transistors (Y = 0).
"""
from __future__ import annotations

import enum

import numpy as np


class CState(enum.IntEnum):
    ACTIVE = 0      # C0
    DEEP_IDLE = 1   # C6


TEMP_ACTIVE_ALLOCATED_C = 54.0
TEMP_ACTIVE_UNALLOCATED_C = 51.08
TEMP_DEEP_IDLE_C = 48.0

STRESS_ACTIVE = 1.0   # paper: worst-case stress for any active core
STRESS_DEEP_IDLE = 0.0


def core_temperature_c(c_state: CState, task_allocated: bool) -> float:
    if c_state == CState.DEEP_IDLE:
        return TEMP_DEEP_IDLE_C
    return TEMP_ACTIVE_ALLOCATED_C if task_allocated else TEMP_ACTIVE_UNALLOCATED_C


def core_stress(c_state: CState, task_allocated: bool) -> float:
    del task_allocated  # worst-case: active cores always stressed (OS tasks)
    return STRESS_DEEP_IDLE if c_state == CState.DEEP_IDLE else STRESS_ACTIVE


def regime_arrays(c_state, task_allocated):
    """Vectorized Table-1 regimes: (temps_C, stress) arrays from per-core
    C-states and allocation flags. Both `CoreManager._settled_dvth` and
    the fleet-batched settler (`repro.sim.fleetstate`) derive regimes
    through this one helper — their outputs must stay byte-identical for
    batched settlement to remain bit-exact with per-machine settlement.

    Args:
      c_state:        (...,) int array of `CState` values.
      task_allocated: (...,) bool array — core currently runs a task.
    """
    active = np.asarray(c_state) == CState.ACTIVE
    temps = np.where(
        active,
        np.where(task_allocated, TEMP_ACTIVE_ALLOCATED_C,
                 TEMP_ACTIVE_UNALLOCATED_C),
        TEMP_DEEP_IDLE_C,
    )
    stress = np.where(active, STRESS_ACTIVE, STRESS_DEEP_IDLE)
    return temps, stress
