"""Trace ingestion, replay and export (Azure LLM inference CSV schema).

The public AzurePublicDataset LLM inference traces ship as CSV with one
row per request: a timestamp plus context (input) and generated (output)
token counts. This module reads that schema into `Request` lists, turns
any request list into a replayable `WorkloadScenario` with the paper's
replay transformations (time-scaling, window splicing, rate-rescaling),
and writes any synthetic stream back out in the same schema, so every
scenario in the registry can be exported and re-ingested losslessly.

    reqs = load_csv("AzureLLMInferenceTrace_conv.csv")
    sc = ReplayScenario.from_requests(reqs, start_s=600, stop_s=1200)
    trace = sc.generate(rate_rps=60, duration_s=120)   # rate-rescaled
    export_csv(trace, "spliced.csv")
"""
from __future__ import annotations

import csv
import dataclasses
import datetime as _dt
import io
import os
import re

# Python 3.10's fromisoformat only accepts 3 or 6 fractional digits; the
# real Azure traces carry 7 (e.g. "2023-11-16 18:15:46.6805900").
_FRACTION = re.compile(r"^(?P<head>[^.]*\.)(?P<frac>\d+)(?P<tail>.*)$")

from repro.workloads.base import Request, WorkloadScenario

# Header of the public Azure LLM inference trace release.
AZURE_COLUMNS = ("TIMESTAMP", "ContextTokens", "GeneratedTokens")


def _parse_timestamp(raw: str) -> tuple[float, bool]:
    """Accept float seconds or an ISO-8601 datetime; the second element
    flags an absolute (datetime) timestamp."""
    try:
        return float(raw), False
    except ValueError:
        s = raw.strip().replace("Z", "+00:00")
        m = _FRACTION.match(s)
        if m:
            frac = m.group("frac")[:6].ljust(6, "0")
            s = m.group("head") + frac + m.group("tail")
        ts = _dt.datetime.fromisoformat(s)
        if ts.tzinfo is None:
            # Treat naive trace timestamps as UTC: local-time rules
            # would distort gaps across DST transitions and make the
            # replayed trace depend on the machine's timezone.
            ts = ts.replace(tzinfo=_dt.timezone.utc)
        return ts.timestamp(), True


def load_csv(path_or_file, rebase: bool | None = None) -> list[Request]:
    """Ingest an Azure-schema trace CSV into a `Request` list.

    Rows are returned sorted by arrival and re-numbered 0..n-1. With
    `rebase=None` (default), absolute datetime timestamps — what the
    public Azure traces use — are shifted so the earliest request
    arrives at t=0, while already-relative float-second timestamps pass
    through untouched (so an `export_csv` round-trip is the identity).
    Pass True/False to force either behaviour.
    """
    if isinstance(path_or_file, (str, os.PathLike)):
        with open(path_or_file, newline="") as f:
            return load_csv(f, rebase=rebase)
    reader = csv.DictReader(path_or_file)
    missing = set(AZURE_COLUMNS) - set(reader.fieldnames or ())
    if missing:
        raise ValueError(f"trace CSV is missing Azure-schema columns "
                         f"{sorted(missing)}; expected header "
                         f"{','.join(AZURE_COLUMNS)}")
    rows, n_absolute = [], 0
    for r in reader:
        t, is_abs = _parse_timestamp(r["TIMESTAMP"])
        n_absolute += is_abs
        rows.append((t, int(r["ContextTokens"]), int(r["GeneratedTokens"])))
    if not rows:
        return []
    if 0 < n_absolute < len(rows):
        raise ValueError(
            f"trace CSV mixes {n_absolute} absolute datetime timestamps "
            f"with {len(rows) - n_absolute} relative float ones; rebasing "
            "such a file would silently corrupt arrivals")
    absolute = n_absolute == len(rows)
    rows.sort(key=lambda x: x[0])
    t0 = rows[0][0] if (absolute if rebase is None else rebase) else 0.0
    return [Request(i, t - t0, n_in, n_out)
            for i, (t, n_in, n_out) in enumerate(rows)]


def export_csv(requests: list[Request], path_or_file) -> None:
    """Write a request stream in the Azure trace schema.

    Arrival seconds are written with `repr` so a load_csv round-trip
    reconstructs bit-identical floats.
    """
    if isinstance(path_or_file, (str, os.PathLike)):
        with open(path_or_file, "w", newline="") as f:
            export_csv(requests, f)
        return
    w = csv.writer(path_or_file)
    w.writerow(AZURE_COLUMNS)
    for r in requests:
        w.writerow([repr(float(r.arrival_s)),
                    r.input_tokens, r.output_tokens])


def export_csv_str(requests: list[Request]) -> str:
    """`export_csv` into a string (handy for tests and piping)."""
    buf = io.StringIO()
    export_csv(requests, buf)
    return buf.getvalue()


def splice(requests: list[Request], start_s: float = 0.0,
           stop_s: float | None = None) -> list[Request]:
    """Cut the [start_s, stop_s) window and shift it to start at t=0."""
    kept = [r for r in requests
            if r.arrival_s >= start_s
            and (stop_s is None or r.arrival_s < stop_s)]
    return [dataclasses.replace(r, req_id=i, arrival_s=r.arrival_s - start_s)
            for i, r in enumerate(kept)]


def time_scale(requests: list[Request], factor: float) -> list[Request]:
    """Stretch (factor > 1) or compress (factor < 1) arrival times.

    Compressing raises the delivered request rate — the replay knob the
    paper uses to sweep throughput levels over one recorded trace.
    """
    if factor <= 0:
        raise ValueError(f"time-scale factor must be positive, got {factor}")
    return [dataclasses.replace(r, arrival_s=r.arrival_s * factor)
            for r in requests]


def rescale_rate(requests: list[Request], rate_rps: float,
                 duration_s: float | None = None) -> list[Request]:
    """Time-scale so the stream's mean rate over its span is `rate_rps`,
    optionally also truncating to `duration_s` after rescaling."""
    if not requests:
        return []
    span = max(r.arrival_s for r in requests)
    if span <= 0:
        raise ValueError("cannot rescale a zero-span trace")
    current = len(requests) / span
    out = time_scale(requests, current / rate_rps)
    if duration_s is not None:
        out = [r for r in out if r.arrival_s < duration_s]
    return out


@dataclasses.dataclass(frozen=True)
class ReplayScenario:
    """A recorded trace as a first-class `WorkloadScenario`.

    `generate` splices the configured window, rescales so the mean rate
    matches the requested `rate_rps`, and — because the rescaled
    recording may hold less volume than `rate_rps * duration_s` — loops
    it end-to-end until `duration_s` is covered (so replay honors the
    same duration contract as the synthetic scenarios; set `loop=False`
    to emit the recording at most once). Replay is deterministic by
    construction; `seed` is accepted (for protocol compatibility) and
    ignored.
    """

    requests: tuple
    name: str = "replay"
    start_s: float = 0.0
    stop_s: float | None = None
    loop: bool = True

    @classmethod
    def from_requests(cls, requests, name: str = "replay",
                      start_s: float = 0.0, stop_s: float | None = None,
                      loop: bool = True) -> "ReplayScenario":
        return cls(tuple(requests), name=name, start_s=start_s,
                   stop_s=stop_s, loop=loop)

    @classmethod
    def from_csv(cls, path, name: str | None = None, start_s: float = 0.0,
                 stop_s: float | None = None,
                 loop: bool = True) -> "ReplayScenario":
        base = os.path.splitext(os.path.basename(os.fspath(path)))[0]
        return cls.from_requests(load_csv(path), name=name or base,
                                 start_s=start_s, stop_s=stop_s, loop=loop)

    def generate(self, rate_rps: float = 60.0, duration_s: float = 120.0,
                 seed: int = 0) -> list[Request]:
        window = splice(list(self.requests), self.start_s, self.stop_s)
        if not window:
            return []
        if max(r.arrival_s for r in window) <= 0:
            # Degenerate window (one request, or identical timestamps):
            # nothing to rescale — replay the burst at t=0 as-is.
            return window
        scaled = rescale_rate(window, rate_rps)
        # Rescaling to mean rate r makes the span exactly len/r — also
        # the tiling period, so recorded gaps survive across the seam.
        period = len(scaled) / rate_rps
        out: list[Request] = []
        offset = 0.0
        while offset < duration_s:
            for r in scaled:
                t = r.arrival_s + offset
                if t >= duration_s:
                    break
                out.append(dataclasses.replace(r, req_id=len(out),
                                               arrival_s=t))
            if not self.loop:
                break
            offset += period
        return out
