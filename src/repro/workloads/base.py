"""Core workload abstractions: `Request`, the `WorkloadScenario` protocol,
and the two composable layers every synthetic scenario is built from —
an `ArrivalProcess` (when requests land) and a `TokenMix` (how big they
are).

The paper's evaluation (§6.1.2) replays Azure LLM inference traces, which
characterize each request by (arrival time, input tokens, output tokens).
`Request` is exactly that triple plus an id. A scenario is anything that
can turn (rate, duration, seed) into a deterministic `Request` list; the
built-in `Scenario` composition interleaves one arrival-gap draw with one
token-mix draw per request from a single seeded generator, so scenarios
are reproducible bit-for-bit across runs and platforms.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One LLM inference request (Azure LLM trace schema)."""

    req_id: int
    arrival_s: float
    input_tokens: int
    output_tokens: int


@runtime_checkable
class WorkloadScenario(Protocol):
    """Anything that deterministically produces a request stream.

    Implementations must be pure in (rate_rps, duration_s, seed): calling
    `generate` twice with the same arguments returns equal lists.
    """

    name: str

    def generate(self, rate_rps: float = 60.0, duration_s: float = 120.0,
                 seed: int = 0) -> list[Request]:
        ...


class ArrivalProcess(Protocol):
    """Stateful arrival-time layer: produces inter-arrival gaps.

    `next_gap(rng, t)` returns the gap from current time `t` to the next
    arrival, drawing only from `rng` (never from global state). Processes
    may keep per-run state (e.g. the MMPP regime), so a fresh instance is
    built for every `generate` call.
    """

    def next_gap(self, rng: np.random.Generator, t: float) -> float:
        ...


class TokenMix(Protocol):
    """Stateless token-size layer: samples one request's token counts."""

    def sample_one(self, rng: np.random.Generator) -> tuple[int, int]:
        ...


def request_stats(requests: list[Request]) -> dict:
    """Summary statistics of a request stream.

    An empty stream returns an explicit all-zero dict (no NaNs from
    zero-length medians) so callers can always read the same keys.
    """
    if not requests:
        return {"n_requests": 0, "input_median": 0.0, "input_mean": 0.0,
                "output_mean": 0.0, "output_median": 0.0,
                "duration_s": 0.0, "mean_rate_rps": 0.0}
    n_in = np.array([r.input_tokens for r in requests])
    n_out = np.array([r.output_tokens for r in requests])
    span = max(r.arrival_s for r in requests)
    return {
        "n_requests": len(requests),
        "input_median": float(np.median(n_in)),
        "input_mean": float(n_in.mean()),
        "output_mean": float(n_out.mean()),
        "output_median": float(np.median(n_out)),
        "duration_s": float(span),
        "mean_rate_rps": float(len(requests) / span) if span > 0 else 0.0,
    }
