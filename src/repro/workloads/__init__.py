"""Pluggable workload-scenario subsystem (paper §6.1.2 opened wide).

The paper evaluates against replayed Azure LLM inference traces with
distinct temporal patterns; this package makes the workload axis
pluggable the same way `repro.core.policies` made the policy axis
pluggable. Three composable layers:

  arrivals  — *when* requests land (Poisson, diurnal, MMPP bursts,
              flash crowd, constant-rate)
  mixes     — *how big* requests are (Splitwise conversation / code,
              long-context, blends)
  traceio   — ingest/replay/export real traces in the Azure CSV schema

and a string-keyed registry of named scenarios:

    from repro.workloads import get_scenario, available_scenarios

    trace = get_scenario("conversation-mmpp").generate(
        rate_rps=60, duration_s=120, seed=0)

Experiments select scenarios by name: `ExperimentConfig(scenario=...)`,
and `run_policy_sweep(..., scenarios=(...))` runs policy x scenario
grids. Adding a scenario:

    from repro.workloads import Scenario, register_scenario, mixes

    @register_scenario("my-scenario")
    def my_scenario() -> Scenario:
        return Scenario("my-scenario", mixes.CONVERSATION, my_arrivals)
"""
from repro.workloads import arrivals, mixes, traceio
from repro.workloads.base import (ArrivalProcess, Request, TokenMix,
                                  WorkloadScenario, request_stats)
from repro.workloads.registry import (available_scenarios,
                                      canonical_scenario_name, get_scenario,
                                      register_scenario)
# Importing the module registers the built-in scenario library.
from repro.workloads.scenario import Scenario
from repro.workloads.traceio import (ReplayScenario, export_csv,
                                     export_csv_str, load_csv, rescale_rate,
                                     splice, time_scale)

__all__ = [
    "ArrivalProcess", "Request", "TokenMix", "WorkloadScenario",
    "request_stats", "available_scenarios", "canonical_scenario_name",
    "get_scenario", "register_scenario", "Scenario", "ReplayScenario",
    "export_csv", "export_csv_str", "load_csv", "rescale_rate", "splice",
    "time_scale", "arrivals", "mixes", "traceio",
]
