"""Arrival processes — the *when* layer of a workload scenario.

All processes draw from the single generator a `Scenario.generate` call
owns, one `next_gap` at a time, so the composed request stream is
deterministic per seed. `rate_rps` is always the *mean* cluster request
rate: temporal shapes (diurnal swing, MMPP bursts, flash crowds)
modulate around it without changing the delivered request volume, which
keeps throughput-normalized comparisons across scenarios honest.

Non-homogeneous processes use Lewis thinning: candidate arrivals are
drawn at the peak rate and accepted with probability rate(t)/peak — the
standard exact method for a time-varying Poisson process.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


def _thinned_gap(rng: np.random.Generator, t: float, peak: float,
                 rate) -> float:
    """One inter-arrival gap of a non-homogeneous Poisson process via
    Lewis thinning: candidates at `peak`, accepted w.p. rate(t)/peak."""
    t_cand = t
    while True:
        t_cand += rng.exponential(1.0 / peak)
        if rng.random() * peak <= rate(t_cand):
            return t_cand - t


@dataclasses.dataclass
class PoissonArrivals:
    """Homogeneous Poisson process (the paper's / Splitwise default)."""

    rate_rps: float

    def next_gap(self, rng: np.random.Generator, t: float) -> float:
        return rng.exponential(1.0 / self.rate_rps)


@dataclasses.dataclass
class ConstantArrivals:
    """Deterministic fixed-gap arrivals (closed-loop load generators)."""

    rate_rps: float

    def next_gap(self, rng: np.random.Generator, t: float) -> float:
        return 1.0 / self.rate_rps


@dataclasses.dataclass
class DiurnalPoissonArrivals:
    """Sinusoidal day/night-modulated Poisson process.

    rate(t) = rate_rps * (1 + amplitude * sin(2*pi*t/period + phase));
    with the default amplitude 0.6 the peak:trough ratio is 4:1, the
    order of the day/night swing in the Azure LLM inference traces the
    paper (and EcoServe, arXiv:2502.05043) evaluate against. `phase`
    defaults so a trace starting at t=0 begins mid-ramp.
    """

    rate_rps: float
    amplitude: float = 0.6
    period_s: float = 86_400.0
    phase: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(f"amplitude must be in [0, 1), got "
                             f"{self.amplitude}")

    def rate(self, t: float) -> float:
        return self.rate_rps * (1.0 + self.amplitude * math.sin(
            2.0 * math.pi * t / self.period_s + self.phase))

    def next_gap(self, rng: np.random.Generator, t: float) -> float:
        peak = self.rate_rps * (1.0 + self.amplitude)
        return _thinned_gap(rng, t, peak, self.rate)


@dataclasses.dataclass
class MMPPArrivals:
    """Two-state Markov-modulated Poisson process (bursty load).

    Alternates between a quiet regime and a burst regime with
    exponentially distributed sojourns. Regime rates are solved so the
    long-run mean equals `rate_rps`:

        mean = (r_quiet * s_quiet + r_burst * s_burst) / (s_quiet + s_burst)

    with r_burst = burst_factor * r_quiet.
    """

    rate_rps: float
    burst_factor: float = 6.0
    quiet_sojourn_s: float = 20.0
    burst_sojourn_s: float = 4.0
    _state: int = dataclasses.field(default=0, repr=False)       # 0=quiet
    _switch_in: float | None = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        if self.burst_factor < 1.0:
            raise ValueError("burst_factor must be >= 1")
        s_q, s_b = self.quiet_sojourn_s, self.burst_sojourn_s
        r_quiet = self.rate_rps * (s_q + s_b) / (
            s_q + self.burst_factor * s_b)
        self._rates = (r_quiet, self.burst_factor * r_quiet)
        self._sojourns = (s_q, s_b)

    def next_gap(self, rng: np.random.Generator, t: float) -> float:
        gap = 0.0
        if self._switch_in is None:
            # Start from the stationary regime distribution, else short
            # traces (always opening in the quiet regime) systematically
            # under-deliver the configured mean rate.
            s_q, s_b = self._sojourns
            self._state = 0 if rng.random() < s_q / (s_q + s_b) else 1
            self._switch_in = rng.exponential(self._sojourns[self._state])
        while True:
            arrival = rng.exponential(1.0 / self._rates[self._state])
            if arrival < self._switch_in:
                self._switch_in -= arrival
                return gap + arrival
            # The regime switches first; the leftover exponential beyond
            # the switch is discarded (memorylessness makes this exact).
            gap += self._switch_in
            self._state = 1 - self._state
            self._switch_in = rng.exponential(self._sojourns[self._state])


@dataclasses.dataclass
class FlashCrowdArrivals:
    """Baseline Poisson load with one rectangular traffic spike.

    Outside [spike_start_s, spike_start_s + spike_duration_s) requests
    arrive at a reduced base rate; inside, at `spike_multiplier` times
    the base rate. The base rate is solved per-duration at scenario
    build time so the *mean* over `norm_duration_s` equals `rate_rps`.
    """

    rate_rps: float
    spike_multiplier: float = 8.0
    spike_start_s: float = 40.0
    spike_duration_s: float = 20.0
    norm_duration_s: float = 120.0

    def __post_init__(self):
        if self.spike_multiplier < 1.0:
            raise ValueError("spike_multiplier must be >= 1")
        # volume = base*(D - d) + base*mult*d  ==  rate_rps * D, where d
        # is the spike's overlap with [0, D) — a spike extending past
        # the trace end contributes only its in-trace part.
        lo = min(self.spike_start_s, self.norm_duration_s)
        hi = min(self.spike_start_s + self.spike_duration_s,
                 self.norm_duration_s)
        d = max(0.0, hi - lo)
        base = self.rate_rps * self.norm_duration_s / (
            self.norm_duration_s + (self.spike_multiplier - 1.0) * d)
        self._base = base

    def rate(self, t: float) -> float:
        in_spike = (self.spike_start_s <= t
                    < self.spike_start_s + self.spike_duration_s)
        return self._base * (self.spike_multiplier if in_spike else 1.0)

    def next_gap(self, rng: np.random.Generator, t: float) -> float:
        peak = self._base * self.spike_multiplier
        return _thinned_gap(rng, t, peak, self.rate)
