"""Token mixes — the *how big* layer of a workload scenario.

Each mix samples (input_tokens, output_tokens) for one request. The
lognormal fits follow the Splitwise [26] characterization of the public
Azure LLM inference traces, the same source the paper replays:

  conversation — median input ~1020 / mean ~1155, mean output ~211
  code         — much longer prompts (median ~2k) and very short
                 completions (median ~15): the classic code-assist shape
  long-context — document-scale prompts (median ~6k) with report-length
                 outputs; stresses KV-transfer and prefill paths

`sample_one` draws input then output from the shared generator — the
exact draw order the pre-subsystem `sim.trace.generate` used, which is
what keeps the `conversation-poisson` scenario bit-identical to it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LognormalMix:
    """Independent clipped-lognormal input/output token distributions."""

    input_logmean: float
    input_logstd: float
    output_logmean: float
    output_logstd: float
    input_min: int = 8
    input_max: int = 8192
    output_min: int = 1
    output_max: int = 2048

    def sample_one(self, rng: np.random.Generator) -> tuple[int, int]:
        n_in = int(np.clip(
            rng.lognormal(self.input_logmean, self.input_logstd),
            self.input_min, self.input_max))
        n_out = int(np.clip(
            rng.lognormal(self.output_logmean, self.output_logstd),
            self.output_min, self.output_max))
        return n_in, n_out


@dataclasses.dataclass(frozen=True)
class BlendedMix:
    """Probabilistic mixture of component mixes (heterogeneous traffic).

    `components` is ((weight, mix), ...); weights need not be normalized.
    One uniform draw selects the component, then the component samples —
    three draws per request, deterministic per seed.
    """

    components: tuple

    def __post_init__(self):
        total = sum(w for w, _ in self.components)
        if not self.components or total <= 0:
            raise ValueError("BlendedMix needs positively weighted "
                             "components")
        cum, acc = [], 0.0
        for w, _ in self.components:
            acc += w / total
            cum.append(acc)
        object.__setattr__(self, "_cum", tuple(cum))

    def sample_one(self, rng: np.random.Generator) -> tuple[int, int]:
        u = rng.random()
        for edge, (_, mix) in zip(self._cum, self.components):
            if u <= edge:
                return mix.sample_one(rng)
        return self.components[-1][1].sample_one(rng)


# Splitwise Azure-conversation fit — field-for-field the defaults the
# deprecated `sim.trace.TraceConfig` shipped (bit-exactness contract).
CONVERSATION = LognormalMix(
    input_logmean=6.93, input_logstd=0.85,      # median ~1020 tokens
    output_logmean=4.92, output_logstd=0.95,    # mean ~210 tokens
    input_max=8192, output_max=2048,
)

# Splitwise Azure-code fit: long prompts, short completions.
CODE = LognormalMix(
    input_logmean=7.57, input_logstd=0.9,       # median ~1940 tokens
    output_logmean=2.7, output_logstd=0.8,      # median ~15 tokens
    input_max=8192, output_max=256,
)

# Document-scale prompts with report-length outputs.
LONG_CONTEXT = LognormalMix(
    input_logmean=8.7, input_logstd=0.6,        # median ~6000 tokens
    output_logmean=5.7, output_logstd=0.8,      # median ~300 tokens
    input_max=16384, output_max=4096,
)

# Production-like blend: conversation-dominated with a code tail.
BLENDED = BlendedMix(components=((0.7, CONVERSATION), (0.3, CODE)))
