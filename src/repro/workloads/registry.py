"""String-keyed registry of workload scenarios (mirrors
`repro.core.policies.registry`).

    @register_scenario("conversation-poisson")
    def conversation_poisson() -> Scenario: ...

    sc = get_scenario("conversation-poisson")       # fresh scenario
    sc = get_scenario("conversation-mmpp", burst_factor=8.0)

Names are case-insensitive and underscore/hyphen-insensitive. Factories
(not instances) are registered so every `get_scenario` call can take
constructor options and returns an independent scenario object.
"""
from __future__ import annotations

from typing import Callable

from repro.workloads.base import WorkloadScenario

_REGISTRY: dict[str, Callable[..., WorkloadScenario]] = {}


def canonical_scenario_name(name: str) -> str:
    """Normalize a user-supplied scenario key ("Conv_Poisson" style)."""
    return str(name).strip().lower().replace("_", "-")


def register_scenario(name: str):
    """Decorator: register a factory returning a `WorkloadScenario`."""
    key = canonical_scenario_name(name)

    def deco(factory: Callable[..., WorkloadScenario]):
        if not callable(factory):
            raise TypeError(f"@register_scenario({name!r}) expects a "
                            f"callable factory, got {factory!r}")
        prev = _REGISTRY.get(key)
        if prev is not None and prev is not factory:
            raise ValueError(f"scenario name {key!r} already registered "
                             f"to {getattr(prev, '__name__', prev)!r}")
        _REGISTRY[key] = factory
        return factory

    return deco


def get_scenario(name: str, **opts) -> WorkloadScenario:
    """Build the scenario registered under `name` with `opts`."""
    key = canonical_scenario_name(name)
    try:
        factory = _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown workload scenario {name!r}; available: "
            f"{', '.join(available_scenarios())}") from None
    scenario = factory(**opts)
    if not isinstance(scenario, WorkloadScenario):
        raise TypeError(f"scenario factory for {key!r} returned "
                        f"{scenario!r}, which lacks generate()/name")
    return scenario


def available_scenarios() -> tuple[str, ...]:
    """Sorted canonical names of every registered scenario."""
    return tuple(sorted(_REGISTRY))
