"""String-keyed registry of workload scenarios (mirrors
`repro.core.policies.registry`).

    @register_scenario("conversation-poisson")
    def conversation_poisson() -> Scenario: ...

    sc = get_scenario("conversation-poisson")       # fresh scenario
    sc = get_scenario("conversation-mmpp", burst_factor=8.0)

Names are case-insensitive and underscore/hyphen-insensitive. Factories
(not instances) are registered so every `get_scenario` call can take
constructor options and returns an independent scenario object.

The mechanics live in the shared `repro.registry.Registry` (one
implementation for the policy / scenario / router axes).
"""
from __future__ import annotations

from repro.registry import Registry, canonical_name
from repro.workloads.base import WorkloadScenario


def _check_scenario(key: str, scenario):
    if not isinstance(scenario, WorkloadScenario):
        raise TypeError(f"scenario factory for {key!r} returned "
                        f"{scenario!r}, which lacks generate()/name")
    return scenario


_SCENARIOS = Registry(
    noun="scenario", kind="workload scenario",
    decorator="register_scenario", expects="callable factory",
    check=callable, set_name=False, quote_prev=True,
    post_get=_check_scenario,
)
#: historical module-level alias (tests clean up through it)
_REGISTRY = _SCENARIOS.store


def canonical_scenario_name(name: str) -> str:
    """Normalize a user-supplied scenario key ("Conv_Poisson" style)."""
    return canonical_name(name)


def register_scenario(name: str):
    """Decorator: register a factory returning a `WorkloadScenario`."""
    return _SCENARIOS.register(name)


def get_scenario(name: str, **opts) -> WorkloadScenario:
    """Build the scenario registered under `name` with `opts`."""
    return _SCENARIOS.get(name, **opts)


def available_scenarios() -> tuple[str, ...]:
    """Sorted canonical names of every registered scenario."""
    return _SCENARIOS.available()
