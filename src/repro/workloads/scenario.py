"""`Scenario` — composition of an arrival process and a token mix —
plus the built-in scenario library.

A `Scenario` turns (rate_rps, duration_s, seed) into a `Request` list by
interleaving one arrival-gap draw with one token-mix draw per request
from a single `np.random.default_rng(seed)`. For the homogeneous-Poisson
conversation scenario this reproduces the pre-subsystem
`sim.trace.generate` draw sequence exactly, so `conversation-poisson`
is bit-identical to the legacy generator (golden-pinned in
tests/test_workloads.py).

Built-ins registered here (see `available_scenarios()`):

  conversation-poisson    — the paper's default Azure-conversation load
  conversation-constant   — same mix, deterministic fixed-gap arrivals
  conversation-diurnal    — day/night sinusoidal swing (EcoServe-style)
  conversation-mmpp       — two-state Markov-modulated bursts
  conversation-flashcrowd — rectangular traffic spike mid-trace
  code-poisson            — Splitwise code mix (long in / short out)
  longcontext-poisson     — document-scale prompts
  mixed-poisson           — 70/30 conversation/code blend
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.workloads import arrivals as arr
from repro.workloads import mixes
from repro.workloads.base import ArrivalProcess, Request, TokenMix
from repro.workloads.registry import register_scenario


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named (arrival process x token mix) workload scenario.

    `arrival_factory(rate_rps, duration_s)` builds a fresh (possibly
    stateful) arrival process per generate call; `mix` is stateless and
    shared.
    """

    name: str
    mix: TokenMix
    arrival_factory: Callable[[float, float], ArrivalProcess]
    description: str = ""

    def generate(self, rate_rps: float = 60.0, duration_s: float = 120.0,
                 seed: int = 0) -> list[Request]:
        if rate_rps <= 0 or duration_s <= 0:
            raise ValueError("rate_rps and duration_s must be positive, "
                             f"got {rate_rps}/{duration_s}")
        rng = np.random.default_rng(seed)
        process = self.arrival_factory(rate_rps, duration_s)
        requests: list[Request] = []
        t = 0.0
        while True:
            t += process.next_gap(rng, t)
            if t >= duration_s:
                break
            n_in, n_out = self.mix.sample_one(rng)
            requests.append(Request(len(requests), t, n_in, n_out))
        return requests


# --------------------------- built-ins -------------------------------- #

@register_scenario("conversation-poisson")
def conversation_poisson() -> Scenario:
    return Scenario(
        "conversation-poisson", mixes.CONVERSATION,
        lambda rate, dur: arr.PoissonArrivals(rate),
        "Azure-conversation mix, homogeneous Poisson arrivals (the "
        "paper's default; bit-exact vs the legacy TraceConfig generator)")


@register_scenario("conversation-constant")
def conversation_constant() -> Scenario:
    return Scenario(
        "conversation-constant", mixes.CONVERSATION,
        lambda rate, dur: arr.ConstantArrivals(rate),
        "Azure-conversation mix, deterministic fixed-gap arrivals "
        "(closed-loop load generator)")


@register_scenario("conversation-diurnal")
def conversation_diurnal(amplitude: float = 0.6,
                         period_s: float | None = None,
                         phase: float = 0.0) -> Scenario:
    # By default one full diurnal cycle is time-compressed into the
    # trace (period = duration): a wall-clock 86400 s period would be
    # flat — indistinguishable from plain Poisson — over the 30-120 s
    # traces the benchmarks run. Pass period_s for wall-clock replay.
    return Scenario(
        "conversation-diurnal", mixes.CONVERSATION,
        lambda rate, dur: arr.DiurnalPoissonArrivals(
            rate, amplitude=amplitude,
            period_s=period_s if period_s is not None else dur,
            phase=phase),
        "Azure-conversation mix with a sinusoidal day/night rate swing "
        f"(peak:trough {(1 + amplitude) / (1 - amplitude):.1f}:1; one "
        "cycle per trace unless period_s is given)")


@register_scenario("conversation-mmpp")
def conversation_mmpp(burst_factor: float = 6.0,
                      quiet_sojourn_s: float = 20.0,
                      burst_sojourn_s: float = 4.0) -> Scenario:
    return Scenario(
        "conversation-mmpp", mixes.CONVERSATION,
        lambda rate, dur: arr.MMPPArrivals(
            rate, burst_factor=burst_factor,
            quiet_sojourn_s=quiet_sojourn_s,
            burst_sojourn_s=burst_sojourn_s),
        "Azure-conversation mix under two-state Markov-modulated bursts "
        f"({burst_factor:g}x burst regime)")


@register_scenario("conversation-flashcrowd")
def conversation_flashcrowd(spike_multiplier: float = 8.0,
                            spike_start_frac: float = 1 / 3,
                            spike_duration_frac: float = 1 / 6) -> Scenario:
    return Scenario(
        "conversation-flashcrowd", mixes.CONVERSATION,
        lambda rate, dur: arr.FlashCrowdArrivals(
            rate, spike_multiplier=spike_multiplier,
            spike_start_s=spike_start_frac * dur,
            spike_duration_s=spike_duration_frac * dur,
            norm_duration_s=dur),
        "Azure-conversation mix with a rectangular flash-crowd spike "
        f"({spike_multiplier:g}x for {spike_duration_frac:.0%} of the "
        "trace)")


@register_scenario("code-poisson")
def code_poisson() -> Scenario:
    return Scenario(
        "code-poisson", mixes.CODE,
        lambda rate, dur: arr.PoissonArrivals(rate),
        "Splitwise Azure-code mix (long prompts, short completions), "
        "Poisson arrivals")


@register_scenario("longcontext-poisson")
def longcontext_poisson() -> Scenario:
    return Scenario(
        "longcontext-poisson", mixes.LONG_CONTEXT,
        lambda rate, dur: arr.PoissonArrivals(rate),
        "Document-scale prompts with report-length outputs, Poisson "
        "arrivals")


@register_scenario("mixed-poisson")
def mixed_poisson(conversation_weight: float = 0.7) -> Scenario:
    mix = mixes.BlendedMix(components=(
        (conversation_weight, mixes.CONVERSATION),
        (1.0 - conversation_weight, mixes.CODE)))
    return Scenario(
        "mixed-poisson", mix,
        lambda rate, dur: arr.PoissonArrivals(rate),
        f"{conversation_weight:.0%} conversation / "
        f"{1 - conversation_weight:.0%} code blend, Poisson arrivals")
