"""Built-in carbon models (paper §2.1/§2.2, §6.2 Fig. 7; EcoServe).

Amortization accounts embodied carbon over the asset's operating life:
a CPU with E kgCO2eq embodied over L years emits E/L kgCO2eq per year.
Slowing aging extends L; how observed degradation maps to L is exactly
what the pluggable models disagree about:

  linear-extension      — the paper's model: life scales with the ratio
                          of reference to technique degradation
                          (conservative; this is the 37.67% headline)
  reliability-threshold — life ends when projected degradation crosses
                          the frequency guardband (paper §2.2); NBTI's
                          dVth = ADF * t^n inverts to a ratio^(1/n)
                          extension (optimistic upper bound)
  operational-embodied  — EcoServe-style total: embodied amortization
                          from a wrapped lifetime model *plus*
                          operational carbon priced by a grid
                          `CarbonIntensity` signal

Reporting the same experiment under several models gives an
EcoLogits-style range over explicit assumptions instead of one number.
"""
from __future__ import annotations

from repro.carbon.base import (BASELINE_LIFESPAN_YEARS, CPU_EMBODIED_KGCO2EQ,
                               CarbonFootprint, CarbonModel,
                               LifetimeEstimate, MAX_EXTENSION_FACTOR,
                               MIN_EXTENSION_FACTOR)
from repro.carbon.intensity import CarbonIntensity, get_intensity
from repro.carbon.registry import get_carbon_model, register_carbon_model

#: NBTI reaction-diffusion time exponent (paper §3.2); must match the
#: `repro.core.aging.AgingParams.n` default — duplicated here (rather
#: than imported) so the carbon layer never imports `repro.core`, which
#: itself re-exports this package through `repro.core.carbon`.
NBTI_TIME_EXPONENT = 1.0 / 6.0


def _amortize(model_name: str, ext: float, embodied_kg: float,
              base_life_years: float) -> LifetimeEstimate:
    """Turn an extension factor into the amortized estimate — the
    arithmetic shared by every lifetime model (kept in one place, and in
    this exact operation order: it is golden-pinned bit-exact against
    the pre-subsystem `carbon.estimate`)."""
    life = base_life_years * ext
    yearly = embodied_kg / life
    base_yearly = embodied_kg / base_life_years
    return LifetimeEstimate(
        extension_factor=ext,
        extended_life_years=life,
        yearly_kgco2eq=yearly,
        baseline_yearly_kgco2eq=base_yearly,
        reduction_frac=1.0 - yearly / base_yearly,
        model=model_name,
        baseline_life_years=base_life_years,
    )

#: historical name — `repro.core.carbon.CarbonEstimate` callers keep
#: working; the type gained `model` / `baseline_life_years` tail fields.
CarbonEstimate = LifetimeEstimate


def lifetime_extension(deg_linux: float, deg_technique: float) -> float:
    """Linear lifetime-extension model. Degradations must be >= 0.

    A technique that halted aging entirely within the horizon
    (`deg_technique <= 0`) has a divergent ratio; `MAX_EXTENSION_FACTOR`
    stands in for it. Positive ratios are NOT clamped (only floored at
    `MIN_EXTENSION_FACTOR`) — the pre-subsystem `carbon.estimate` never
    clamped them, and this function is pinned bit-exact against it."""
    if deg_technique <= 0.0:
        return MAX_EXTENSION_FACTOR
    return max(deg_linux / deg_technique, MIN_EXTENSION_FACTOR)


@register_carbon_model("linear-extension")
class LinearExtensionModel(CarbonModel):
    """The paper's linear lifetime-extension model (§2.1):

        extension = deg_ref / deg_technique
        life'     = base_life * extension
        yearly'   = E / life'
        saving    = 1 - yearly'/yearly = 1 - 1/extension

    Bit-exact with the pre-subsystem `repro.core.carbon.estimate`
    (golden-pinned in tests/test_carbon.py).
    """

    def __init__(self, embodied_kg: float = CPU_EMBODIED_KGCO2EQ,
                 base_life_years: float = BASELINE_LIFESPAN_YEARS):
        if embodied_kg <= 0.0 or base_life_years <= 0.0:
            raise ValueError("embodied_kg and base_life_years must be > 0, "
                             f"got {embodied_kg}/{base_life_years}")
        self.embodied_kg = embodied_kg
        self.base_life_years = base_life_years

    def lifetime(self, deg_ref: float,
                 deg_technique: float) -> LifetimeEstimate:
        return _amortize(self.name, lifetime_extension(deg_ref,
                                                       deg_technique),
                         self.embodied_kg, self.base_life_years)


@register_carbon_model("reliability-threshold")
class ReliabilityThresholdModel(CarbonModel):
    """Guardband-crossing lifetime model (paper §2.2).

    A CPU's service life ends when aging-induced frequency degradation
    crosses the design guardband. Both CPUs are observed over the same
    horizon t_obs, and NBTI degradation follows dVth = ADF * t^n, so a
    core's time-to-guardband is t_obs * (D_guard / deg)^(1/n) and the
    ratio of technique to reference life is

        extension = (deg_ref / deg_technique)^(1/n)

    independent of the guardband level itself. The reference CPU is
    defined to exhaust its guardband at the refresh cycle
    (`base_life_years`), anchoring absolute life. With the paper's
    n = 1/6 the extension is ratio^6 — the physics-faithful *optimistic*
    bound, where linear-extension is the conservative one; the cap
    (`max_extension`, default `MAX_EXTENSION_FACTOR`) therefore binds
    often and is part of the reported estimate.
    """

    def __init__(self, embodied_kg: float = CPU_EMBODIED_KGCO2EQ,
                 base_life_years: float = BASELINE_LIFESPAN_YEARS,
                 n: float = NBTI_TIME_EXPONENT,
                 max_extension: float = MAX_EXTENSION_FACTOR):
        if embodied_kg <= 0.0 or base_life_years <= 0.0:
            raise ValueError("embodied_kg and base_life_years must be > 0, "
                             f"got {embodied_kg}/{base_life_years}")
        if not 0.0 < n <= 1.0:
            raise ValueError(f"time exponent n must be in (0, 1], got {n}")
        if max_extension < 1.0:
            raise ValueError(f"max_extension must be >= 1, got "
                             f"{max_extension}")
        self.embodied_kg = embodied_kg
        self.base_life_years = base_life_years
        self.n = n
        self.max_extension = max_extension

    def lifetime(self, deg_ref: float,
                 deg_technique: float) -> LifetimeEstimate:
        if deg_technique <= 0.0:
            ext = self.max_extension
        else:
            ratio = max(deg_ref / deg_technique, MIN_EXTENSION_FACTOR)
            ext = min(ratio ** (1.0 / self.n), self.max_extension)
            ext = max(ext, MIN_EXTENSION_FACTOR)
        return _amortize(self.name, ext, self.embodied_kg,
                         self.base_life_years)


# ------------------------------------------------------------------ #
# Fig.-1-style server power envelope: operational vs embodied carbon of
# an inference server as grid carbon intensity falls (paper Fig. 1).
# ------------------------------------------------------------------ #
SERVER_GPU_TDP_W = 4 * 700.0        # 4x accelerator server (H100-class)
SERVER_OTHER_TDP_W = 800.0          # host CPU/mem/fans
# Accelerator embodied is comparatively small: Li'24 (paper [18]) finds
# the CPU die + mainboard dominate inference-server embodied carbon.
GPU_EMBODIED_KGCO2EQ = 150.0
HOURS_PER_YEAR = 8766.0


@register_carbon_model("operational-embodied")
class OperationalEmbodiedModel(CarbonModel):
    """EcoServe-style total footprint: embodied amortization from a
    wrapped lifetime model plus grid-intensity-priced operational
    carbon.

        operational = served energy [kWh/yr] * mean intensity [g/kWh]
        embodied    = E_cpu / life'(aging)  +  E_gpu / gpu_life

    `intensity` is a `CarbonIntensity` instance or a spec name
    ("constant" / "diurnal" / "trace" / "trace-csv") built with
    `intensity_opts`; `lifetime_model` is any registered lifetime model
    (the embodied axis stays pluggable inside the total)."""

    def __init__(self, intensity="constant", intensity_opts=None,
                 lifetime_model: str = "linear-extension",
                 lifetime_opts=None,
                 utilization: float = 0.6,
                 gpu_tdp_w: float = SERVER_GPU_TDP_W,
                 other_tdp_w: float = SERVER_OTHER_TDP_W,
                 gpu_embodied_kg: float = GPU_EMBODIED_KGCO2EQ,
                 gpu_life_years: float = BASELINE_LIFESPAN_YEARS):
        if not 0.0 <= utilization <= 1.0:
            raise ValueError(f"utilization must be in [0, 1], got "
                             f"{utilization}")
        if gpu_life_years <= 0.0:
            raise ValueError(f"gpu_life_years must be > 0, got "
                             f"{gpu_life_years}")
        self.intensity: CarbonIntensity = get_intensity(
            intensity, **dict(intensity_opts or {}))
        self.lifetime_model: CarbonModel = get_carbon_model(
            lifetime_model, **dict(lifetime_opts or {}))
        self.utilization = utilization
        self.gpu_tdp_w = gpu_tdp_w
        self.other_tdp_w = other_tdp_w
        self.gpu_embodied_kg = gpu_embodied_kg
        self.gpu_life_years = gpu_life_years

    def lifetime(self, deg_ref: float,
                 deg_technique: float) -> LifetimeEstimate:
        return self.lifetime_model.lifetime(deg_ref, deg_technique)

    def footprint(self, deg_ref: float, deg_technique: float,
                  utilization: float | None = None,
                  energy_kwh_per_year: float | None = None
                  ) -> CarbonFootprint:
        """Total yearly footprint. `energy_kwh_per_year` feeds MEASURED
        energy (e.g. an `ExperimentResult`'s power-model accounting,
        annualized) in place of the flat `tdp * utilization` stand-in;
        the stand-in remains the default so existing callers keep their
        exact numbers."""
        if energy_kwh_per_year is None:
            util = self.utilization if utilization is None else utilization
            energy_kwh = (self.gpu_tdp_w + self.other_tdp_w) \
                * util * HOURS_PER_YEAR / 1000.0
        else:
            if energy_kwh_per_year < 0.0:
                raise ValueError(f"energy_kwh_per_year must be >= 0, got "
                                 f"{energy_kwh_per_year}")
            energy_kwh = energy_kwh_per_year
        mean_ci = self.intensity.mean_g_per_kwh()
        operational = energy_kwh * mean_ci / 1000.0
        cpu_embodied = self.lifetime(deg_ref, deg_technique).yearly_kgco2eq
        gpu_embodied = self.gpu_embodied_kg / self.gpu_life_years
        return CarbonFootprint(
            operational_kg=operational,
            cpu_embodied_kg=cpu_embodied,
            gpu_embodied_kg=gpu_embodied,
            total_kg=operational + cpu_embodied + gpu_embodied,
            carbon_intensity_g_per_kwh=mean_ci,
            model=self.name,
        )


# ------------------------------------------------------------------ #
# Convenience functions kept from the pre-subsystem repro.core.carbon
# module (thin wrappers over the registered models).
# ------------------------------------------------------------------ #
def estimate(deg_linux: float, deg_technique: float,
             embodied_kg: float = CPU_EMBODIED_KGCO2EQ,
             base_life_years: float = BASELINE_LIFESPAN_YEARS
             ) -> LifetimeEstimate:
    """The paper's linear model in one call (== `linear-extension`)."""
    return LinearExtensionModel(
        embodied_kg=embodied_kg,
        base_life_years=base_life_years).lifetime(deg_linux, deg_technique)


def cluster_yearly_emissions(
        per_server_estimates: list[LifetimeEstimate]) -> float:
    return sum(e.yearly_kgco2eq for e in per_server_estimates)


def reference_degradation(params, elapsed_s: float) -> float:
    """Worst-case mean frequency degradation of a fresh core (an
    `aging.AgingParams`) aged continuously at active-allocated stress
    for `elapsed_s` — the linear-aging reference the carbon-greedy
    router and the fleet carbon metrics normalize against (stands in
    for the `linux` baseline of `lifetime_extension` within a single
    run)."""
    # Imported lazily: `repro.core` re-exports this package through
    # `repro.core.carbon`, so a module-level import would be circular.
    from repro.core import aging, temperature
    dvth = aging.dvth_after(params, temperature.TEMP_ACTIVE_ALLOCATED_C,
                            temperature.STRESS_ACTIVE,
                            max(elapsed_s, 1e-9))
    return params.f_nominal * dvth / params.headroom


def yearly_footprint(carbon_intensity_g_per_kwh: float,
                     utilization: float = 0.6,
                     cpu_life_years: float = BASELINE_LIFESPAN_YEARS,
                     gpu_life_years: float = BASELINE_LIFESPAN_YEARS) -> dict:
    """Yearly kgCO2eq of one inference server split into operational and
    embodied components (the paper's Fig.-1 composition), as a plain
    dict. Thin wrapper over `operational-embodied` with a constant
    intensity; extended CPU life enters via `cpu_life_years`."""
    model = OperationalEmbodiedModel(
        intensity="constant",
        intensity_opts={"value_g_per_kwh": carbon_intensity_g_per_kwh},
        lifetime_opts={"base_life_years": cpu_life_years},
        utilization=utilization, gpu_life_years=gpu_life_years)
    # equal degradations -> extension 1.0 -> embodied = E / cpu_life
    fp = model.footprint(1.0, 1.0)
    return {
        "carbon_intensity": carbon_intensity_g_per_kwh,
        "operational_kg": fp.operational_kg,
        "cpu_embodied_kg": fp.cpu_embodied_kg,
        "gpu_embodied_kg": fp.gpu_embodied_kg,
        "total_kg": fp.total_kg,
        "cpu_embodied_frac": fp.cpu_embodied_kg / fp.total_kg,
    }
