"""Pluggable carbon-accounting subsystem (paper §2, §6.2 Fig. 7).

The fourth experiment axis, mirroring `repro.core.policies`,
`repro.workloads` and `repro.sim.routing`: a string-keyed registry of
`CarbonModel`s that turn observed aging into lifetime and footprint
estimates.

    from repro.carbon import get_carbon_model, available_carbon_models

    est = get_carbon_model("linear-extension").lifetime(0.02, 0.01)
    est.extension_factor, est.yearly_kgco2eq, est.reduction_frac

    fp = get_carbon_model(
        "operational-embodied",
        intensity="diurnal", intensity_opts={"mean": 120.0},
    ).footprint(0.02, 0.01)
    fp.operational_kg, fp.cpu_embodied_kg, fp.embodied_frac

Experiments select models by name — `ExperimentConfig(carbon_model=...,
carbon_opts=...)` — and `run_experiment` prices every machine's
embodied carbon through the configured model. Custom models register
like policies:

    from repro.carbon import CarbonModel, register_carbon_model

    @register_carbon_model("my-model")
    class MyModel(CarbonModel):
        def lifetime(self, deg_ref, deg_technique): ...
"""
from repro.carbon import intensity
from repro.carbon.base import (BASELINE_LIFESPAN_YEARS, CPU_EMBODIED_KGCO2EQ,
                               CarbonFootprint, CarbonModel,
                               LifetimeEstimate, MAX_EXTENSION_FACTOR,
                               MIN_EXTENSION_FACTOR)
from repro.carbon.intensity import (CarbonIntensity, ConstantIntensity,
                                    DiurnalIntensity, ShiftedIntensity,
                                    TraceIntensity, WORLD_AVG_G_PER_KWH,
                                    get_intensity)
# Importing the module registers the built-in model library.
from repro.carbon.models import (CarbonEstimate, GPU_EMBODIED_KGCO2EQ,
                                 HOURS_PER_YEAR, LinearExtensionModel,
                                 NBTI_TIME_EXPONENT,
                                 OperationalEmbodiedModel,
                                 ReliabilityThresholdModel,
                                 SERVER_GPU_TDP_W, SERVER_OTHER_TDP_W,
                                 cluster_yearly_emissions, estimate,
                                 lifetime_extension, reference_degradation,
                                 yearly_footprint)
from repro.carbon.registry import (available_carbon_models,
                                   canonical_carbon_model_name,
                                   get_carbon_model, register_carbon_model)

__all__ = [
    "BASELINE_LIFESPAN_YEARS", "CPU_EMBODIED_KGCO2EQ",
    "MAX_EXTENSION_FACTOR", "MIN_EXTENSION_FACTOR",
    "CarbonEstimate", "CarbonFootprint", "CarbonIntensity", "CarbonModel",
    "ConstantIntensity", "DiurnalIntensity", "ShiftedIntensity",
    "TraceIntensity",
    "LifetimeEstimate", "LinearExtensionModel", "OperationalEmbodiedModel",
    "ReliabilityThresholdModel", "WORLD_AVG_G_PER_KWH",
    "GPU_EMBODIED_KGCO2EQ", "HOURS_PER_YEAR", "NBTI_TIME_EXPONENT",
    "SERVER_GPU_TDP_W",
    "SERVER_OTHER_TDP_W", "available_carbon_models",
    "canonical_carbon_model_name", "cluster_yearly_emissions", "estimate",
    "get_carbon_model", "get_intensity", "intensity", "lifetime_extension",
    "reference_degradation", "register_carbon_model", "yearly_footprint",
]
