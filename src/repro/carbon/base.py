"""Carbon-accounting data model + `CarbonModel` protocol (paper §2).

Carbon accounting is the fourth pluggable axis of the reproduction
(after policies, workload scenarios and cluster routers): a
`CarbonModel` turns observed aging — a reference degradation and a
technique's degradation over the same horizon — into

  * a `LifetimeEstimate` (how much longer the CPU lives, and what the
    amortized yearly *embodied* carbon becomes), and
  * a `CarbonFootprint` (the yearly total, split into embodied and
    grid-intensity-dependent *operational* components, EcoServe-style).

Models register under string keys (`repro.carbon.registry`) and are
selected per experiment via `ExperimentConfig(carbon_model=...)`.

Constants come from Li'24 ("Towards Carbon-efficient LLM Life Cycle",
paper [18]): a typical Linux LLM inference server refreshes hardware
every 3 years, with 278.3 kgCO2eq CPU embodied carbon over that
lifespan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar

CPU_EMBODIED_KGCO2EQ = 278.3   # per server CPU over baseline lifespan [18]
BASELINE_LIFESPAN_YEARS = 3.0  # hardware refresh cycle [18]

#: Extension factor substituted when a technique halts aging entirely
#: within the observation horizon (deg_technique <= 0), where the raw
#: ratio diverges — large but finite (a 300-year CPU life is already far
#: beyond any plausible deployment). `linear-extension` applies it ONLY
#: at that singularity, preserving bit-exactness with the pre-subsystem
#: `carbon.estimate` (which never clamped positive ratios);
#: `reliability-threshold` additionally uses it as a true upper clamp
#: (`max_extension` opt) because its ratio^(1/n) amplification reaches
#: unphysical values at ordinary inputs. Named so the figure drivers and
#: docs can reference the exact bound instead of a magic 100.0 buried in
#: a formula.
MAX_EXTENSION_FACTOR = 100.0
#: Floor on the extension factor: a technique that ages *faster* than
#: the reference still yields a positive, finite life.
MIN_EXTENSION_FACTOR = 1e-6


@dataclasses.dataclass(frozen=True)
class LifetimeEstimate:
    """One model's lifetime/embodied-carbon verdict for one CPU.

    Field order (and the first five names) matches the historical
    `repro.core.carbon.CarbonEstimate`, which this type replaces.
    """

    extension_factor: float
    extended_life_years: float
    yearly_kgco2eq: float            # embodied, amortized per year
    baseline_yearly_kgco2eq: float
    reduction_frac: float            # 1 - yearly'/yearly
    model: str = "linear-extension"
    baseline_life_years: float = BASELINE_LIFESPAN_YEARS

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "LifetimeEstimate":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CarbonFootprint:
    """Yearly kgCO2eq of one inference server, split into operational
    (grid-intensity-dependent energy) and embodied (CPU / accelerator
    die amortization) components — the decomposition behind the paper's
    Fig. 1 and EcoServe's serving decisions."""

    operational_kg: float
    cpu_embodied_kg: float
    gpu_embodied_kg: float
    total_kg: float
    carbon_intensity_g_per_kwh: float   # mean intensity priced in
    model: str = "operational-embodied"

    @property
    def embodied_kg(self) -> float:
        return self.cpu_embodied_kg + self.gpu_embodied_kg

    @property
    def embodied_frac(self) -> float:
        return self.embodied_kg / self.total_kg if self.total_kg else 0.0

    @property
    def cpu_embodied_frac(self) -> float:
        return self.cpu_embodied_kg / self.total_kg if self.total_kg else 0.0

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "CarbonFootprint":
        return cls(**d)


class CarbonModel:
    """Base class for pluggable carbon-accounting models.

    Subclasses register under a string key with
    `@register_carbon_model(name)` and are instantiated per experiment
    via `get_carbon_model(name, **opts)`. Both hooks take the same pair
    of observations: `deg_ref`, the reference (worst-case / `linux`)
    mean frequency degradation over the horizon, and `deg_technique`,
    the technique's degradation over the *same* horizon.
    """

    #: canonical registry key, set by @register_carbon_model
    name: ClassVar[str] = "?"

    def lifetime(self, deg_ref: float,
                 deg_technique: float) -> LifetimeEstimate:
        """Project CPU lifetime + amortized embodied carbon."""
        raise NotImplementedError

    def footprint(self, deg_ref: float, deg_technique: float,
                  utilization: float = 0.6) -> CarbonFootprint:
        """Yearly total footprint. The base implementation prices the
        embodied component only (zero-carbon grid); the
        `operational-embodied` model overrides this with an intensity
        signal."""
        life = self.lifetime(deg_ref, deg_technique)
        return CarbonFootprint(
            operational_kg=0.0,
            cpu_embodied_kg=life.yearly_kgco2eq,
            gpu_embodied_kg=0.0,
            total_kg=life.yearly_kgco2eq,
            carbon_intensity_g_per_kwh=0.0,
            model=self.name,
        )

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"
