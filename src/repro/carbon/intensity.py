"""Grid carbon-intensity signals — the *when is the grid dirty* layer.

Mirrors `repro.workloads.arrivals` on the carbon axis: small composable
dataclasses, each answering `g_per_kwh(t_s)` (instantaneous gCO2eq per
kWh at time `t_s`) and `mean_g_per_kwh()` (the time-weighted mean an
amortized yearly estimate should price in). The
`operational-embodied` carbon model consumes one of these to turn
served energy into operational carbon; EcoLogits-style range reporting
falls out of evaluating the same experiment under several signals.

Built-in shapes:

  constant — one fixed intensity (world-average grid by default)
  diurnal  — sinusoidal day/night swing around a mean (solar-heavy
             grids dip mid-day; mirrors `DiurnalPoissonArrivals`)
  trace    — step-held samples from a CSV (`time_s,g_per_kwh`), looped
             cyclically so a one-day trace can price a full year
"""
from __future__ import annotations

import csv
import dataclasses
import io
import math

#: world-average grid intensity, gCO2eq/kWh (Ember 2023, the value the
#: paper's Fig. 1 uses for the "grid" column)
WORLD_AVG_G_PER_KWH = 436.0


class CarbonIntensity:
    """Base class: an intensity signal over simulation/wall time."""

    def g_per_kwh(self, t_s: float) -> float:
        raise NotImplementedError

    def mean_g_per_kwh(self) -> float:
        """Time-weighted mean intensity over one full cycle of the
        signal (== the yearly mean for periodic signals)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantIntensity(CarbonIntensity):
    """Fixed grid intensity (the classic single-number assumption)."""

    value_g_per_kwh: float = WORLD_AVG_G_PER_KWH

    def __post_init__(self):
        if self.value_g_per_kwh < 0.0:
            raise ValueError(f"intensity must be >= 0, got "
                             f"{self.value_g_per_kwh}")

    def g_per_kwh(self, t_s: float) -> float:
        return self.value_g_per_kwh

    def mean_g_per_kwh(self) -> float:
        return self.value_g_per_kwh


@dataclasses.dataclass(frozen=True)
class ShiftedIntensity(CarbonIntensity):
    """`base` evaluated at `t + t0_s` — a timezone/phase offset.

    Fleets spanning regions see the same diurnal shape at different
    local phases; `FleetInventory` rows carry a per-machine `t0_s` and
    pricing wraps the configured signal per machine. The time-weighted
    mean is shift-invariant, so amortized yearly estimates are
    unchanged — only *when* the operational carbon lands moves.
    """

    base: CarbonIntensity = dataclasses.field(
        default_factory=lambda: ConstantIntensity())
    t0_s: float = 0.0

    def g_per_kwh(self, t_s: float) -> float:
        return self.base.g_per_kwh(t_s + self.t0_s)

    def mean_g_per_kwh(self) -> float:
        return self.base.mean_g_per_kwh()


@dataclasses.dataclass(frozen=True)
class DiurnalIntensity(CarbonIntensity):
    """Sinusoidal day/night swing around a mean intensity.

    intensity(t) = mean * (1 + amplitude * sin(2*pi*t/period + phase)).
    The analytic mean over any whole number of periods is exactly
    `mean_g_per_kwh` — matching the mean-rate-preserving contract of the
    arrival processes, so footprints stay comparable across shapes.
    """

    mean: float = WORLD_AVG_G_PER_KWH
    amplitude: float = 0.4
    period_s: float = 86_400.0
    phase: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(f"amplitude must be in [0, 1), got "
                             f"{self.amplitude}")
        if self.mean < 0.0:
            raise ValueError(f"mean intensity must be >= 0, got {self.mean}")
        if self.period_s <= 0.0:
            raise ValueError(f"period_s must be > 0, got {self.period_s}")

    def g_per_kwh(self, t_s: float) -> float:
        return self.mean * (1.0 + self.amplitude * math.sin(
            2.0 * math.pi * t_s / self.period_s + self.phase))

    def mean_g_per_kwh(self) -> float:
        return self.mean


@dataclasses.dataclass(frozen=True)
class TraceIntensity(CarbonIntensity):
    """Step-held intensity samples, extended cyclically.

    `times_s` must be strictly increasing and start at 0; each value
    holds until the next sample time, and the signal wraps modulo the
    trace span (last sample time + its holding interval, taken as the
    mean gap). A 24-hour grid trace therefore prices a whole year.
    """

    times_s: tuple[float, ...]
    values_g_per_kwh: tuple[float, ...]

    def __post_init__(self):
        times = tuple(float(t) for t in self.times_s)
        values = tuple(float(v) for v in self.values_g_per_kwh)
        if len(times) != len(values) or not times:
            raise ValueError("need equally many sample times and values, "
                             f"got {len(times)}/{len(values)}")
        # Power x intensity integration multiplies these values straight
        # into headline results, so reject bad ingest loudly and point
        # at the offending sample (NaN fails every comparison below).
        for i, t in enumerate(times):
            if not math.isfinite(t):
                raise ValueError("sample times must be finite, got "
                                 f"times_s[{i}]={t}")
        if times[0] != 0.0:
            raise ValueError("sample times must be strictly increasing "
                             f"and start at 0; got times_s[0]={times[0]}")
        for i, (a, b) in enumerate(zip(times, times[1:])):
            if not b > a:
                raise ValueError(
                    "sample times must be strictly increasing and start "
                    f"at 0; got times_s[{i + 1}]={b} after times_s[{i}]="
                    f"{a}")
        for i, v in enumerate(values):
            if not (math.isfinite(v) and v >= 0.0):
                raise ValueError("intensities must be finite and >= 0, "
                                 f"got g_per_kwh[{i}]={v}")
        object.__setattr__(self, "times_s", times)
        object.__setattr__(self, "values_g_per_kwh", values)
        # The last sample holds for the mean inter-sample gap, closing
        # the cycle (a single-sample trace degenerates to constant).
        tail = times[-1] / (len(times) - 1) if len(times) > 1 else 1.0
        object.__setattr__(self, "_span_s", times[-1] + tail)

    @classmethod
    def from_csv(cls, path_or_text: str) -> "TraceIntensity":
        """Load a `time_s,g_per_kwh` CSV: a path, or the CSV text
        itself. Dispatch is on newline presence — CSV text always spans
        header + data lines, while a path never contains one (a comma
        in a path is fine). Extra columns ignored."""
        if "\n" in path_or_text:
            fh = io.StringIO(path_or_text)
        else:
            fh = open(path_or_text, newline="")
        with fh:
            rows = list(csv.DictReader(fh))
        if not rows:
            raise ValueError("empty carbon-intensity CSV")
        try:
            times = tuple(float(r["time_s"]) for r in rows)
            values = tuple(float(r["g_per_kwh"]) for r in rows)
        except KeyError as e:
            raise ValueError(f"carbon-intensity CSV needs a {e.args[0]!r} "
                             "column (schema: time_s,g_per_kwh)") from None
        return cls(times_s=times, values_g_per_kwh=values)

    def g_per_kwh(self, t_s: float) -> float:
        t = t_s % self._span_s
        # Step-hold: last sample at or before t. Linear scan is fine —
        # signals have a handful of samples and footprint() integrates
        # analytically via mean_g_per_kwh, not by sampling.
        i = 0
        for j, tj in enumerate(self.times_s):
            if tj <= t:
                i = j
            else:
                break
        return self.values_g_per_kwh[i]

    def mean_g_per_kwh(self) -> float:
        times = self.times_s + (self._span_s,)
        total = sum(v * (times[i + 1] - times[i])
                    for i, v in enumerate(self.values_g_per_kwh))
        return total / self._span_s


#: spec-name → signal factory, mirroring how scenarios name arrival
#: shapes. Kept a plain dict (not a Registry): signals are constructor
#: details of the `operational-embodied` model, not an experiment axis.
_INTENSITIES = {
    "constant": ConstantIntensity,
    "diurnal": DiurnalIntensity,
    "trace": TraceIntensity,
    "trace-csv": TraceIntensity.from_csv,
}


def get_intensity(spec, **opts) -> CarbonIntensity:
    """Resolve an intensity spec: a `CarbonIntensity` passes through,
    a name in {constant, diurnal, trace, trace-csv} builds one."""
    if isinstance(spec, CarbonIntensity):
        if opts:
            raise TypeError("intensity opts only apply to named specs, "
                            f"got instance {spec!r} with opts {opts}")
        return spec
    try:
        factory = _INTENSITIES[str(spec)]
    except KeyError:
        raise KeyError(
            f"unknown carbon-intensity signal {spec!r}; available: "
            f"{', '.join(sorted(_INTENSITIES))}") from None
    return factory(**opts)
