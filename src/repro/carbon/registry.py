"""String-keyed registry of carbon-accounting models.

    @register_carbon_model("linear-extension")
    class LinearExtensionModel(CarbonModel): ...

    model = get_carbon_model("linear-extension")
    model = get_carbon_model("reliability-threshold", max_extension=20.0)

Names are case-insensitive and underscore/hyphen-insensitive, matching
the policy / scenario / router axes. Every `get_carbon_model` call
returns a NEW instance. The mechanics live in the shared
`repro.registry.Registry` (one implementation for all five axes).
"""
from __future__ import annotations

from repro.carbon.base import CarbonModel
from repro.registry import Registry, canonical_name

_MODELS = Registry(
    noun="carbon model", kind="carbon model",
    decorator="register_carbon_model", expects="CarbonModel subclass",
    check=lambda cls: isinstance(cls, type) and issubclass(cls,
                                                           CarbonModel),
)
#: module-level alias matching the other axes (tests clean up through it)
_REGISTRY = _MODELS.store


def canonical_carbon_model_name(name: str) -> str:
    """Normalize a user-supplied model key ("Linear_Extension" style)."""
    return canonical_name(name)


def register_carbon_model(name: str):
    """Class decorator: register a `CarbonModel` subclass under `name`."""
    return _MODELS.register(name)


def get_carbon_model(name: str, **opts) -> CarbonModel:
    """Instantiate the carbon model registered under `name` with `opts`."""
    return _MODELS.get(name, **opts)


def available_carbon_models() -> tuple[str, ...]:
    """Sorted canonical names of every registered carbon model."""
    return _MODELS.available()
