"""Hardware SKU protocol + Boavizta-style per-CPU embodied-impact table.

A `HardwareSKU` describes one CPU model an operator can rack: core
count, TDP, base/max frequency, the f0/Vth process-distribution
parameters feeding `repro.core.variation` / `repro.core.aging`, the
hardware generation and launch year (for generation-aware routing), and
the embodied-carbon figure used to price replace-vs-extend decisions.

Embodied figures come from a per-CPU-model impact table in the style of
Boavizta / ichnos `EmbodiedCarbon.py`: `get_cpu_impact(cpu_model)`
returns the full-lifecycle manufacturing footprint in kgCO2eq, and
`embodied_carbon(...)` amortizes it over a usage window.

The default (reference) SKU reproduces today's fleet-wide constants
exactly — 40 cores, `CPU_EMBODIED_KGCO2EQ`, `BASELINE_LIFESPAN_YEARS`,
the `tdp-per-core` 13.75 W/core TDP, and `aging.DEFAULT_PARAMS` — so a
`uniform` fleet of reference machines is bit-identical to the
pre-heterogeneity simulator.
"""
from __future__ import annotations

import dataclasses

from repro.carbon.base import BASELINE_LIFESPAN_YEARS, CPU_EMBODIED_KGCO2EQ
from repro.core import aging
from repro.core.variation import VariationParams

#: TDP of the reference SKU (tdp-per-core default: 13.75 W x 40 cores).
#: Per-SKU power scaling is the ratio `cpu_tdp_w / REFERENCE_CPU_TDP_W`.
REFERENCE_CPU_TDP_W = 550.0

#: Hours in the amortization year (matches `repro.carbon`).
_HOURS_PER_YEAR = 24.0 * 365.0

#: Per-CPU-model manufacturing footprint, kgCO2eq over the full
#: lifecycle (Boavizta-style LCA figures a la ichnos EmbodiedCarbon.py).
#: The reference entry equals `CPU_EMBODIED_KGCO2EQ` so default pricing
#: is unchanged; other entries scale roughly with die area / core count.
CPU_IMPACT_KGCO2EQ: dict[str, float] = {
    "reference-xeon-40c": CPU_EMBODIED_KGCO2EQ,   # 278.3
    "xeon-e5-2695v4-18c": 127.9,
    "xeon-platinum-8280-28c": 191.4,
    "epyc-9354-32c": 224.6,
    "epyc-9554-64c": 347.8,
    "epyc-9754-128c": 512.5,
}


def get_cpu_impact(cpu_model: str) -> float:
    """Full-lifecycle embodied footprint of `cpu_model` in kgCO2eq."""
    try:
        return CPU_IMPACT_KGCO2EQ[cpu_model]
    except KeyError:
        raise KeyError(
            f"unknown cpu_model {cpu_model!r} in the embodied-impact "
            f"table; known: {', '.join(sorted(CPU_IMPACT_KGCO2EQ))}"
        ) from None


def embodied_carbon(cpu_model: str, duration_used_h: float,
                    lifetime_years: float = BASELINE_LIFESPAN_YEARS,
                    cpu_usage: float = 1.0) -> float:
    """Embodied kgCO2eq attributable to `duration_used_h` hours of use,
    amortizing the LCA figure over `lifetime_years` (ichnos-style)."""
    if duration_used_h < 0.0:
        raise ValueError("duration_used_h must be >= 0")
    if lifetime_years <= 0.0:
        raise ValueError("lifetime_years must be > 0")
    total = get_cpu_impact(cpu_model)
    return total * (duration_used_h / (lifetime_years * _HOURS_PER_YEAR)) \
        * cpu_usage


@dataclasses.dataclass(frozen=True)
class HardwareSKU:
    """One CPU model: silicon, power, and embodied-carbon description.

    Subclass and redeclare field defaults to add catalog entries (see
    `repro.hardware.skus`); `register_sku` makes them selectable by
    name. `embodied_kgco2eq == 0.0` means "look `cpu_model` up in
    `CPU_IMPACT_KGCO2EQ`".
    """

    num_cores: int = 40
    cpu_model: str = "reference-xeon-40c"
    generation: int = 3
    launch_year: int = 2021
    cpu_tdp_w: float = REFERENCE_CPU_TDP_W
    base_freq_ghz: float = 2.3
    max_freq_ghz: float = 3.4
    #: process-distribution parameters: fresh-core frequencies are drawn
    #: around `f_nominal` with spread `sigma_frac` (repro.core.variation)
    f_nominal: float = 1.0
    sigma_frac: float = 0.05
    #: NBTI operating point; headroom = vdd - vth (repro.core.aging)
    vdd: float = 1.0
    vth: float = 0.45
    embodied_kgco2eq: float = 0.0
    base_life_years: float = BASELINE_LIFESPAN_YEARS
    #: carbon-intensity phase offset (timezone) for machines of this row
    t0_s: float = 0.0

    def __post_init__(self):
        if self.num_cores < 1:
            raise ValueError("num_cores must be >= 1")
        if self.cpu_tdp_w <= 0.0:
            raise ValueError("cpu_tdp_w must be > 0")
        if self.sigma_frac < 0.0:
            raise ValueError("sigma_frac must be >= 0")
        if not self.vdd > self.vth:
            raise ValueError("vdd must exceed vth (aging headroom)")
        if self.base_life_years <= 0.0:
            raise ValueError("base_life_years must be > 0")

    # ------------------------------------------------------------------ #
    @property
    def embodied_kg(self) -> float:
        """Embodied footprint: explicit override or impact-table entry."""
        if self.embodied_kgco2eq > 0.0:
            return self.embodied_kgco2eq
        return get_cpu_impact(self.cpu_model)

    @property
    def power_scale(self) -> float:
        """TDP relative to the reference SKU; multiplies every power
        figure the configured power model reports for this machine."""
        return self.cpu_tdp_w / REFERENCE_CPU_TDP_W

    def aging_params(self, base: aging.AgingParams | None = None
                     ) -> aging.AgingParams:
        """NBTI parameters for this silicon. Returns `base` *unchanged*
        (same object) when the SKU matches its operating point — the
        identity keeps reference-SKU fleets bit-exact and lets the
        fleet settler group machines sharing parameters."""
        base = aging.DEFAULT_PARAMS if base is None else base
        if (self.vdd, self.vth, self.f_nominal) == \
                (base.vdd, base.vth, base.f_nominal):
            return base
        return aging.solve_k(dataclasses.replace(
            base, vdd=self.vdd, vth=self.vth, f_nominal=self.f_nominal,
            K=0.0))

    def variation_params(self) -> VariationParams:
        """Process-variation distribution for fresh-core f0 draws."""
        return VariationParams(sigma_frac=self.sigma_frac,
                               f_nominal=self.f_nominal)
