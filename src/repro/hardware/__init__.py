"""Heterogeneous hardware — the seventh registry axis.

Per-SKU fleet inventory: `HardwareSKU` describes one CPU model (cores,
TDP, process distribution, generation, Boavizta-style embodied impact;
see `repro.hardware.base`), the shared-`Registry` catalog makes SKUs
selectable by name, and `FleetInventory` expands
`ExperimentConfig.fleet` / `fleet_opts` into per-machine hardware. The
default `"uniform"` fleet resolves to None and builds no heterogeneity
machinery at all — bit-exact and fingerprint-invisible vs the
pre-hardware simulator.
"""
from repro.hardware.base import (CPU_IMPACT_KGCO2EQ, HardwareSKU,
                                 REFERENCE_CPU_TDP_W, embodied_carbon,
                                 get_cpu_impact)
from repro.hardware.inventory import (FleetInventory, canonical_fleet_name,
                                      resolve_fleet, sku_carbon_model)
from repro.hardware.registry import (
    available_skus,
    canonical_sku_name,
    get_sku,
    register_sku,
)

# importing the package registers the built-in catalog
from repro.hardware import skus as _skus  # noqa: E402,F401

__all__ = [
    "CPU_IMPACT_KGCO2EQ",
    "FleetInventory",
    "HardwareSKU",
    "REFERENCE_CPU_TDP_W",
    "available_skus",
    "canonical_fleet_name",
    "canonical_sku_name",
    "embodied_carbon",
    "get_cpu_impact",
    "get_sku",
    "register_sku",
    "resolve_fleet",
    "sku_carbon_model",
]
