"""Fleet inventory: which SKU each machine is, and what that costs.

A `FleetInventory` is the resolved per-machine hardware description of
one experiment: `(sku_key, count, opts)` rows expanded in machine order
(prompt machines first, token machines after, matching
`repro.sim.cluster`). It answers every per-machine question the stack
asks — core count, aging/variation parameters, per-SKU carbon model,
TDP power scale, and the carbon-intensity phase offset `t0_s`.

Fleet specs (`ExperimentConfig.fleet` / `fleet_opts`):

  fleet="uniform"                       the default clone army; resolves
                                        to None so every legacy code
                                        path runs bit-identically
  fleet="epyc-64c"                      whole fleet on one catalog SKU
                                        (opts override SKU fields)
  fleet="mixed",
  fleet_opts={"rows": (("xeon-40c", 1),
                       ("epyc-64c", 2, {"t0_s": 3600.0}))}
                                        explicit rows; counts must sum
                                        to n_machines ("rest" fills)
  fleet="xeon-40c:1+epyc-64c:2"         the same rows as a CLI-friendly
                                        spec string (--fleet flag)
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.carbon import get_carbon_model
from repro.carbon.base import CarbonModel
from repro.carbon.intensity import CarbonIntensity, ShiftedIntensity
from repro.core import aging
from repro.hardware.base import HardwareSKU
from repro.hardware.registry import canonical_sku_name, get_sku
from repro.registry import canonical_name


def canonical_fleet_name(name: str) -> str:
    """Normalize a fleet spec key; spec strings ("a:1+b:2") pass
    through with their SKU parts canonicalized."""
    name = canonical_name(name)
    if ":" in name or "+" in name:
        return "+".join(
            ":".join([canonical_sku_name(part.split(":", 1)[0])]
                     + part.split(":", 1)[1:])
            for part in name.split("+"))
    return name


def _freeze_opts(opts) -> dict:
    if opts is None:
        return {}
    if isinstance(opts, Mapping):
        return dict(opts)
    return dict(opts)  # tuple of (key, value) pairs


def _parse_spec_string(spec: str) -> tuple:
    """"xeon-40c:1+epyc-64c:rest" -> (("xeon-40c", 1), ("epyc-64c", "rest"))."""
    rows = []
    for part in spec.split("+"):
        sku, _, count = part.partition(":")
        if not sku or not count:
            raise ValueError(
                f"bad fleet spec segment {part!r}; expected 'sku:count' "
                f"(counts: positive int or 'rest')")
        rows.append((sku, count if count == "rest" else int(count)))
    return tuple(rows)


class FleetInventory:
    """Per-machine hardware description, expanded from inventory rows.

    Machine `i`'s SKU is `skus[i]`; all per-machine accessors are
    precomputed tuples so the hot paths never re-instantiate SKUs.
    """

    def __init__(self, skus: tuple[HardwareSKU, ...],
                 sku_names: tuple[str, ...],
                 rows: tuple = ()):
        if not skus:
            raise ValueError("FleetInventory needs at least one machine")
        self.skus = tuple(skus)
        self.sku_names = tuple(sku_names)
        self.rows = tuple(rows)
        self.num_cores = tuple(s.num_cores for s in self.skus)
        self.generations = tuple(s.generation for s in self.skus)
        self.launch_years = tuple(s.launch_year for s in self.skus)
        self.t0_s = tuple(s.t0_s for s in self.skus)
        self.power_scales = tuple(s.power_scale for s in self.skus)
        self.aging_params = tuple(s.aging_params() for s in self.skus)
        self.variation_params = tuple(s.variation_params()
                                      for s in self.skus)

    # ------------------------------------------------------------------ #
    @property
    def n_machines(self) -> int:
        return len(self.skus)

    @property
    def max_cores(self) -> int:
        return max(self.num_cores)

    @property
    def total_cores(self) -> int:
        return sum(self.num_cores)

    @property
    def ragged(self) -> bool:
        """True when machines disagree on core count (the fleet engine
        then pads state to `(n_machines, max_cores)` under a mask)."""
        return len(set(self.num_cores)) > 1

    def shared_dynamics_params(self) -> aging.AgingParams:
        """The one `AgingParams` the vectorized fleet engine advances.

        `f_nominal` only enters through per-machine f0 draws and
        pricing, so SKUs may differ there; any Vdd/Vth (physics) spread
        needs the per-machine event engine."""
        first = self.aging_params[0]
        norm = dataclasses.replace(first, f_nominal=1.0)
        for p in self.aging_params[1:]:
            if dataclasses.replace(p, f_nominal=1.0) != norm:
                raise ValueError(
                    "fleet engine cannot vectorize fleets mixing NBTI "
                    "operating points (Vdd/Vth); run it under "
                    "engine='event'")
        return first

    def carbon_models(self, model_name: str,
                      model_opts: Mapping | None) -> tuple[CarbonModel, ...]:
        """One carbon-model instance per machine, each pricing against
        its own SKU's embodied figure and baseline lifespan."""
        opts = dict(model_opts or {})
        cache: dict[str, CarbonModel] = {}
        out = []
        for name, sku in zip(self.sku_names, self.skus):
            if name not in cache:
                cache[name] = sku_carbon_model(sku, model_name, opts)
            out.append(cache[name])
        return tuple(out)

    def intensity_for(self, i: int,
                      base: CarbonIntensity) -> CarbonIntensity:
        """Machine `i`'s intensity signal: `base` phase-shifted by the
        row's `t0_s` (the base object itself when the offset is 0)."""
        t0 = self.t0_s[i]
        return base if t0 == 0.0 else ShiftedIntensity(base, t0)


def sku_carbon_model(sku: HardwareSKU, model_name: str,
                     model_opts: Mapping | None) -> CarbonModel:
    """Instantiate carbon model `model_name` priced against `sku`.

    Explicit user opts win; the SKU supplies `embodied_kg` /
    `base_life_years` defaults (routed through `lifetime_opts` for
    `operational-embodied`, whose embodied figure lives on its wrapped
    lifetime model). Custom registered models that don't accept the
    embodied kwargs fall back to their plain opts.
    """
    opts = dict(model_opts or {})
    name = canonical_name(model_name)
    if name == "operational-embodied":
        lo = dict(opts.get("lifetime_opts") or {})
        lo.setdefault("embodied_kg", sku.embodied_kg)
        lo.setdefault("base_life_years", sku.base_life_years)
        opts["lifetime_opts"] = lo
        return get_carbon_model(name, **opts)
    skud = dict(opts)
    skud.setdefault("embodied_kg", sku.embodied_kg)
    skud.setdefault("base_life_years", sku.base_life_years)
    try:
        return get_carbon_model(name, **skud)
    except TypeError:
        return get_carbon_model(name, **opts)


def resolve_fleet(fleet: str, fleet_opts: Mapping | None,
                  n_machines: int) -> FleetInventory | None:
    """Resolve a fleet spec to a `FleetInventory`, or None for the
    bit-exact `uniform` default (no opts) — callers treat None as
    "run the legacy homogeneous path unchanged"."""
    name = canonical_fleet_name(fleet)
    opts = _freeze_opts(fleet_opts)
    if name == "uniform" and not opts:
        return None

    if name == "uniform":
        sku_name = canonical_sku_name(opts.pop("sku", "xeon-40c"))
        rows = ((sku_name, "rest", opts),)
    elif name == "mixed":
        raw = opts.pop("rows", None)
        if raw is None:
            raise ValueError(
                "fleet='mixed' needs fleet_opts={'rows': ((sku, count, "
                "opts?), ...)}")
        if opts:
            raise ValueError(f"unknown fleet_opts for 'mixed': "
                             f"{', '.join(sorted(opts))}")
        rows = tuple((r[0], r[1], _freeze_opts(r[2]) if len(r) > 2 else {})
                     for r in raw)
    elif ":" in name or "+" in name:
        rows = tuple((sku, count, dict(opts))
                     for sku, count in _parse_spec_string(name))
    else:
        # bare SKU name: the whole fleet on that part
        rows = ((name, "rest", opts),)

    return _expand_rows(rows, n_machines)


def _expand_rows(rows, n_machines: int) -> FleetInventory:
    skus: list[HardwareSKU] = []
    names: list[str] = []
    rest: tuple[int, HardwareSKU, str] | None = None
    for sku_name, count, row_opts in rows:
        key = canonical_sku_name(sku_name)
        sku = get_sku(key, **_freeze_opts(row_opts))
        if count == "rest" or count is None:
            if rest is not None:
                raise ValueError("only one fleet row may take count='rest'")
            rest = (len(skus), sku, key)
            continue
        if int(count) < 1:
            raise ValueError(f"fleet row count must be >= 1 or 'rest', "
                             f"got {count!r}")
        skus.extend([sku] * int(count))
        names.extend([key] * int(count))
    if rest is not None:
        at, sku, key = rest
        missing = n_machines - len(skus)
        if missing < 0:
            raise ValueError(
                f"fleet rows place {len(skus)} machines but the "
                f"experiment has n_machines={n_machines}")
        skus[at:at] = [sku] * missing
        names[at:at] = [key] * missing
    if len(skus) != n_machines:
        raise ValueError(
            f"fleet rows place {len(skus)} machines but the experiment "
            f"has n_machines={n_machines} (use count='rest' to fill)")
    return FleetInventory(tuple(skus), tuple(names), tuple(
        (n, c, tuple(sorted(o.items()))) for n, c, o in rows))
