"""String-keyed registry of hardware SKUs — the seventh axis.

    @register_sku("epyc-9554-64c")
    class Epyc9554(HardwareSKU): ...

    sku = get_sku("epyc-9554-64c")
    sku = get_sku("epyc-9554-64c", num_cores=32)

Names are case-insensitive and underscore/hyphen-insensitive, matching
the policy / scenario / router / carbon / power / fault axes. Every
`get_sku` call returns a NEW instance (row opts may override any SKU
field). The mechanics live in the shared `repro.registry.Registry` (one
implementation for all seven axes).
"""
from __future__ import annotations

from repro.hardware.base import HardwareSKU
from repro.registry import Registry, canonical_name

_SKUS = Registry(
    noun="hardware SKU", kind="hardware SKU",
    decorator="register_sku", expects="HardwareSKU subclass",
    check=lambda cls: isinstance(cls, type) and issubclass(cls,
                                                           HardwareSKU),
)
#: module-level alias matching the other axes (tests clean up through it)
_REGISTRY = _SKUS.store


def canonical_sku_name(name: str) -> str:
    """Normalize a user-supplied SKU key ("Epyc_9554_64c" style)."""
    return canonical_name(name)


def register_sku(name: str):
    """Class decorator: register a `HardwareSKU` subclass under `name`."""
    return _SKUS.register(name)


def get_sku(name: str, **opts) -> HardwareSKU:
    """Instantiate the SKU registered under `name` with field overrides."""
    return _SKUS.get(name, **opts)


def available_skus() -> tuple[str, ...]:
    """Sorted canonical names of every registered hardware SKU."""
    return _SKUS.available()
