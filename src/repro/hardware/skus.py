"""Built-in hardware SKU catalog.

Five generations of server CPUs spanning the replace-vs-extend design
space: the reference `xeon-40c` (bit-exact with the pre-heterogeneity
fleet-wide constants), an old low-core part, a mid-life Xeon, and two
modern high-core EPYCs whose larger dies carry larger embodied
footprints. Names align with the `fitted-linear` power model's
`NODE_COEFFS` presets where both exist (`xeon-40c`, `epyc-64c`).

`legacy-18c` deliberately runs at a different NBTI operating point
(higher Vth, lower headroom): mixing it into a fleet exercises the
grouped per-parameter aging settlers under the event engine. The fleet
engine vectorizes one shared `AgingParams` per run, so fleets mixing
Vdd/Vth corners must use `engine="event"`.
"""
from __future__ import annotations

import dataclasses

from repro.hardware.base import HardwareSKU
from repro.hardware.registry import register_sku


@register_sku("xeon-40c")
@dataclasses.dataclass(frozen=True)
class Xeon40c(HardwareSKU):
    """Reference SKU — today's implicit fleet-wide machine."""


@register_sku("legacy-18c")
@dataclasses.dataclass(frozen=True)
class Legacy18c(HardwareSKU):
    num_cores: int = 18
    cpu_model: str = "xeon-e5-2695v4-18c"
    generation: int = 1
    launch_year: int = 2016
    cpu_tdp_w: float = 270.0
    base_freq_ghz: float = 2.1
    max_freq_ghz: float = 3.3
    f_nominal: float = 0.82
    sigma_frac: float = 0.08
    vth: float = 0.48  # tighter headroom: ages faster per stress-second


@register_sku("xeon-28c")
@dataclasses.dataclass(frozen=True)
class Xeon28c(HardwareSKU):
    num_cores: int = 28
    cpu_model: str = "xeon-platinum-8280-28c"
    generation: int = 2
    launch_year: int = 2019
    cpu_tdp_w: float = 405.0
    base_freq_ghz: float = 2.7
    max_freq_ghz: float = 4.0
    f_nominal: float = 0.93
    sigma_frac: float = 0.06


@register_sku("epyc-64c")
@dataclasses.dataclass(frozen=True)
class Epyc64c(HardwareSKU):
    num_cores: int = 64
    cpu_model: str = "epyc-9554-64c"
    generation: int = 4
    launch_year: int = 2023
    cpu_tdp_w: float = 720.0
    base_freq_ghz: float = 3.1
    max_freq_ghz: float = 3.75
    f_nominal: float = 1.06
    sigma_frac: float = 0.045


@register_sku("epyc-128c")
@dataclasses.dataclass(frozen=True)
class Epyc128c(HardwareSKU):
    num_cores: int = 128
    cpu_model: str = "epyc-9754-128c"
    generation: int = 5
    launch_year: int = 2025
    cpu_tdp_w: float = 1120.0
    base_freq_ghz: float = 2.25
    max_freq_ghz: float = 3.1
    f_nominal: float = 1.1
    sigma_frac: float = 0.04
