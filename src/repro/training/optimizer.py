"""AdamW optimizer + LR schedules, implemented from scratch (no optax
offline). State is a pytree mirroring params, so it inherits the same
sharding (and can be ZeRO-sharded over the data axis — see launch/train).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    mu: dict             # first moment (fp32)
    nu: dict             # second moment (fp32)


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig
                  ) -> tuple[dict, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "lr": lr, "grad_norm": gnorm}
