"""Training substrate: AdamW + schedules."""
