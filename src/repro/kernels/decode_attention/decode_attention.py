"""Pallas TPU kernel: single-token decode attention against a KV cache
(flash-decoding style).

One query token per sequence attends to a long cache: grid (B, nK)
streams KV blocks HBM->VMEM while (m, l, acc) scratch carries the online
softmax; the (H, S) score matrix never exists. This is the decode-side
memory-bound hot spot — the kernel's roofline is HBM bandwidth on the
cache stream, so block_k is sized to keep the DMA pipeline busy
(block_k x Hkv x D tiles, 128-aligned). Supports GQA (grouped query
heads share cache heads) and sliding windows, with per-sequence `pos`
masking for continuous batching.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, block_k, n_k, window, groups):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]                                  # () int32, valid len
    q = q_ref[0].astype(jnp.float32)                  # (H, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    hkv = k.shape[1]
    h, d = q.shape
    qg = q.reshape(hkv, groups, d)
    # scores (Hkv, G, bk) -> (H, bk)
    s = jnp.einsum("egd,ked->egk", qg, k).reshape(h, -1) * scale

    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (h, block_k), 1)
    mask = k_pos < pos
    if window:
        mask &= k_pos >= pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                   # (H, bk)
    l_new = l_scr[...] * alpha + p.sum(axis=-1)
    pv = jnp.einsum("egk,ked->egd", p.reshape(hkv, groups, -1),
                    v).reshape(h, d)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, window=0, block_k=512,
                     interpret=False):
    """q: (B, H, D); caches: (B, S, Hkv, D); pos: (B,) int32 valid lengths
    (current token already written at pos-1). Returns (B, H, D)."""
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    groups = h // hkv
    n_k = s // block_k
    grid = (b, n_k)
    kernel = functools.partial(_kernel, scale=d ** -0.5, block_k=block_k,
                               n_k=n_k, window=window, groups=groups)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, ki: (bb,)),
            pl.BlockSpec((1, h, d), lambda bb, ki: (bb, 0, 0)),
            pl.BlockSpec((1, block_k, hkv, d), lambda bb, ki: (bb, ki, 0, 0)),
            pl.BlockSpec((1, block_k, hkv, d), lambda bb, ki: (bb, ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda bb, ki: (bb, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q, k_cache, v_cache)
