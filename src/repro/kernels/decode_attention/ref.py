"""Pure-jnp oracle for decode_attention (delegates to the model's own
decode attention math, which tests also exercise independently)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import decode_attention as _model_decode


def decode_attention_ref(q, k_cache, v_cache, pos, *, window=0):
    """q: (B,H,D); caches (B,S,Hkv,D); pos (B,). Returns (B,H,D)."""
    out = _model_decode(q[:, None].swapaxes(1, 1), k_cache, v_cache,
                        pos, window=window)
    # _model_decode wants q (B,1,H,D)
    return out[:, 0]


def decode_attention_ref_explicit(q, k_cache, v_cache, pos, *, window=0):
    """Fully-explicit fp32 oracle."""
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    k = jnp.repeat(k_cache, rep, axis=2).astype(jnp.float32)  # (B,S,H,D)
    v = jnp.repeat(v_cache, rep, axis=2).astype(jnp.float32)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k) * d ** -0.5
    idx = jnp.arange(s)[None, None, :]
    p = pos[:, None, None]
    mask = idx < p
    if window:
        mask &= idx >= p - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", probs, v).astype(q.dtype)
