"""Public wrapper for decode_attention: padding + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref_explicit


def decode_bhd(q, k_cache, v_cache, pos, *, window=0, use_kernel=True,
               block_k=512, interpret=None):
    """q: (B,H,D); caches (B,S,Hkv,D); pos () or (B,)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (q.shape[0],))
    if not use_kernel:
        return decode_attention_ref_explicit(q, k_cache, v_cache, pos,
                                             window=window)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    s = k_cache.shape[1]
    block_k = min(block_k, s)
    pad = (-s) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded positions are masked by `idx < pos` automatically
    return decode_attention(q, k_cache, v_cache, pos, window=window,
                            block_k=block_k, interpret=interpret)
