"""Public wrapper for ssd_scan: padding + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan


def ssd(x, dt, a_log, b, c, *, chunk=256, use_kernel=True, interpret=None):
    """Chunked SSD. x (B,L,H,P), dt (B,L,H), a_log (H,), b/c (B,L,N)."""
    if not use_kernel:
        return ssd_scan_ref(x, dt, a_log, b, c)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    l = x.shape[1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        # zero-dt padding: exp(0)=1 decay, zero update => exact
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan(x, dt, a_log, b, c, chunk=chunk, interpret=interpret)
    return y[:, :l]
