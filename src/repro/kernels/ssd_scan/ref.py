"""Pure-jnp oracle for ssd_scan: the naive sequential recurrence."""
from __future__ import annotations

from repro.models.mamba2 import ssd_reference


def ssd_scan_ref(x, dt, a_log, b, c):
    """Same contract as ssd_scan; returns y only (state is internal)."""
    y, _ = ssd_reference(x, dt, a_log, b, c)
    return y
