"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid (B, H, nc) walks chunks left-to-right per (batch, head) with the
inter-chunk SSM state carried in a VMEM scratch (P x N fp32), so the
recurrence never round-trips HBM. Each chunk does the dense SSD algebra
on MXU-shaped tiles: the (Q x Q) decay-masked score matrix, the chunk
state contribution (P x N outer products), and the off-diagonal term
against the carried state — the TPU-native adaptation of Mamba2's
"state-space duality" (dense matmuls instead of a sequential scan).

Chunk length Q is a multiple of 128; P/N (64/128 for the assigned
configs) map to VPU lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_scr, *,
            chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    a_log = alog_ref[0].astype(jnp.float32)       # ()
    bg = b_ref[0].astype(jnp.float32)             # (Q, N)
    cg = c_ref[0].astype(jnp.float32)             # (Q, N)

    a = dt * (-jnp.exp(a_log))                    # (Q,) <= 0
    cum = jnp.cumsum(a)                           # s_t
    # intra-chunk decay matrix L[i, j] = exp(s_i - s_j) for j <= i
    diff = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    ldecay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)

    xdt = x * dt[:, None]                         # (Q, P)
    scores = jax.lax.dot_general(cg, bg, (((1,), (1,)), ((), ())))  # (Q,Q)
    y_diag = jax.lax.dot_general(scores * ldecay, xdt,
                                 (((1,), (0,)), ((), ())))          # (Q,P)
    # off-diagonal: contribution of the carried state
    state = state_scr[...]                        # (P, N)
    y_off = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cg, state, (((1,), (1,)), ((), ())))      # (Q, P)
    y_ref[0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # chunk state update: state' = e^{s_Q} state + sum_j e^{s_Q-s_j} dt_j x_j B_j^T
    t = jnp.exp(cum[-1] - cum)                    # (Q,)
    s_c = jax.lax.dot_general(xdt * t[:, None], bg,
                              (((0,), (0,)), ((), ())))             # (P, N)
    state_scr[...] = jnp.exp(cum[-1]) * state + s_c


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, b, c, *, chunk=256, interpret=False):
    """x: (B, L, H, P); dt: (B, L, H); a_log: (H,); b/c: (B, L, N).
    L must be a multiple of `chunk` (ops.py pads). Returns y (B,L,H,P)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    nc = l // chunk
    grid = (bsz, h, nc)
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, ci: (bb, ci, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, ci: (bb, ci, hh)),
            pl.BlockSpec((1,), lambda bb, hh, ci: (hh,)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ci: (bb, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ci: (bb, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda bb, hh, ci: (bb, ci, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b, c)
