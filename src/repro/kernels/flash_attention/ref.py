"""Pure-jnp oracle for flash_attention (materializes the score matrix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,S,D); k/v: (B,Hkv,S,D). fp32 softmax, like the kernel."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * d ** -0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
