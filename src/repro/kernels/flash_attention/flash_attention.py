"""Pallas TPU kernel: blocked causal GQA flash attention (prefill/train).

Online-softmax attention that never materializes the (S, S) score matrix:
grid (B, H, nQ, nK) revisits each output block across the KV axis with
running (m, l, acc) scratch in VMEM. Block shapes are MXU-aligned
(block_q x head_dim and block_k x head_dim tiles, multiples of 128 on the
contracting dims for the 128x128 systolic array). GQA is expressed in the
kernel's index_map: query head h reads KV head h * Hkv // H, so grouped
heads share the same KV block without a repeated-KV copy in HBM.

Supports causal masking and sliding-window (Mixtral SWA) masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, block_q, block_k, n_k, causal, window):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + p.sum(axis=-1)
    acc_new = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    """q: (B, H, S, D); k/v: (B, Hkv, S, D) with H % Hkv == 0.
    Returns (B, H, S, D). Sequence length must divide the block sizes
    (ops.py pads)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    scale = d ** -0.5
    n_q = s // block_q
    n_k = s // block_k
    grid = (b, h, n_q, n_k)

    kernel = functools.partial(_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, n_k=n_k, causal=causal,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki: (bb, hh * hkv // h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki: (bb, hh * hkv // h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
