"""Public wrapper: layout conversion, padding, kernel/ref dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def attention_bhsd(q, k, v, *, causal=True, window=0, use_kernel=True,
                   block_q=128, block_k=128, interpret=None):
    """Flash attention in (B, S, H, D) model layout. GQA-aware."""
    qt = jnp.swapaxes(q, 1, 2)   # (B,H,S,D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if not use_kernel:
        out = flash_attention_ref(qt, kt, vt, causal=causal, window=window)
        return jnp.swapaxes(out, 1, 2)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    s = qt.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    pad = (-s) % max(block_q, block_k)
    if pad:
        # pad queries AND keys; padded kv columns are masked by causality
        # for padded q rows only, so mask padded kv explicitly via window
        # -- simpler: pad then slice; padded rows produce garbage that we
        # drop, padded kv columns are masked because k_pos > s-1 >= q_pos
        # only for padded q rows. For causal attention this is exact.
        assert causal, "padding path requires causal masking"
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)
    if pad:
        out = out[:, :, :s]
    return jnp.swapaxes(out, 1, 2)
