"""jit'd public wrapper: pads to the kernel block size, dispatches to the
Pallas kernel (interpret=True on CPU) or the jnp oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.aging_update.aging_update import BLOCK, aging_update
from repro.kernels.aging_update.ref import aging_update_ref


def advance_fleet(dvth, temp_c, stress, tau, params, use_kernel=True,
                  interpret=None):
    """Advance a fleet of cores' dVth. Inputs (N,); returns (N,) f32."""
    dvth = jnp.asarray(dvth, jnp.float32)
    temp_c = jnp.asarray(temp_c, jnp.float32)
    stress = jnp.asarray(stress, jnp.float32)
    tau = jnp.asarray(tau, jnp.float32)
    if not use_kernel:
        return aging_update_ref(dvth, temp_c, stress, tau, params)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = dvth.shape[0]
    pad = (-n) % BLOCK
    if pad:
        dvth = jnp.pad(dvth, (0, pad))
        temp_c = jnp.pad(temp_c, (0, pad))
        stress = jnp.pad(stress, (0, pad))
        tau = jnp.pad(tau, (0, pad))
    out = aging_update(dvth, temp_c, stress, tau, params,
                       interpret=interpret)
    return out[:n]
