"""Pure-jnp oracle for the aging_update kernel."""
from __future__ import annotations

import jax.numpy as jnp


def aging_update_ref(dvth, temp_c, stress, tau, params):
    dvth = dvth.astype(jnp.float32)
    t_k = temp_c.astype(jnp.float32) + 273.15
    adf = (params.K * jnp.exp(-params.E0 / (params.kB * t_k))
           * jnp.exp(params.c_field * params.vdd / (params.kB * t_k))
           * jnp.where(stress > 0, stress, 1.0) ** params.n)
    live = (stress > 0) & (tau > 0)
    safe = jnp.where(live, adf, 1.0)
    eff_t = (dvth / safe) ** (1.0 / params.n)
    new = safe * (eff_t + tau) ** params.n
    return jnp.where(live, new, dvth)
