"""Pallas TPU kernel: fleet-scale batched NBTI dVth update.

The paper's hot loop — advancing every core's threshold-voltage shift by
an interval under its current (temperature, stress) regime — vectorized
over an entire fleet's cores (cluster analytics path / periodic
settlement). Elementwise math, so the kernel is a 1-D VMEM tiling with
128-lane-aligned blocks; on TPU this runs out of VMEM at vector-unit
throughput rather than bouncing per-core scalars through HBM.

    dvth' = ADF * ((dvth/ADF)^(1/n) + tau)^n,  ADF = 0 freezes (deep idle)
    ADF   = K * exp(-E0/kB*T) * exp(C*Vdd/(kB*T)) * Y^n
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024  # cores per block; multiple of the 128-lane VPU width


def _kernel(dvth_ref, temp_ref, stress_ref, tau_ref, out_ref, *,
            n, k_fit, e0, kb, c_field, vdd):
    dvth = dvth_ref[...].astype(jnp.float32)
    t_k = temp_ref[...].astype(jnp.float32) + 273.15
    stress = stress_ref[...].astype(jnp.float32)
    tau = tau_ref[...].astype(jnp.float32)
    adf = (k_fit * jnp.exp(-e0 / (kb * t_k))
           * jnp.exp(c_field * vdd / (kb * t_k))
           * jnp.where(stress > 0, stress, 1.0) ** n)
    live = (stress > 0) & (tau > 0)
    safe = jnp.where(live, adf, 1.0)
    eff_t = (dvth / safe) ** (1.0 / n)
    new = safe * (eff_t + tau) ** n
    out_ref[...] = jnp.where(live, new, dvth)


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def aging_update(dvth, temp_c, stress, tau, params, interpret=False):
    """Batched dVth advance. All inputs shape (N,) float32 (N padded to a
    BLOCK multiple by the wrapper in ops.py). `params` is AgingParams."""
    n_cores = dvth.shape[0]
    grid = (pl.cdiv(n_cores, BLOCK),)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    kernel = functools.partial(
        _kernel, n=params.n, k_fit=params.K, e0=params.E0, kb=params.kB,
        c_field=params.c_field, vdd=params.vdd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n_cores,), jnp.float32),
        interpret=interpret,
    )(dvth, temp_c, stress, tau)
