"""Paper Fig. 6: management of CPU aging effects — frequency-CV and mean
frequency-degradation performance vs baselines, for 40- and 80-core VMs
across throughput levels. Performance = value under `linux` divided by
value under the technique (higher = better), mirroring the paper's
normalized performance plots.

`--scenario` (repeatable) extends the sweep to a policy x scenario grid:
the robustness question the paper can't answer — does the proposed
policy's aging win survive bursty (conversation-mmpp) or diurnal load? —
falls out of the same rows, normalized against linux *per scenario*.
`--router` (repeatable) adds the cluster-routing axis the same way,
normalized against linux per (scenario, router).
"""
from __future__ import annotations

from repro.sim import DEFAULT_SWEEP, ExperimentConfig, run_policy_sweep

from benchmarks.common import (DEFAULT_ROUTERS, DEFAULT_SCENARIOS, emit,
                               parse_axes)


def run(duration_s: float = 120.0, rates=(40, 70, 100),
        core_counts=(40, 80), policies=DEFAULT_SWEEP,
        scenarios=DEFAULT_SCENARIOS, routers=DEFAULT_ROUTERS) -> list[dict]:
    rows = []
    for scenario in scenarios:
        for router in routers:
            for cores in core_counts:
                for rate in rates:
                    res = run_policy_sweep(
                        ExperimentConfig(num_cores=cores, rate_rps=rate,
                                         duration_s=duration_s, seed=1,
                                         scenario=scenario, router=router),
                        policies=policies)
                    linux = res["linux"]
                    for name, m in res.items():
                        rows.append({
                            "scenario": m.scenario,
                            "router": m.router,
                            "cores": cores,
                            "rate_rps": rate,
                            "policy": name,
                            "cv_p50": round(m.freq_cv_percentiles[50], 6),
                            "cv_p99": round(m.freq_cv_percentiles[99], 6),
                            "deg_p50": round(
                                m.mean_degradation_percentiles[50], 6),
                            "deg_p99": round(
                                m.mean_degradation_percentiles[99], 6),
                            "fleet_deg_cv": round(
                                m.fleet_degradation_cv, 6),
                            "cv_perf_p50": round(
                                linux.freq_cv_percentiles[50]
                                / max(m.freq_cv_percentiles[50], 1e-12), 4),
                            "freq_perf_p50": round(
                                linux.mean_degradation_percentiles[50]
                                / max(m.mean_degradation_percentiles[50],
                                      1e-12), 4),
                            "freq_perf_p99": round(
                                linux.mean_degradation_percentiles[99]
                                / max(m.mean_degradation_percentiles[99],
                                      1e-12), 4),
                        })
    emit("fig6_aging_effects", rows)
    return rows


if __name__ == "__main__":
    scenarios, routers = parse_axes(__doc__)
    run(scenarios=scenarios, routers=routers)
