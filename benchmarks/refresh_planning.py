"""Fleet-refresh planning: replace vs extend, priced per hardware SKU.

For every catalog SKU (`repro.hardware`) this driver runs a short
uniform fleet of that SKU, measures the NBTI degradation its host CPUs
actually accumulate under the proposed management policy, and asks each
registered `repro.carbon` model how long the silicon will last
(`model.lifetime` — the SKU's own Boavizta-style embodied figure and
baseline lifespan are priced in via `repro.hardware.sku_carbon_model`).
From that it builds the forward-looking decision curve a fleet owner
faces at refresh time, in kgCO2eq per core of serving capacity:

  extend   — keep the aged SKU: its embodied carbon is sunk, so the
             curve is its operational carbon (TDP x utilization x grid
             intensity) until the model's extended lifetime runs out,
             then a forced replacement (newest SKU's embodied lump +
             its operational rate) for the remaining horizon.
  replace  — buy the newest-generation SKU now: its embodied carbon
             lands as a lump at year 0, then its (lower, per-core)
             operational rate.

The crossover year — the first planning year where replacing is
cumulatively cheaper than extending — is the replace-vs-extend verdict,
and it moves with the carbon model: an optimistic lifetime model
(`reliability-threshold`) stretches the extend branch, a conservative
one (`linear-extension`) shortens it. Emits one row per
(sku, carbon_model, year) plus per-cell summary columns via the shared
benchmark emitter (`experiments/refresh_planning[_mini].json`).

    PYTHONPATH=src python benchmarks/refresh_planning.py          # full
    PYTHONPATH=src python benchmarks/refresh_planning.py --mini   # CI
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks import common
from repro.carbon import available_carbon_models
from repro.carbon.intensity import ConstantIntensity
from repro.hardware import available_skus, get_sku
from repro.hardware.inventory import sku_carbon_model
from repro.sim import ExperimentConfig, run_experiment

#: assumed average CPU utilization for the operational branches
UTILIZATION = 0.5
HOURS_PER_YEAR = 8760.0


def _op_kg_per_core_year(sku, g_per_kwh: float) -> float:
    """Operational kgCO2eq per core-year at the assumed utilization."""
    kwh = sku.cpu_tdp_w / sku.num_cores * UTILIZATION \
        * HOURS_PER_YEAR / 1000.0
    return kwh * g_per_kwh / 1000.0


def _measured_degradation(sku_name: str, duration_s: float,
                          rate_rps: float, seed: int) -> tuple:
    """Mean per-machine degradation of a short uniform fleet of this
    SKU under the proposed policy (the management the paper studies)."""
    cfg = ExperimentConfig(duration_s=duration_s, rate_rps=rate_rps,
                           seed=seed, n_prompt=1, n_token=2,
                           policy="proposed", fleet=sku_name)
    res = run_experiment(cfg)
    deg = float(np.mean(res.per_machine_degradation))
    return max(deg, 0.0), res


def curves(sku, newest, est, g_per_kwh: float,
           horizon_years: int) -> list[dict]:
    """Cumulative replace-vs-extend rows for one (sku, model) cell."""
    op_old = _op_kg_per_core_year(sku, g_per_kwh)
    op_new = _op_kg_per_core_year(newest, g_per_kwh)
    emb_new = newest.embodied_kg / newest.num_cores
    life_ext = est.extended_life_years
    rows = []
    crossover = None
    for year in range(1, horizon_years + 1):
        if year <= life_ext:
            extend = op_old * year
        else:
            # the extended silicon died: forced refresh mid-plan
            extend = (op_old * life_ext + emb_new
                      + op_new * (year - life_ext))
        replace = emb_new + op_new * year
        if crossover is None and replace <= extend:
            crossover = year
        rows.append({"year": year,
                     "extend_kgco2eq_per_core": round(extend, 4),
                     "replace_kgco2eq_per_core": round(replace, 4)})
    for row in rows:
        row["crossover_year"] = crossover
    return rows


def run(mini: bool = False, carbon_models=None,
        horizon_years: int = 8, intensity_g_per_kwh: float | None = None,
        seed: int = 0) -> list[dict]:
    models = tuple(carbon_models or available_carbon_models())
    g = (intensity_g_per_kwh if intensity_g_per_kwh is not None
         else ConstantIntensity().mean_g_per_kwh())
    duration = 8.0 if mini else 60.0
    rate = 20.0 if mini else 40.0
    skus = {name: get_sku(name) for name in available_skus()}
    newest = max(skus.values(), key=lambda s: (s.launch_year, s.generation))
    rows: list[dict] = []
    for name, sku in skus.items():
        deg, res = _measured_degradation(name, duration, rate, seed)
        for model_name in models:
            model = sku_carbon_model(sku, model_name, {})
            est = model.lifetime(res.deg_reference, deg)
            for row in curves(sku, newest, est, g, horizon_years):
                rows.append({
                    "sku": name,
                    "generation": sku.generation,
                    "launch_year": sku.launch_year,
                    "carbon_model": model_name,
                    "measured_degradation_ghz": round(deg, 6),
                    "extension_factor": round(est.extension_factor, 4),
                    "extended_life_years": round(
                        est.extended_life_years, 3),
                    "embodied_kgco2eq": round(sku.embodied_kg, 2),
                    "newest_sku": max(
                        skus, key=lambda n: (skus[n].launch_year,
                                             skus[n].generation)),
                    **row,
                })
    common.emit("refresh_planning_mini" if mini else "refresh_planning",
                rows)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        epilog=common.axes_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    common.add_carbon_model_arg(ap)
    ap.add_argument("--mini", action="store_true",
                    help="CI smoke: 8 s sims, same curve structure")
    ap.add_argument("--horizon", type=int, default=8,
                    help="planning horizon in years (default 8)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    models = (tuple(args.carbon_model) if args.carbon_model
              else available_carbon_models())
    rows = run(mini=args.mini, carbon_models=models,
               horizon_years=args.horizon, seed=args.seed)
    cells = {(r["sku"], r["carbon_model"]) for r in rows}
    if not rows or any(r["replace_kgco2eq_per_core"] <= 0 for r in rows):
        print("refresh planning: degenerate curves", file=sys.stderr)
        return 1
    print(f"refresh planning OK: {len(rows)} rows across "
          f"{len(cells)} (sku x carbon model) cells")
    return 0


if __name__ == "__main__":
    sys.exit(main())
