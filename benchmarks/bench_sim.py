"""Simulator perf-benchmark harness: events/sec and wall time per
canonical config, emitted to BENCH_sim.json to seed the repo's perf
trajectory.

    PYTHONPATH=src python benchmarks/bench_sim.py            # full (~3 min)
    PYTHONPATH=src python benchmarks/bench_sim.py --smoke    # CI-scale

The committed BASELINE block pins the pre-optimization numbers (PR 4's
"before", captured at commit 94bd8ac on the same canonical default
config) so every future run reports an honest end-to-end speedup next
to its absolute numbers. Wall-time comparisons use the min over runs —
the least-noise estimator on shared machines.

Bit-exactness is NOT this harness's job: tests/test_perf_bitexact.py
pins optimized-vs-golden `ExperimentMetrics`; this file only measures.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import statistics
import time

import numpy as np

from repro.core import CoreManager
from repro.sim import ExperimentConfig, metrics as metrics_mod
from repro.sim.cluster import Cluster
from repro.sim.fleetstate import FleetAgingSettler
from repro.workloads import get_scenario

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_sim.json")
# --smoke writes elsewhere by default so a CI-scale run can never
# clobber the committed full-config record README points at.
SMOKE_OUT = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_sim_smoke.json")

# Pre-PR-4 numbers for the canonical default config (ExperimentConfig()
# defaults: proposed / jsq / conversation-poisson, 22 machines x 40
# cores, 120 s @ 60 rps, seed 0), captured at commit 94bd8ac with this
# harness's timing loop (3 runs, min/median). Events counted by the
# event loop; the per-event numpy dispatch these numbers price out is
# exactly what the PR-4 fast paths removed.
BASELINE = {
    "captured_at_commit": "94bd8ac",
    "benchmark": "default-e2e",
    "runs": 3,
    "wall_s_min": 12.148,
    "wall_s_median": 12.839,
    "events": 140488,
    "events_per_sec": 11563.0,
    "completed": 2525,
}


def _run_once(cfg: ExperimentConfig) -> dict:
    """One timed end-to-end experiment; returns wall/events/completed."""
    scenario = get_scenario(cfg.scenario, **cfg.scenario_options)
    trace = scenario.generate(rate_rps=cfg.rate_rps,
                              duration_s=cfg.duration_s, seed=cfg.seed)
    t0 = time.perf_counter()
    cluster = Cluster(cfg)
    cluster.run(list(trace), cfg.duration_s,
                sample_period_s=cfg.sample_period_s)
    wall = time.perf_counter() - t0
    m = metrics_mod.collect(cluster, cfg)
    return {"wall_s": wall, "events": cluster.queue.processed,
            "completed": m.completed}


def bench_end_to_end(cfg: ExperimentConfig, runs: int) -> dict:
    walls, events, completed = [], None, None
    for _ in range(runs):
        r = _run_once(cfg)
        walls.append(r["wall_s"])
        events, completed = r["events"], r["completed"]
    wall_min = min(walls)
    return {
        "runs": runs,
        "wall_s_min": round(wall_min, 4),
        "wall_s_median": round(statistics.median(walls), 4),
        "events": events,
        "events_per_sec": round(events / wall_min, 1),
        "completed": completed,
        "config": {
            "policy": cfg.policy, "router": cfg.router,
            "scenario": cfg.scenario, "num_cores": cfg.num_cores,
            "n_machines": cfg.n_machines, "rate_rps": cfg.rate_rps,
            "duration_s": cfg.duration_s, "seed": cfg.seed,
        },
    }


def bench_telemetry_overhead(duration_s: float = 20.0,
                             runs: int = 2) -> dict:
    """Telemetry-off vs telemetry-on wall time on a short default-config
    run — the price of the hub's event/series recording when enabled,
    and evidence the `is not None` guards are free when disabled (the
    off time here is the same path `bench_end_to_end` measures)."""
    from repro.sim.runner import run_experiment

    def timed(cfg):
        walls = []
        for _ in range(runs):
            t0 = time.perf_counter()
            run_experiment(cfg)
            walls.append(time.perf_counter() - t0)
        return min(walls)

    base = ExperimentConfig(duration_s=duration_s)
    off = timed(base)
    on = timed(base.with_telemetry())
    return {
        "duration_s": duration_s,
        "runs": runs,
        "telemetry_off_s": round(off, 4),
        "telemetry_on_s": round(on, 4),
        "overhead_pct": round(100.0 * (on - off) / off, 2),
    }


def bench_manager_hot_path(n_ops: int = 20_000) -> dict:
    """Raw assign/release throughput of one CoreManager (proposed):
    the per-event cost every simulated CPU task pays."""
    m = CoreManager(40, policy="proposed", rng=np.random.default_rng(0))
    t0 = time.perf_counter()
    t = 0.0
    for tid in range(n_ops):
        t += 0.001
        m.assign(tid, t)
        m.release(tid, t + 0.0005)
    wall = time.perf_counter() - t0
    return {"ops": n_ops, "assign_release_per_sec": round(n_ops / wall, 1)}


def bench_fleet_settle(n_machines: int = 22, num_cores: int = 40,
                       reps: int = 200) -> dict:
    """Fleet-batched periodic settlement vs n_machines sequential
    settle_all chains (what the cluster tick used to do)."""
    def build():
        ms = [CoreManager(num_cores, policy="linux",
                          rng=np.random.default_rng(i))
              for i in range(n_machines)]
        for i, m in enumerate(ms):       # heterogeneous regimes
            for tid in range(i % 7):
                m.assign(tid, 0.0)
        return ms

    ms = build()
    t0 = time.perf_counter()
    for k in range(reps):
        for m in ms:
            m.settle_all(float(k + 1))
    seq = time.perf_counter() - t0

    ms = build()
    settler = FleetAgingSettler(ms)
    t0 = time.perf_counter()
    for k in range(reps):
        settler.settle(float(k + 1))
    batched = time.perf_counter() - t0
    return {"reps": reps, "n_machines": n_machines,
            "sequential_s": round(seq, 4), "batched_s": round(batched, 4),
            "speedup": round(seq / batched, 2)}


def bench_fleet_scale(smoke: bool = False) -> dict:
    """Scale curve: event-loop reference vs the vectorized fleet engine
    (`repro.sim.fleetsim`) at growing fleet sizes and horizons.

    Machines scale with the default 5:17 prompt:token split and
    proportional offered load, so per-machine utilization is comparable
    across the curve. The headline row drives >= 200 machines for >= 1
    simulated hour through the time-stepped engine — a scale where the
    per-event loop is no longer practical (its 22-machine x 120 s wall
    time extrapolates to ~15 min there). `machine_s_per_wall_s` is the
    honest cross-engine throughput unit: simulated machine-seconds per
    wall second."""
    from repro.sim.runner import run_experiment

    def scaled_cfg(n_machines: int, duration_s: float) -> ExperimentConfig:
        n_prompt = max(1, round(n_machines * 5 / 22))
        return ExperimentConfig(
            n_prompt=n_prompt, n_token=n_machines - n_prompt,
            rate_rps=round(60.0 * n_machines / 22, 3),
            duration_s=duration_s)

    if smoke:
        event_points = [(22, 30.0)]
        fleet_points = [("numpy", 22, 30.0), ("jax", 22, 30.0)]
    else:
        event_points = [(22, 120.0)]
        fleet_points = [("numpy", 22, 120.0), ("numpy", 50, 600.0),
                        ("numpy", 200, 3600.0), ("jax", 200, 3600.0)]

    rows = []
    for n, dur in event_points:
        cfg = scaled_cfg(n, dur)
        t0 = time.perf_counter()
        res = run_experiment(cfg)
        wall = time.perf_counter() - t0
        rows.append({"engine": "event", "backend": "python",
                     "n_machines": n, "duration_s": dur,
                     "wall_s": round(wall, 4),
                     "machine_s_per_wall_s": round(n * dur / wall, 1),
                     "completed": res.completed})
    for backend, n, dur in fleet_points:
        cfg = scaled_cfg(n, dur).with_engine("fleet", backend=backend)
        try:
            t0 = time.perf_counter()
            res = run_experiment(cfg)
            wall = time.perf_counter() - t0
        except ImportError:                  # jax absent on this host
            rows.append({"engine": "fleet", "backend": backend,
                         "n_machines": n, "duration_s": dur,
                         "skipped": "backend unavailable"})
            continue
        rows.append({"engine": "fleet", "backend": backend,
                     "n_machines": n, "duration_s": dur,
                     "wall_s": round(wall, 4),
                     "machine_s_per_wall_s": round(n * dur / wall, 1),
                     "completed": res.completed})
    return {"rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale run (short trace, 1 timing run); "
                    "skips the pinned-baseline speedup comparison")
    ap.add_argument("--runs", type=int, default=3,
                    help="timing repetitions for the end-to-end bench")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                    "BENCH_sim.json, or BENCH_sim_smoke.json with "
                    "--smoke)")
    args = ap.parse_args()

    if args.out is None:
        args.out = SMOKE_OUT if args.smoke else DEFAULT_OUT
    if args.smoke:
        cfg = ExperimentConfig(duration_s=8.0)
        runs = 1
    else:
        cfg = ExperimentConfig()
        runs = args.runs

    out = {
        "benchmark": "default-e2e" if not args.smoke else "smoke-e2e",
        "env": {
            "python": platform.python_version(),
            "numpy": np.__version__,
            "machine": platform.machine(),
            "cpus": os.cpu_count(),
        },
        "current": bench_end_to_end(cfg, runs),
        "micro": {
            "manager_hot_path": bench_manager_hot_path(),
            "fleet_settle": bench_fleet_settle(),
            "telemetry_overhead": bench_telemetry_overhead(
                duration_s=8.0 if args.smoke else 20.0,
                runs=1 if args.smoke else 2),
        },
        "fleet_scale": bench_fleet_scale(smoke=args.smoke),
    }
    if not args.smoke:
        out["baseline"] = BASELINE
        out["speedup_end_to_end"] = round(
            BASELINE["wall_s_min"] / out["current"]["wall_s_min"], 2)

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    for k, v in out.items():
        if k != "env":
            print(f"{k}: {json.dumps(v)}")
    print(f"wrote {os.path.normpath(args.out)}")


if __name__ == "__main__":
    main()
