"""Paper Fig. 7: estimated yearly CPU-embodied carbon reduction via the
paper's linear lifetime-extension model (p99 and p50 of mean-frequency
performance). Paper headline: 37.67% @ p99, 49.01% @ p50.

`--scenario` (repeatable) recomputes the carbon estimate under any
registered workload scenario — the headline number's robustness to
temporal demand shape (EcoServe's central question) in one sweep.
`--router` (repeatable) does the same on the cluster-routing axis and
additionally reports the per-run fleet yearly total aggregated from
per-machine `CarbonEstimate`s.
"""
from __future__ import annotations

from repro.core.carbon import CPU_EMBODIED_KGCO2EQ, BASELINE_LIFESPAN_YEARS
from repro.sim import ExperimentConfig, carbon_comparison, run_policy_sweep

from benchmarks.common import (DEFAULT_ROUTERS, DEFAULT_SCENARIOS, emit,
                               parse_axes)

N_MACHINES = 22


def run(duration_s: float = 120.0, rates=(40, 70, 100),
        scenarios=DEFAULT_SCENARIOS, routers=DEFAULT_ROUTERS) -> list[dict]:
    rows = []
    for scenario in scenarios:
        for router in routers:
            for rate in rates:
                res = run_policy_sweep(ExperimentConfig(
                    num_cores=40, rate_rps=rate, duration_s=duration_s,
                    seed=1, scenario=scenario, router=router))
                base_yearly = (N_MACHINES * CPU_EMBODIED_KGCO2EQ
                               / BASELINE_LIFESPAN_YEARS)
                for tech in ("least-aged", "proposed"):
                    for pct in (99, 50):
                        est = carbon_comparison(res["linux"], res[tech], pct)
                        rows.append({
                            "scenario": res[tech].scenario,
                            "router": res[tech].router,
                            "rate_rps": rate,
                            "policy": tech,
                            "percentile": pct,
                            "lifetime_extension": round(
                                est.extension_factor, 4),
                            "cluster_yearly_kgco2eq": round(
                                N_MACHINES * est.yearly_kgco2eq, 2),
                            "cluster_baseline_kgco2eq": round(base_yearly, 2),
                            "reduction_pct": round(
                                100 * est.reduction_frac, 2),
                            "fleet_yearly_kgco2eq": round(
                                res[tech].fleet_yearly_kgco2eq, 2),
                        })
    emit("fig7_carbon", rows)
    return rows


if __name__ == "__main__":
    scenarios, routers = parse_axes(__doc__)
    run(scenarios=scenarios, routers=routers)
