"""Paper Fig. 7: estimated yearly CPU-embodied carbon reduction via the
paper's linear lifetime-extension model (p99 and p50 of mean-frequency
performance). Paper headline: 37.67% @ p99, 49.01% @ p50.

`--scenario` (repeatable) recomputes the carbon estimate under any
registered workload scenario — the headline number's robustness to
temporal demand shape (EcoServe's central question) in one sweep.
`--router` (repeatable) does the same on the cluster-routing axis and
additionally reports the per-run fleet yearly total aggregated from
per-machine `LifetimeEstimate`s. `--carbon-model` (repeatable) re-prices
the same degradation data under any registered `repro.carbon` model —
the EcoLogits-style range over lifetime assumptions (e.g. the paper's
conservative `linear-extension` next to the optimistic
`reliability-threshold`). `--power-model` (repeatable) re-prices the
same per-core residency data under any registered `repro.power` model
(`fleet_energy_under`, exact) — the measured-energy counterpart on the
operational side. `--fleet` (repeatable) re-runs the grid on any
`repro.hardware` fleet spec (a SKU name or "sku:count+sku:rest"), so
mixed fleets price each machine against its own SKU's embodied and TDP
figures. Each sweep's full grid is also persisted as a `SweepResult`
JSON (energy scalars included) next to the row CSVs, so runs diff
across commits via `SweepResult.diff_scalars`.
"""
from __future__ import annotations

import os

from repro.sim import ExperimentConfig, carbon_comparison, run_policy_sweep

from benchmarks.common import (DEFAULT_CARBON_MODELS, DEFAULT_FLEETS,
                               DEFAULT_POWER_MODELS, DEFAULT_ROUTERS,
                               DEFAULT_SCENARIOS, RESULTS_DIR, emit,
                               parse_axes)

N_MACHINES = 22


def run(duration_s: float = 120.0, rates=(40, 70, 100),
        scenarios=DEFAULT_SCENARIOS, routers=DEFAULT_ROUTERS,
        carbon_models=DEFAULT_CARBON_MODELS,
        power_models=DEFAULT_POWER_MODELS,
        fleets=DEFAULT_FLEETS,
        telemetry: dict | None = None) -> list[dict]:
    rows = []
    os.makedirs(RESULTS_DIR, exist_ok=True)
    for scenario in scenarios:
        for router in routers:
            for fleet in fleets:
                _run_fleet(rows, duration_s, rates, scenario, router,
                           carbon_models, power_models, fleet, telemetry)
    emit("fig7_carbon", rows)
    return rows


def _run_fleet(rows, duration_s, rates, scenario, router, carbon_models,
               power_models, fleet, telemetry):
    for rate in rates:
        # One simulation per cell: aging is carbon-model-independent
        # and residencies are power-model-independent, so each
        # requested model re-prices the same saved data
        # (`fleet_yearly_under` / `fleet_energy_under`, exact) instead
        # of re-running the sweep. The first power model prices the
        # persisted grid's own energy scalars.
        cfg = ExperimentConfig(
            num_cores=40, rate_rps=rate, duration_s=duration_s,
            seed=1, scenario=scenario, router=router,
            power_model=power_models[0])
        if fleet != "uniform":
            cfg = cfg.with_fleet(fleet)
        if telemetry is not None:
            cfg = cfg.with_telemetry(**telemetry)
        res = run_policy_sweep(cfg)
        tag = "" if fleet == "uniform" else f"_{fleet.replace(':', '-')}"
        res.save(os.path.join(
            RESULTS_DIR,
            f"fig7_sweep_{scenario}_{router}{tag}_r{rate}.json"))
        for model in carbon_models:
            for power in power_models:
                for tech in ("least-aged", "proposed"):
                    fleet_yearly = res[tech].fleet_yearly_under(model)
                    fleet_kwh = res[tech].fleet_energy_under(power)
                    for pct in (99, 50):
                        est = carbon_comparison(
                            res["linux"], res[tech], pct, model=model)
                        rows.append({
                            "scenario": res[tech].scenario,
                            "router": res[tech].router,
                            "carbon_model": model,
                            "power_model": power,
                            "fleet": fleet,
                            "rate_rps": rate,
                            "policy": tech,
                            "percentile": pct,
                            "lifetime_extension": round(
                                est.extension_factor, 4),
                            "cluster_yearly_kgco2eq": round(
                                N_MACHINES * est.yearly_kgco2eq, 2),
                            "cluster_baseline_kgco2eq": round(
                                N_MACHINES
                                * est.baseline_yearly_kgco2eq, 2),
                            "reduction_pct": round(
                                100 * est.reduction_frac, 2),
                            "fleet_yearly_kgco2eq": round(
                                fleet_yearly, 2),
                            "fleet_energy_kwh": round(fleet_kwh, 6),
                        })


if __name__ == "__main__":
    scenarios, routers, carbon_models, power_models, fleets, telemetry = \
        parse_axes(__doc__, carbon=True, power=True, fleet=True,
                   telemetry=True)
    run(scenarios=scenarios, routers=routers, carbon_models=carbon_models,
        power_models=power_models, fleets=fleets, telemetry=telemetry)
