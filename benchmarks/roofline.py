"""Aggregate dry-run JSON records into the §Roofline table.

Reads experiments/dryrun/*.json (written by `repro.launch.dryrun --out`),
computes the three roofline terms per (arch x shape) on the single-pod
mesh, identifies the dominant bottleneck, and emits a markdown table +
the hillclimb-candidate selection (worst roofline fraction, most
collective-bound, most paper-representative).

  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str, mesh: str = "16x16") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def enrich(r: dict) -> dict:
    t = r["roofline_s"]
    dom = max(t, key=t.get)
    total = max(t.values())
    step_time = total  # bound = max of the three terms (no overlap model)
    compute_frac = t["compute"] / max(step_time, 1e-30)
    return {
        **r,
        "dominant": dom,
        "bound_step_s": step_time,
        "roofline_fraction": compute_frac,  # fraction of bound that is MXU
    }


def table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | peak GB/dev | useful FLOP ratio |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        t = r["roofline_s"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']:.3e} | "
            f"{t['memory']:.3e} | {t['collective']:.3e} | "
            f"{r['dominant']} | "
            f"{r['per_device']['peak_bytes']/1e9:.2f} | "
            f"{r['useful_flops_ratio']:.3f} |")
    return hdr + "\n".join(rows)


def candidates(recs: list[dict]) -> dict:
    """Select the three hillclimb pairs."""
    def key(r):
        return f"{r['arch']} x {r['shape']}"

    worst_frac = min(recs, key=lambda r: r["roofline_fraction"])
    coll = max(recs, key=lambda r: (r["roofline_s"]["collective"]
                                    / max(r["bound_step_s"], 1e-30)))
    # most representative of the paper: the serving decode path of the
    # largest dense model (host-CPU tasks per decode step dominate the
    # paper's workload -> decode_32k llama3-8b)
    rep = next((r for r in recs if r["arch"] == "llama3-8b"
                and r["shape"] == "decode_32k"), recs[0])
    return {"worst_roofline_fraction": key(worst_frac),
            "most_collective_bound": key(coll),
            "paper_representative": key(rep)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = [enrich(r) for r in load(args.dir)]
    if not recs:
        print("no dry-run records found; run repro.launch.dryrun --all "
              "--out", args.dir)
        return
    print(table(recs))
    print()
    for k, v in candidates(recs).items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
