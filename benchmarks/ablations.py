"""Beyond-paper ablations of the proposed technique's design choices.

1. Reaction-function gains: the paper fixes tan(0.785·e)/arctan(1.55·e).
   We sweep the asymmetry to show why slow-idle/fast-wake is the right
   shape (symmetric or inverted gains either oversubscribe or leave
   age-halting opportunity unused).
2. Idling period: Algorithm 2's control interval trades oversubscription
   risk against actuation overhead.
3. Idle-history window: Algorithm 1's age-estimation window (8 in the
   paper, after the Linux cpuidle governor).
"""
from __future__ import annotations

import numpy as np

from repro.core import CoreManager
from repro.core import idling, mapping
from repro.sim import ExperimentConfig, run_experiment

from benchmarks.common import emit


def _bursty_load(mgr: CoreManager, hours: float = 1.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    task_id, t = 0, 0.0
    while t < hours * 3600:
        for _ in range(rng.poisson(3)):
            mgr.assign(task_id, t)
            mgr.release(task_id, t + rng.uniform(0.005, 0.03))
            task_id += 1
        t += 1.0
        mgr.periodic(t)
    mgr.settle_all(hours * 3600)
    return mgr


def sweep_reaction_gains() -> list[dict]:
    rows = []
    base = (idling.UNDERUTIL_GAIN, idling.OVERSUB_GAIN)
    try:
        for under, over in [(0.785, 1.55),   # paper
                            (1.55, 1.55),    # symmetric fast
                            (0.785, 0.785),  # symmetric slow
                            (1.55, 0.785),   # inverted (fast idle/slow wake)
                            (0.4, 2.5)]:     # extreme asymmetry
            idling.UNDERUTIL_GAIN, idling.OVERSUB_GAIN = under, over
            mgr = _bursty_load(CoreManager(
                40, policy="proposed", rng=np.random.default_rng(0)))
            samples = np.asarray(mgr.metrics.idle_norm_samples)
            rows.append({
                "ablation": "reaction_gains",
                "underutil_gain": under,
                "oversub_gain": over,
                "is_paper": (under, over) == (0.785, 1.55),
                "mean_degradation": round(
                    mgr.mean_frequency_degradation(), 6),
                "idle_p90": round(float(np.percentile(samples, 90)), 4),
                "oversub_frac": round(float((samples < -0.1).mean()), 4),
            })
    finally:
        idling.UNDERUTIL_GAIN, idling.OVERSUB_GAIN = base
    return rows


def sweep_idling_period() -> list[dict]:
    rows = []
    for period in (0.25, 1.0, 5.0, 30.0):
        m = run_experiment(ExperimentConfig(
            policy="proposed", num_cores=40, rate_rps=60, duration_s=60,
            seed=0, idling_period_s=period))
        rows.append({
            "ablation": "idling_period",
            "period_s": period,
            "deg_p50": round(m.mean_degradation_percentiles[50], 6),
            "idle_p90": round(m.idle_norm_percentiles[90], 4),
            "idle_p1": round(m.idle_norm_percentiles[1], 4),
            "p99_latency_s": round(m.p99_latency_s, 2),
        })
    return rows


def sweep_history_window() -> list[dict]:
    rows = []
    base = mapping.IDLE_HISTORY_LEN
    try:
        for win in (2, 8, 32):
            mapping.IDLE_HISTORY_LEN = win
            mgr = _bursty_load(CoreManager(
                40, policy="proposed", rng=np.random.default_rng(0)))
            rows.append({
                "ablation": "idle_history_window",
                "window": win,
                "is_paper": win == 8,
                "freq_cv": round(mgr.frequency_cv(), 6),
                "mean_degradation": round(
                    mgr.mean_frequency_degradation(), 6),
            })
    finally:
        mapping.IDLE_HISTORY_LEN = base
    return rows


def run() -> list[dict]:
    rows = sweep_reaction_gains() + sweep_idling_period() \
        + sweep_history_window()
    emit("ablations", rows)
    return rows


if __name__ == "__main__":
    run()
