"""Paper Fig. 2: distribution of concurrent inference tasks per machine
at different throughput levels (uncovers CPU underutilization O1/O2).

Accepts `--scenario` (repeatable) to profile task concurrency under any
registered workload scenario — bursty/diurnal arrivals shift the O2
burst statistics substantially vs homogeneous Poisson.
"""
from __future__ import annotations

import numpy as np

from repro.sim import ExperimentConfig, run_experiment

from benchmarks.common import DEFAULT_SCENARIOS, emit, parse_scenarios


def run(duration_s: float = 60.0, rates=(40, 60, 80, 100),
        scenarios=DEFAULT_SCENARIOS) -> list[dict]:
    rows = []
    for scenario in scenarios:
        for rate in rates:
            m = run_experiment(ExperimentConfig(
                policy="linux", num_cores=40, rate_rps=rate,
                duration_s=duration_s, seed=0, scenario=scenario))
            samples = np.concatenate(m.per_machine_task_samples)
            rows.append({
                "scenario": m.scenario,
                "rate_rps": rate,
                "task_mean": round(float(samples.mean()), 3),
                "task_p50": float(np.percentile(samples, 50)),
                "task_p99": float(np.percentile(samples, 99)),
                "task_max": int(samples.max()),
                "o1_underutilized": bool(samples.mean() < 40 * 0.25),
                "o2_bursts": bool(samples.max() >= 5 * samples.mean()),
            })
    emit("fig2_task_distribution", rows)
    return rows


if __name__ == "__main__":
    run(scenarios=parse_scenarios(__doc__))
